"""Fig. 16 analogue: CFD speedup after each optimization step
(baseline KBK → CKE-with-channel → + kernel balancing), measured and
modeled, mirroring §7.3.1."""
from __future__ import annotations

from repro import workloads
from repro.core import (ChipSpec, compile_plan, cke_timeline,
                        kbk_timeline, optimize, plan_cke, profile_graph,
                        ResourceModel, Factors)

from .common import csv_row, time_fn


def run() -> list[str]:
    graph, buffers = workloads.cfd.build(n=1 << 16)
    graph = profile_graph(graph, buffers)
    model = ResourceModel(ChipSpec.cpu())
    plan = plan_cke(graph)
    compiled, report = optimize(graph, model=ResourceModel(ChipSpec.cpu()))

    kbk = compile_plan(plan, mode="kbk")
    cke = compile_plan(plan)

    t_kbk = time_fn(kbk, buffers)
    t_cke = time_fn(cke, buffers)

    times = {s.name: s.profile.time_s for s in graph.stages}
    utils = {s.name: model.estimate(s, Factors()) for s in graph.stages}
    tl_kbk = kbk_timeline(graph.topo_order(), times, utils)
    tl_cke = cke_timeline(plan.groups, times, utils)

    # balanced: stage times divide by granted N_uni (Alg. 1 estimate)
    n_uni = report.balance.n_uni() if report.balance else {}
    times_bal = {k: v / max(n_uni.get(k, 1), 1) for k, v in times.items()}
    tl_bal = cke_timeline(plan.groups, times_bal, utils)

    rows = [
        csv_row("fig16_cfd_kbk", t_kbk * 1e6, "speedup=1.00"),
        csv_row("fig16_cfd_channel", t_cke * 1e6,
                f"speedup={t_kbk/t_cke:.2f};"
                f"modeled={tl_kbk.makespan/tl_cke.makespan:.2f}"),
        csv_row("fig16_cfd_balanced", t_cke * 1e6,
                f"modeled={tl_kbk.makespan/tl_bal.makespan:.2f};"
                f"n_uni={n_uni}"),
        csv_row("fig16_cfd_eru", 0.0,
                f"kbk_eru={tl_kbk.time_weighted_eru:.3f};"
                f"cke_eru={tl_cke.time_weighted_eru:.3f}"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
