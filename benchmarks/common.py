"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall-clock seconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
