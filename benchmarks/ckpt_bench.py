"""Checkpoint format A/B: v1 host-gathered npz vs v2 per-shard files.

Runs in a subprocess with 8 fake host devices (the same emulation the
distributed tests use) so the tree is genuinely sharded over a
(stage, data, model) mesh.  For each format this times save and restore
wall-clock and reports the bytes the save path moves through host
memory:

- v1 gathers every leaf to a single global host array before writing
  (``np.savez`` of full arrays) — peak host buffer = the largest
  *global* leaf;
- v2 copies only the unique addressable shards (`snapshot_tree`) —
  peak host buffer = the largest *shard*, 1/stages x 1/model of the
  stacked layer leaf on this mesh.

Total bytes written to disk are identical (same logical state); the
derived column makes the peak-buffer ratio explicit because that is
what breaks at real model scale, not wall-clock on a toy tree.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

from .common import csv_row

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, tempfile, time
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt import (load_checkpoint, save_checkpoint,
                            save_checkpoint_v1, snapshot_nbytes,
                            snapshot_tree)
    from repro.launch.mesh import make_mesh

    REPEATS = 3
    mesh = make_mesh((2, 2, 2), ("stage", "data", "model"))
    rng = np.random.default_rng(0)
    tree = {
        "layers": jax.device_put(
            jnp.asarray(rng.normal(size=(4, 256, 384)), jnp.float32),
            NamedSharding(mesh, P("stage", None, "model"))),
        "emb": jax.device_put(
            jnp.asarray(rng.normal(size=(512, 384)), jnp.float32),
            NamedSharding(mesh, P(None, "model"))),
        "step": jnp.int32(0),
    }
    jax.block_until_ready(tree)

    def med(fn):
        ts = []
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2]

    out = {}
    with tempfile.TemporaryDirectory() as d1, \
         tempfile.TemporaryDirectory() as d2:
        out["v1_save_s"] = med(
            lambda: save_checkpoint_v1(d1, 1, tree))
        out["v2_save_s"] = med(lambda: save_checkpoint(d2, 1, tree))
        out["v1_restore_s"] = med(lambda: load_checkpoint(d1, 1, tree))
        out["v2_restore_s"] = med(lambda: load_checkpoint(d2, 1, tree))

    global_nbytes = sum(
        int(np.asarray(l).nbytes) for l in jax.tree.leaves(tree))
    snaps = snapshot_tree(tree)
    out["v1_gather_bytes"] = global_nbytes
    out["v2_shard_bytes"] = snapshot_nbytes(snaps)
    out["v1_peak_buffer"] = max(
        int(np.asarray(l).nbytes) for l in jax.tree.leaves(tree))
    out["v2_peak_buffer"] = max(
        int(a.nbytes) for s in snaps for _, a in s.shards)
    print(json.dumps(out))
""")


def run() -> list[str]:
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600)
    if r.returncode != 0:
        raise RuntimeError(f"ckpt bench subprocess failed:\n"
                           f"{r.stderr[-3000:]}")
    m = json.loads(r.stdout.strip().splitlines()[-1])
    rows = []
    for fmt in ("v1", "v2"):
        rows.append(csv_row(
            f"ckpt_{fmt}_save", m[f"{fmt}_save_s"] * 1e6,
            f"gather_bytes={m[f'{fmt}_gather_bytes']}"
            if fmt == "v1" else
            f"shard_bytes={m['v2_shard_bytes']}"))
        rows.append(csv_row(
            f"ckpt_{fmt}_restore", m[f"{fmt}_restore_s"] * 1e6,
            f"peak_host_buffer={m[f'{fmt}_peak_buffer']}"))
    ratio = m["v1_peak_buffer"] / max(m["v2_peak_buffer"], 1)
    if ratio < 2.0:
        raise RuntimeError(
            "v2 peak host buffer should be a fraction of the largest "
            f"global leaf on a sharded mesh; got ratio {ratio:.2f}")
    rows.append(csv_row(
        "ckpt_v2_peak_buffer_ratio", 0.0,
        f"v1_peak={m['v1_peak_buffer']};v2_peak={m['v2_peak_buffer']};"
        f"ratio={ratio:.1f}x;verdict=NO-HOST-GATHER"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
