"""Table 2 analogue: per-workload resource utilization and ERU for the
baseline (naive factors) vs the balanced configuration, on the TPU
resource model (MXU/HBM-BW/VMEM/HBM-cap/ICI instead of ALUT/FF/RAM/DSP)."""
from __future__ import annotations

from repro import workloads
from repro.core import (ChipSpec, Factors, ResourceModel, eru,
                        optimize, profile_graph)

from .common import csv_row


def run() -> list[str]:
    rows = []
    model = ResourceModel(ChipSpec.cpu())
    for name, mod in sorted(workloads.ALL.items()):
        graph, buffers = mod.build()
        graph = profile_graph(graph, buffers)
        _, report = optimize(graph, model=ResourceModel(ChipSpec.cpu()))
        base_eru = {}
        opt_eru = {}
        for s in graph.stages:
            base_util = model.estimate(s, Factors())
            base_eru[s.name] = eru(base_util)
            f = (report.balance.factors.get(s.name, Factors())
                 if report.balance else Factors())
            opt_eru[s.name] = eru(model.estimate(s, f))
        n_uni = report.balance.n_uni() if report.balance else {}
        rows.append(csv_row(
            f"table2_{name}", 0.0,
            f"base_eru={ {k: round(v,3) for k,v in base_eru.items()} };"
            f"opt_eru={ {k: round(v,3) for k,v in opt_eru.items()} };"
            f"n_uni={n_uni}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
