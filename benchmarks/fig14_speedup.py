"""Fig. 14 analogue: per-workload speedup of the MKPipe-optimized plan over
the KBK baseline (each kernel individually optimized, executed
sequentially with materialization barriers).

Two numbers per workload:
  measured  — CPU wall clock of the compiled plan vs forced-KBK (the
              fusion/channel HBM-round-trip elimination is real on any
              backend);
  modeled   — makespan ratio of the ERU timelines on the TPU resource
              model (the paper's own Fig. 2 accounting), including the
              balancing step.
Paper reference: up to 3.6×, average 1.4×.
"""
from __future__ import annotations

from repro import workloads
from repro.core import (ChipSpec, ResourceModel, compile_plan,
                        optimize, profile_graph)

from .common import csv_row, time_fn


def run() -> list[str]:
    rows = []
    speedups_measured = []
    speedups_modeled = []
    for name, mod in sorted(workloads.ALL.items()):
        graph, buffers = mod.build()
        graph = profile_graph(graph, buffers)
        compiled, report = optimize(graph, model=ResourceModel(ChipSpec.cpu()))
        kbk = compile_plan(report.plan, mode="kbk")

        t_opt = time_fn(compiled, buffers)
        t_kbk = time_fn(kbk, buffers)
        measured = t_kbk / t_opt
        modeled = report.modeled_speedup
        speedups_measured.append(measured)
        speedups_modeled.append(modeled)
        mechs = {f"{e.producer}->{e.consumer}": e.mechanism
                 for e in report.plan.edges}
        rows.append(csv_row(
            f"fig14_{name}", t_opt * 1e6,
            f"kbk_us={t_kbk*1e6:.1f};measured_speedup={measured:.2f};"
            f"modeled_speedup={modeled:.2f};mechanisms={mechs}"))
    gm = lambda xs: float(__import__("numpy").prod(xs)) ** (1 / len(xs))
    rows.append(csv_row(
        "fig14_summary", 0.0,
        f"geomean_measured={gm(speedups_measured):.2f};"
        f"geomean_modeled={gm(speedups_modeled):.2f};"
        f"max_measured={max(speedups_measured):.2f};"
        f"paper_avg=1.4;paper_max=3.6"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
