"""Roofline table from the multi-pod dry-run artifacts (§Roofline).

Per (arch × shape × mesh) cell: the three roofline terms (compute /
memory / collective, in seconds), the dominant bottleneck, MODEL_FLOPS
/ HLO_FLOPs usefulness ratio, and a one-line "what would move the
dominant term" note.  Reads results/dryrun/*.json produced by
`python -m repro.launch.dryrun --all`.
"""
from __future__ import annotations

import json
import pathlib

from .common import csv_row

DRYRUN = pathlib.Path("results/dryrun")

ADVICE = {
    "compute": ("raise MXU occupancy: larger per-device batch or less TP "
                "for this size"),
    "memory": ("cut HBM traffic: Pallas-fuse attention/FFN stage pairs "
               "(probs stay in VMEM), bf16 intermediates, wider fusion"),
    "collective": ("cut ICI traffic: bf16 collectives, sequence-parallel "
                   "norms, DP-over-model for small archs, all-to-all MoE "
                   "dispatch"),
}


def load(dirpath: pathlib.Path = DRYRUN) -> list[dict]:
    recs = []
    for p in sorted(dirpath.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def run(dirpath: pathlib.Path = DRYRUN) -> list[str]:
    rows = []
    recs = load(dirpath)
    compiled = [r for r in recs if "skipped" not in r]
    skipped = [r for r in recs if "skipped" in r]
    for r in sorted(compiled,
                    key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["terms_s"]
        bound = r["bottleneck"]
        step_s = max(t.values())
        rows.append(csv_row(
            f"roofline_{r['arch']}__{r['shape']}__{r['mesh']}",
            step_s * 1e6,
            f"compute_s={t['compute']:.4f};memory_s={t['memory']:.4f};"
            f"collective_s={t['collective']:.4f};bottleneck={bound};"
            f"useful_flops_ratio={r['useful_flops_ratio']:.3f};"
            f"roofline_fraction={t['compute']/step_s:.3f};"
            f"advice={ADVICE[bound]}"))
    for r in sorted(skipped,
                    key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        rows.append(csv_row(
            f"roofline_{r['arch']}__{r['shape']}__{r['mesh']}", 0.0,
            f"SKIPPED: {r['skipped'][:80]}"))
    # grad_int8 collective-bytes A/B: pair cells that differ only by the
    # grad_int8 variant (produced with `--mesh dp` / `--mesh dp --variant
    # grad_int8`) and report the reduction the int8 gradient all-reduce
    # buys over the f32 baseline.
    def ab_key(r):
        vs = tuple(v for v in r.get("variants", ()) if v != "grad_int8")
        return (r["arch"], r["shape"], r["mesh"], vs)

    base = {ab_key(r): r for r in compiled
            if "grad_int8" not in r.get("variants", ())}
    for r in compiled:
        if "grad_int8" not in r.get("variants", ()):
            continue
        b = base.get(ab_key(r))
        if b is None:
            continue
        cb_fp, cb_i8 = (b["per_device"]["collective_bytes"],
                        r["per_device"]["collective_bytes"])
        rows.append(csv_row(
            f"grad_int8_ab_{r['arch']}__{r['shape']}__{r['mesh']}", 0.0,
            f"collective_bytes_fp32={cb_fp:.3e};"
            f"collective_bytes_int8={cb_i8:.3e};"
            f"ratio={cb_i8 / cb_fp if cb_fp else 0.0:.3f}"))
    n_bound = {}
    for r in compiled:
        n_bound[r["bottleneck"]] = n_bound.get(r["bottleneck"], 0) + 1
    rows.append(csv_row("roofline_summary", 0.0,
                        f"cells={len(compiled)};skipped={len(skipped)};"
                        f"bottlenecks={n_bound}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
