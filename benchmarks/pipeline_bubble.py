"""Measured vs. predicted pipeline fill/drain bubble (paper Fig. 5 style
decision validation, applied to the GPipe schedule).

For each (n_micro, n_stages) point, an `n_stages`-device subprocess runs
the microbatched `pipeline_apply_microbatched` schedule and the plain
sequential composition of the same stages on the same total batch, and
times both.  Every device computes on every tick of the schedule — the
(M + S - 1) · S device-tick area — while the sequential baseline does the
useful M · S ticks' work, so on host devices that share the same cores
the wall-clock ratio exposes the bubble:

    measured_bubble = 1 - t_seq / t_pipe     ≈ (S-1) / (M + S-1)

which is exactly `pipeline_bubble_fraction(M, S)`.  Subprocesses are
used because the device count must be fixed before jax initializes
(tests/README.md, "the fake-host-device trick").

Caveats of the host-device emulation: the schedule's masking/injection
copies add a per-tick overhead proportional to the activation size, and
the XLA CPU backend partially parallelizes "devices" over host cores, so
the measured bubble carries a constant offset above the analytic value.
The comparison to make is *across* points: measured decreases
monotonically with n_micro at fixed n_stages and ranks the points the
way the model predicts — the paper-style decision-validation signal.

Rows: ``bubble_m{M}_s{S}, t_pipe_us, predicted=..;measured=..``.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

from .common import csv_row

# (n_micro, n_stages) sweep: fill/drain-dominated → amortized
POINTS = [(1, 4), (2, 4), (4, 4), (8, 4), (8, 2)]

SCRIPT = textwrap.dedent("""
    import os, sys, json, time
    M, S = int(sys.argv[1]), int(sys.argv[2])
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%d" % S)
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_apply_microbatched
    from repro.launch.mesh import make_mesh

    B, D, REP = 2048, 768, 2
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(S, REP, D, D)) * 0.1, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def stage_fn(p, c):
        x = c["x"]
        for r in range(REP):
            x = jnp.tanh(x @ p["w"][r])
        return {"x": x}

    mesh = make_mesh((S,), ("stage",))
    pipe = jax.jit(shard_map(
        lambda w, xs: pipeline_apply_microbatched(
            stage_fn, {"w": w}, {"x": xs}, M)["x"],
        mesh=mesh, in_specs=(P("stage"), P()), out_specs=P(),
        check_vma=False))

    def seq_fn(w, xs):
        for s in range(S):
            xs = stage_fn({"w": w[s]}, {"x": xs})["x"]
        return xs
    seq = jax.jit(seq_fn)

    def timed(f, *a):
        jax.block_until_ready(f(*a))              # compile + warm
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*a))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    t_pipe = timed(pipe, w, xs)
    t_seq = timed(seq, w, xs)
    out = np.asarray(pipe(w, xs))
    ref = np.asarray(seq(w, xs))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    print(json.dumps({"t_pipe": t_pipe, "t_seq": t_seq}))
""")


def measure(n_micro: int, n_stages: int, timeout: int = 600) -> dict:
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(n_micro), str(n_stages)],
        capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(
            f"bubble point (M={n_micro}, S={n_stages}) failed:\n"
            f"{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def run() -> list[str]:
    from repro.dist.pipeline import pipeline_bubble_fraction

    rows = []
    for n_micro, n_stages in POINTS:
        t = measure(n_micro, n_stages)
        predicted = pipeline_bubble_fraction(n_micro, n_stages)
        measured = max(0.0, 1.0 - t["t_seq"] / t["t_pipe"])
        rows.append(csv_row(
            f"bubble_m{n_micro}_s{n_stages}", t["t_pipe"] * 1e6,
            f"predicted={predicted:.3f};measured={measured:.3f};"
            f"t_seq_us={t['t_seq'] * 1e6:.0f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
