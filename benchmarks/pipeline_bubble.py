"""Measured vs. predicted pipeline bubble AND peak activation memory for
the pipeline schedules (GPipe, 1F1B, interleaved virtual stages) — paper
Fig. 5 style decision validation applied to the fused train executor.

For each (n_micro, n_stages) point, an `n_stages`-device subprocess runs
`pipeline_train_microbatched` (forward + backward + per-microbatch loss
in one step program) under both schedules, plus the plain sequential
composition of the same stages on the same total batch, and reports:

- **bubble**: wall-clock of the fused step vs the sequential step.  On
  fake host devices that serialize onto shared cores, wall-clock tracks
  the *device-tick area*, not the critical path, so the schedule runs
  with ``busy_idle=True`` (idle slots execute a discarded forward) and

      measured_bubble = 1 - t_seq / t_pipe     ≈ (S-1) / (M + S-1)

  which is `pipeline_bubble_fraction(M, S)` — the same formula for both
  schedules, and the measured values confirm they track each other.
- **peak memory**: `temp_size_in_bytes` from XLA's `memory_analysis` of
  the compiled fused step.  The schedules differ here: the activation
  stash is sized by `pipeline_peak_inflight` — M slots for GPipe,
  min(M, S) for 1F1B — so at M > S the 1F1B step's measured temp bytes
  sit strictly below GPipe's, by ≈ (M - min(M, S)) · microbatch bytes
  (`pipeline_peak_activation_bytes` is the analytic column printed next
  to it).

Caveats of the host-device emulation (see docs/pipeline-schedules.md):
the per-tick masking/stash copies add overhead proportional to the
activation size, backward micro-steps cost ~2× forward ones, and the XLA
CPU backend partially parallelizes "devices" over host cores — so the
measured bubble carries a constant offset above the analytic value.  The
comparison to make is *across* points (measured decreases monotonically
with n_micro at fixed n_stages, and ranks the points the way the model
predicts) and *between* the schedules' memory columns at fixed (M, S).

The `bubble_interleaved_*` rows compare all three schedules at the same
(M, S) with per-tick work held constant (every micro-step is the same
4-layer block, so interleaved cases run a v× deeper model against their
own sequential reference) — the constant per-tick emulation overhead
then cancels across schedules, and the `bubble_interleaved_v2_vs_1f1b_*`
verdict row asserts v=2's measured bubble lands strictly below plain
1F1B's ((S-1)/(vM+S-1) vs (S-1)/(M+S-1)).

Subprocesses are used because the device count must be fixed before jax
initializes (tests/README.md, "the fake-host-device trick").  Numerics
are asserted inside each subprocess: fused loss and gradients match the
sequential reference for every schedule.

Rows: ``bubble_{schedule}_m{M}_s{S}, t_pipe_us,
predicted=..;measured=..;peak_temp_mb=..;peak_act_analytic_mb=..``.

A second section compares **stage partitions on a jamba-style hybrid
pattern** (cheap mamba positions, heavier attention / MoE positions,
`n_repeats % n_stages != 0`): the uniform-padded split vs the
partition `choose_partition` picks from the per-position costs
(staggered extra-repeat placement: same realized per-island time,
lower fused bottleneck), both executed with padded per-stage stacks
and the masked (`lax.cond`) stage scan.  Rows report the predicted
bottleneck-based bubble (`pipeline_bubble_fraction(stage_times=...)`),
the padded-slot fraction, and the measured wall-clock/bubble; the
verdict row pins the planner's acceptance criterion — the chosen
partition's predicted bottleneck never exceeds the uniform-padded
alternative's.  (The two partitions execute the same total work —
the staggering moves it, it doesn't add any — so to the extent the XLA
CPU backend overlaps fake devices across host cores, the measured gap
reflects the better per-stage load balance; fully serialized hosts
would measure a tie instead.)
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

from .common import csv_row

# (n_micro, n_stages) sweep: fill/drain-dominated → amortized; the two
# M > S points are where 1F1B's memory bound bites
POINTS = [(1, 4), (2, 4), (4, 4), (8, 4), (8, 2)]

SCRIPT = textwrap.dedent("""
    import os, sys, json, time
    M, S = int(sys.argv[1]), int(sys.argv[2])
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%d" % S)
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_train_microbatched
    from repro.launch.mesh import make_mesh

    B, D, REP = 4096, 384, 2
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(S, REP, D, D)) * 0.1, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def stage_fn(p, c):
        x = c["x"]
        for r in range(REP):
            x = jnp.tanh(x @ p["w"][r])
        return {"x": x}

    def loss_fn(c):
        return jnp.sum(c["x"] ** 2)

    mesh = make_mesh((S,), ("stage",))

    def make(sched):
        return jax.jit(shard_map(
            lambda w, xs: pipeline_train_microbatched(
                stage_fn, {"w": w}, {"x": xs}, loss_fn, M,
                schedule=sched, busy_idle=True),
            mesh=mesh, in_specs=(P("stage"), P()),
            out_specs=(P(), {"w": P("stage")}), check_vma=False))

    def seq_fn(w, xs):
        total = jnp.zeros((), jnp.float32)
        xmb = xs.reshape(M, B // M, D)
        for m in range(M):
            c = {"x": xmb[m]}
            for s in range(S):
                c = stage_fn({"w": w[s]}, c)
            total = total + loss_fn(c)
        return total
    seq = jax.jit(jax.value_and_grad(seq_fn))

    def timed(f, *a):
        jax.block_until_ready(f(*a))              # compile + warm
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*a))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    l_ref, g_ref = seq(w, xs)
    out = {"mb_bytes": (B // M) * D * 4, "t_seq": timed(seq, w, xs)}
    for sched in ("gpipe", "1f1b"):
        # AOT-compile once; the same executable serves the numerics
        # check, the timed calls, and memory_analysis
        step = make(sched).lower(w, xs).compile()
        loss, grads = step(w, xs)
        np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-4)
        np.testing.assert_allclose(np.asarray(grads["w"]),
                                   np.asarray(g_ref),
                                   rtol=1e-3, atol=1e-5)
        ma = step.memory_analysis()
        out[sched] = {
            "t_pipe": timed(step, w, xs),
            "temp_bytes": (None if ma is None
                           else int(ma.temp_size_in_bytes)),
        }
    print(json.dumps(out))
""")


def measure(n_micro: int, n_stages: int, timeout: int = 900) -> dict:
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT, str(n_micro), str(n_stages)],
        capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(
            f"bubble point (M={n_micro}, S={n_stages}) failed:\n"
            f"{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


# three-schedule comparison at one (M, S) point.  Per-tick work is held
# CONSTANT across schedules: every micro-step — a flat stage or an
# interleaved chunk — computes the same 4-layer block, so an interleaved
# case runs a v× deeper model (N = 4·v·S layers) measured against its
# own sequential reference.  This matters on host-device emulation: the
# per-tick overhead (dispatch, mask/stash copies, thread contention) is
# a constant per tick, and an interleaved program has ~v× the ticks of a
# flat one — with a shared model the overhead scales with the tick count
# and buries the bubble signal, while with equal per-tick work the
# overhead *ratio* is the same for every schedule and cancels in the
# cross-schedule comparison.  measured = 1 - t_seq/t_pipe then estimates
# each schedule's own bubble — (S-1)/(M+S-1) flat, (S-1)/(vM+S-1)
# interleaved — and a smaller idle-slot fraction shows up directly as a
# smaller measured value: the virtual-stage payoff the verdict row pins.
INTERLEAVED_POINTS = [(8, 4), (8, 2)]
INTERLEAVED_SCRIPT = textwrap.dedent("""
    import os, sys, json, time
    M, S = int(sys.argv[1]), int(sys.argv[2])
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%d" % S)
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_train_microbatched
    from repro.launch.mesh import make_mesh

    B, D = 4096, 384
    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def stage_fn(p, c):                    # generic over stack depth
        x = c["x"]
        for r in range(p["w"].shape[0]):
            x = jnp.tanh(x @ p["w"][r])
        return {"x": x}

    def loss_fn(c):
        return jnp.sum(c["x"] ** 2)

    mesh = make_mesh((S,), ("stage",))

    def make(sched, v=1):
        return jax.jit(shard_map(
            lambda w, xs: pipeline_train_microbatched(
                stage_fn, {"w": w}, {"x": xs}, loss_fn, M,
                schedule=sched, virtual_stages=v, busy_idle=True),
            mesh=mesh, in_specs=(P("stage"), P()),
            out_specs=(P(), {"w": P("stage")}), check_vma=False))

    def make_seq(N):
        def seq_fn(w, xs):
            total = jnp.zeros((), jnp.float32)
            for xm in xs.reshape(M, B // M, D):
                c = {"x": xm}
                for r in range(N):
                    c = {"x": jnp.tanh(c["x"] @ w[r])}
                total = total + loss_fn(c)
            return total
        return jax.jit(jax.value_and_grad(seq_fn))

    def timed(f, *a):
        jax.block_until_ready(f(*a))          # compile + warm
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*a))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    out = {"mb_bytes": (B // M) * D * 4}
    cases = [("gpipe", 1), ("1f1b", 1), ("interleaved_v2", 2),
             ("interleaved_v4", 4)]
    seq_cache = {}                      # N -> (l_ref, g_ref, t_seq)
    for name, v in cases:
        sched = "interleaved" if v > 1 else name
        N = 4 * v * S                   # 4 layers per tick, any v
        wr = np.random.default_rng(1)
        ws = jnp.asarray(wr.normal(size=(N, D, D)) * 0.1, jnp.float32)
        if N not in seq_cache:
            seq = make_seq(N)
            l_ref, g_ref = seq(ws, xs)
            seq_cache[N] = (float(l_ref), np.asarray(g_ref),
                            timed(seq, ws, xs))
        l_ref, g_ref, t_seq = seq_cache[N]
        if v > 1:
            w = ws.reshape(v, S, 4, D, D).transpose(1, 0, 2, 3, 4)
        else:
            w = ws.reshape(S, 4, D, D)
        step = make(sched, v).lower(w, xs).compile()
        loss, grads = step(w, xs)
        np.testing.assert_allclose(float(loss), l_ref, rtol=1e-4)
        g = np.asarray(grads["w"])
        g = (g.transpose(1, 0, 2, 3, 4) if v > 1 else g).reshape(N, D, D)
        # atol covers reduction-order noise on near-zero grad entries
        # (chunked accumulation sums in a different order); it scales
        # with the case's grad magnitude since the deeper models' grads
        # span O(1e2)..O(1e5)
        np.testing.assert_allclose(g, g_ref, rtol=1e-3,
                                   atol=1e-6 * float(np.abs(g_ref).max()))
        ma = step.memory_analysis()
        out[name] = {
            "t_pipe": timed(step, w, xs),
            "t_seq": t_seq,
            "temp_bytes": (None if ma is None
                           else int(ma.temp_size_in_bytes)),
        }
    print(json.dumps(out))
""")


def run_interleaved(timeout: int = 900) -> list[str]:
    """Interleaved vs flat schedules (see INTERLEAVED_SCRIPT)."""
    from repro.dist.pipeline import (pipeline_bubble_fraction,
                                     pipeline_peak_activation_bytes)

    rows = []
    for M, S in INTERLEAVED_POINTS:
        r = subprocess.run(
            [sys.executable, "-c", INTERLEAVED_SCRIPT, str(M), str(S)],
            capture_output=True, text=True, timeout=timeout)
        if r.returncode != 0:
            raise RuntimeError(
                f"interleaved bubble point (M={M}, S={S}) failed:\n"
                f"{r.stderr[-2000:]}")
        t = json.loads(r.stdout.strip().splitlines()[-1])
        measured = {}
        for name, v in (("gpipe", 1), ("1f1b", 1), ("interleaved_v2", 2),
                        ("interleaved_v4", 4)):
            d = t[name]
            sched = "interleaved" if v > 1 else name
            predicted = pipeline_bubble_fraction(M, S, virtual_stages=v)
            measured[name] = max(0.0, 1.0 - d["t_seq"] / d["t_pipe"])
            peak = pipeline_peak_activation_bytes(
                M, S, sched, t["mb_bytes"], virtual_stages=v)
            temp = d["temp_bytes"]
            tag = f"v{v}_" if v > 1 else ""
            rows.append(csv_row(
                f"bubble_interleaved_cmp_{tag}{name.split('_')[0]}"
                f"_m{M}_s{S}", d["t_pipe"] * 1e6,
                f"predicted={predicted:.3f};"
                f"measured={measured[name]:.3f};"
                f"peak_temp_mb="
                f"{'n/a' if temp is None else '%.2f' % (temp / 1e6)};"
                f"peak_act_analytic_mb={peak / 1e6:.2f};"
                f"t_seq_us={d['t_seq'] * 1e6:.0f}"))
        # acceptance criterion: interleaved v=2's measured bubble sits
        # strictly below plain 1f1b's at the same (M, S)
        lower = measured["interleaved_v2"] < measured["1f1b"]
        rows.append(csv_row(
            f"bubble_interleaved_v2_vs_1f1b_m{M}_s{S}", 0.0,
            f"f1b_measured={measured['1f1b']:.3f};"
            f"v2_measured={measured['interleaved_v2']:.3f};"
            f"verdict={'LOWER' if lower else 'NOT-LOWER'}"))
    return rows


# jamba-style heterogeneous point: P=4 positions with mamba-cheap /
# attn+moe-heavy relative costs, R=4 repeats over S=3 stages (4 % 3 != 0)
HET_SCRIPT = textwrap.dedent("""
    import os, json, time
    S, M, R, D = 3, 8, 4, 192
    REPS = [1, 3, 1, 5]        # per-position block cost (matmul count)
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=%d" % S)
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import (balance_stages,
                                     pipeline_apply_microbatched)
    from repro.launch.mesh import make_mesh
    from repro.models.pipeline import stage_stack
    from repro.train.pipeline import choose_partition

    Pn = len(REPS)
    B = 1536
    rng = np.random.default_rng(0)
    ws = [jnp.asarray(rng.normal(size=(R, REPS[p], D, D)) * 0.2,
                      jnp.float32) for p in range(Pn)]
    xs = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)
    mesh = make_mesh((S,), ("stage",))

    def block(w, x):
        for r in range(w.shape[0]):
            x = jnp.tanh(x @ w[r])
        return x

    def make_stage_fn(sizes):
        valid_arr = jnp.asarray(sizes, jnp.int32)
        kmax = max(sizes)

        def stage_fn(local, c):
            valid = valid_arr[jax.lax.axis_index("stage")]

            def step(x, rw):
                r, w = rw
                return jax.lax.cond(
                    r < valid, lambda x, w: block(w, x),
                    lambda x, w: x, x, w), None

            x, _ = jax.lax.scan(
                step, c["x"],
                (jnp.arange(kmax, dtype=jnp.int32), local["w"]))
            return {"x": x}

        return stage_fn

    def make_pipe(pos_sizes):
        stacked = [stage_stack({"w": ws[p]}, S, sizes=pos_sizes[p])
                   for p in range(Pn)]

        def fwd(stacked, xs):
            c = {"x": xs}
            for p in range(Pn):
                fn = make_stage_fn(pos_sizes[p])
                c = shard_map(
                    lambda w, c, _fn=fn: pipeline_apply_microbatched(
                        _fn, w, c, M),
                    mesh=mesh, in_specs=(P("stage"), P()), out_specs=P(),
                    check_vma=False)(stacked[p], c)
            return c["x"]

        return jax.jit(lambda xs: fwd(stacked, xs))

    def seq(xs):
        x = xs
        for p in range(Pn):
            for r in range(R):
                x = block(ws[p][r], x)
        return x

    def timed(f, *a):
        jax.block_until_ready(f(*a))
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            jax.block_until_ready(f(*a))
            ts.append(time.perf_counter() - t0)
        return min(ts)

    costs = [float(r) for r in REPS]
    chosen = choose_partition(costs, R, S)
    uni_rows = balance_stages([sum(costs)] * R, S)
    parts = {
        "uniform": tuple(tuple(uni_rows) for _ in range(Pn)),
        "chosen": chosen.sizes,
    }
    ref = seq(xs)
    seq_j = jax.jit(seq)
    out = {"t_seq": timed(seq_j, xs), "chosen_kind": chosen.kind,
           "M": M, "S": S}
    for name, sizes in parts.items():
        stage_times = [sum(sizes[p][s] * costs[p] for p in range(Pn))
                       for s in range(S)]
        padded = S * sum(max(row) for row in sizes)
        f = make_pipe(sizes)
        got = f(xs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        out[name] = {
            "t_pipe": timed(f, xs),
            "bottleneck": max(stage_times),
            "stage_times": stage_times,
            "padded_fraction": 1.0 - (R * Pn) / padded,
            "sizes": [list(r) for r in sizes],
        }
    print(json.dumps(out))
""")


def run_heterogeneous(timeout: int = 900) -> list[str]:
    """The jamba-style partition comparison (see module docstring)."""
    from repro.dist.pipeline import pipeline_bubble_fraction

    r = subprocess.run([sys.executable, "-c", HET_SCRIPT],
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(
            f"heterogeneous bubble point failed:\n{r.stderr[-2000:]}")
    t = json.loads(r.stdout.strip().splitlines()[-1])
    M, S = t["M"], t["S"]      # the script's own point, not a duplicate
    rows = []
    for name in ("uniform", "chosen"):
        d = t[name]
        predicted = pipeline_bubble_fraction(
            M, S, stage_times=d["stage_times"])
        measured = max(0.0, 1.0 - t["t_seq"] / d["t_pipe"])
        rows.append(csv_row(
            f"bubble_het_{name}_m{M}_s{S}", d["t_pipe"] * 1e6,
            f"predicted={predicted:.3f};measured={measured:.3f};"
            f"bottleneck={d['bottleneck']:.3g};"
            f"padded_fraction={d['padded_fraction']:.3f};"
            f"sizes={d['sizes']}"))
    ok = t["chosen"]["bottleneck"] <= t["uniform"]["bottleneck"]
    rows.append(csv_row(
        "het_partition_vs_uniform_padded", 0.0,
        f"kind={t['chosen_kind']};"
        f"chosen_bottleneck={t['chosen']['bottleneck']:.3g};"
        f"uniform_bottleneck={t['uniform']['bottleneck']:.3g};"
        f"verdict={'LEQ' if ok else 'WORSE'}"))
    return rows


def run() -> list[str]:
    from repro.dist.pipeline import (pipeline_bubble_fraction,
                                     pipeline_peak_activation_bytes)

    rows = []
    for n_micro, n_stages in POINTS:
        t = measure(n_micro, n_stages)
        predicted = pipeline_bubble_fraction(n_micro, n_stages)
        for sched in ("gpipe", "1f1b"):
            r = t[sched]
            measured = max(0.0, 1.0 - t["t_seq"] / r["t_pipe"])
            peak = pipeline_peak_activation_bytes(
                n_micro, n_stages, sched, t["mb_bytes"])
            temp = r["temp_bytes"]
            rows.append(csv_row(
                f"bubble_{sched}_m{n_micro}_s{n_stages}",
                r["t_pipe"] * 1e6,
                f"predicted={predicted:.3f};measured={measured:.3f};"
                f"peak_temp_mb="
                f"{'n/a' if temp is None else '%.2f' % (temp / 1e6)};"
                f"peak_act_analytic_mb={peak / 1e6:.2f};"
                f"t_seq_us={t['t_seq'] * 1e6:.0f}"))
        g, f = t["gpipe"]["temp_bytes"], t["1f1b"]["temp_bytes"]
        if g is not None and f is not None and n_micro > n_stages:
            verdict = "LOWER" if f < g else "NOT-LOWER"
            rows.append(csv_row(
                f"peakmem_1f1b_vs_gpipe_m{n_micro}_s{n_stages}", 0.0,
                f"gpipe_mb={g / 1e6:.2f};f1b_mb={f / 1e6:.2f};"
                f"verdict={verdict}"))
    rows.extend(run_heterogeneous())
    rows.extend(run_interleaved())
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
