"""Kernel micro-benchmarks: interpret-mode Pallas vs the jnp reference.

Wall-clock on CPU is NOT the metric (interpret mode is a correctness
vehicle); the derived column reports the structural win — HBM bytes the
fusion eliminates per call, from the analytic tensor sizes."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import (flash_attention_ref, fused_mlp_ref)
from repro.models.layers import visible_pairs

from .common import csv_row


def run() -> list[str]:
    rows = []
    # flash attention: intermediate probability traffic eliminated
    for (B, S, Hq, Hkv, D, causal, window) in [
            (8, 4096, 32, 8, 128, True, 0),
            (8, 4096, 32, 8, 128, True, 1024),
            (1, 32768, 32, 8, 128, True, 0)]:
        nq = nk = S // 512
        pairs = len(visible_pairs(nq, nk, causal=causal, window=window,
                                  q_chunk=512, kv_chunk=512))
        probs_bytes = pairs * B * Hq * 512 * 512 * 4     # f32 probs
        dense_pairs = nq * nk
        rows.append(csv_row(
            f"kernel_flash_S{S}_w{window}", 0.0,
            f"visible_pairs={pairs}/{dense_pairs};"
            f"skipped_frac={1-pairs/dense_pairs:.2f};"
            f"hbm_probs_bytes_eliminated={probs_bytes:.3e}"))
    # fused MLP: hidden activation round-trip eliminated
    for (T, d, ff) in [(4096, 4096, 12800), (4096, 2048, 768)]:
        hidden_bytes = T * ff * 2 * 2 * 2    # gate+up, write+read, bf16
        rows.append(csv_row(
            f"kernel_fused_mlp_d{d}_ff{ff}", 0.0,
            f"hbm_hidden_bytes_eliminated={hidden_bytes:.3e}"))
    # block-size autotuner: measured interpret-mode medians for the
    # tuned winner on small smoke cells (relative ordering only on CPU;
    # the same tuner runs with interpret=False on real TPUs).  Winners
    # are persisted to the results/ cache `dispatch.block_config` reads.
    from repro.kernels import tune as ktune
    for kernel, shape in [("fused_rmsnorm", (128, 64)),
                          ("fused_mlp", (128, 64, 192))]:
        entry = ktune.tune(kernel, shape, "float32", repeats=3,
                           max_candidates=8)
        cfgs = ";".join(f"{k}={v}" for k, v in
                        sorted(entry["config"].items()))
        rows.append(csv_row(
            f"kernel_tune_{kernel}_{'x'.join(map(str, shape))}",
            entry["us"],
            f"{cfgs};n_candidates={entry['n_candidates']}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
