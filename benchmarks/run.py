"""Benchmark orchestrator — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

Sections import lazily: the MKPipe-core benches (fig14/fig16/fig17,
table2, kernels) must run even when a model-layer import is broken, so a
failed section import is reported as a SKIP line rather than taking the
whole run down.  Only failures *inside* a successfully imported section
count toward the exit code.
"""
from __future__ import annotations

import importlib
import sys
import traceback

SECTIONS = [
    ("fig14 (per-workload speedup)", "fig14_speedup"),
    ("table2 (resources/ERU)", "table2_resources"),
    ("fig16 (CFD case study)", "fig16_cfd"),
    ("fig17/§7.3.2 (BP splitting)", "fig17_bp_splitting"),
    ("kernels", "kernels_bench"),
    ("pipeline bubble (measured vs model)", "pipeline_bubble"),
    ("roofline (dry-run)", "roofline"),
    ("planner frontier (mkplan)", "planner_bench"),
    ("checkpoint v1 vs v2", "ckpt_bench"),
]


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tolerate-failures", action="store_true",
                    help="skip-tolerant (CI smoke) mode: section failures "
                         "are reported but don't fail the run; only "
                         "nothing-imported does")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0
    imported = 0
    for title, modname in SECTIONS:
        print(f"# --- {title} ---")
        try:
            mod = importlib.import_module(f".{modname}", __package__)
        except Exception as exc:
            print(f"# SKIP {title}: import failed "
                  f"({type(exc).__name__}: {exc})", flush=True)
            continue
        imported += 1
        try:
            for row in mod.run():
                print(row)
        except Exception:
            failures += 1
            traceback.print_exc()
    if args.tolerate_failures:
        sys.exit(0 if imported else 1)
    if failures or not imported:     # all-skip means nothing was measured
        sys.exit(1)


if __name__ == "__main__":
    main()
