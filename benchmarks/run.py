"""Benchmark orchestrator — one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (fig14_speedup, fig16_cfd, fig17_bp_splitting,
                   kernels_bench, roofline, table2_resources)
    sections = [
        ("fig14 (per-workload speedup)", fig14_speedup),
        ("table2 (resources/ERU)", table2_resources),
        ("fig16 (CFD case study)", fig16_cfd),
        ("fig17/§7.3.2 (BP splitting)", fig17_bp_splitting),
        ("kernels", kernels_bench),
        ("roofline (dry-run)", roofline),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for title, mod in sections:
        print(f"# --- {title} ---")
        try:
            for row in mod.run():
                print(row)
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
