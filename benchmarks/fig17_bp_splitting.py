"""§7.3.2 analogue: BP program-splitting exploration (Eq. 2) with the
paper's published profile, plus the re-balancing after the split.
Paper result: split K4; 1.43× net gain including reprogram overhead."""
from __future__ import annotations

from repro import workloads
from repro.core import explore_split
from repro.core.eru import eru

from .common import csv_row


def run() -> list[str]:
    graph, _ = workloads.bp.build()
    times = workloads.bp.PAPER_PROFILE
    utils = workloads.bp.PAPER_UTILS
    dec = explore_split(graph, times, utils, pipelines=[("K2", "K3")],
                        t_reprogram=1.4)
    total = sum(times[k] * (graph.loops["train_loop"][1]
                            if k in graph.loops["train_loop"][0] else 1)
                for k in times)
    gain = dec.t_coreside / dec.t_split if dec.split else 1.0
    rows = [
        csv_row("fig17_bp_split_decision", 0.0,
                f"split={dec.split};partition={dec.partition};"
                f"t_coreside={dec.t_coreside:.1f}s;t_split={dec.t_split:.1f}s;"
                f"projected_gain={gain:.2f};paper_gain=1.43"),
    ]
    for c in dec.candidates[:4]:
        rows.append(csv_row(
            "fig17_bp_candidate", 0.0,
            f"a={c['a']};b={c['b']};balance={c['balance']:.2f};"
            f"t_split={c['t_split']:.1f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
