"""mkplan frontier benchmark: the planner prices a whole launch space
fast enough to run before every launch.

For each smoke arch on the 8-device mesh the CI smoke trains use, this
enumerates and scores the full discrete launch space (stages ×
microbatch × schedule × virtual-stages × model-par) with the analytic
cost models — nothing compiles — and reports:

- wall-clock of enumeration + scoring + frontier marking (the
  acceptance criterion pins it under 2 s: cheap enough for a default-on
  ``--verify`` pass);
- the frontier size vs the space size (how much of the space static
  domination prunes);
- a verdict row asserting the jamba frontier contains a ``stages=2
  interleaved v=2`` candidate on the (2, 2, 2) PP×TP mesh — the
  schedule PR 8 built and ``make bench-smoke``'s interleaved cell runs.
  (The planner re-optimizes the microbatch knob, so the row checks the
  mesh + schedule shape, not one fixed argv.)
"""
from __future__ import annotations

import time

from .common import csv_row

DEVICES = 8
GLOBAL_BATCH = 8
SEQ_LEN = 64
WALL_BUDGET_S = 2.0

ARCHS = ("granite-3-8b", "jamba-v0.1-52b")


def run() -> list[str]:
    from repro.analysis.planner import plan_frontier
    from repro.configs import get_smoke

    rows = []
    jamba_hit = None
    for arch in ARCHS:
        cfg = get_smoke(arch)
        t0 = time.perf_counter()
        scored = plan_frontier(cfg, DEVICES, global_batch=GLOBAL_BATCH,
                               seq_len=SEQ_LEN)
        wall = time.perf_counter() - t0
        front = [s for s in scored if s.on_frontier]
        if not scored or not front:
            raise RuntimeError(f"{arch}: empty launch space on "
                               f"{DEVICES} devices")
        if wall > WALL_BUDGET_S:
            raise RuntimeError(
                f"{arch}: enumeration + scoring took {wall:.2f}s "
                f"(> {WALL_BUDGET_S}s budget) for {len(scored)} "
                "candidates")
        best = front[0]
        rows.append(csv_row(
            f"planner_frontier_{arch.split('-')[0]}_d{DEVICES}",
            wall * 1e6,
            f"candidates={len(scored)};frontier={len(front)};"
            f"best={best.candidate.label().replace(' ', '/')};"
            f"best_step_model_us={best.score.step_time_s * 1e6:.3f}"))
        if arch.startswith("jamba"):
            jamba_hit = [
                s for s in front
                if s.candidate.schedule == "interleaved"
                and s.candidate.virtual_stages == 2
                and s.candidate.mesh_shape == (2, 2, 2)]
    # acceptance criterion: the config family PR 8 built (interleaved
    # v=2 on the 2,2,2 PP×TP mesh) survives to the jamba frontier
    if not jamba_hit:
        raise RuntimeError("jamba frontier lost the interleaved v=2 "
                           "(2,2,2)-mesh candidate")
    rows.append(csv_row(
        "planner_jamba_interleaved_v2_on_frontier", 0.0,
        f"hits={len(jamba_hit)};"
        f"first={jamba_hit[0].candidate.label().replace(' ', '/')};"
        "verdict=ON-FRONTIER"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
