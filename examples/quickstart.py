"""Quickstart: the MKPipe compiler pass end-to-end on the CFD workload.

    PYTHONPATH=src python examples/quickstart.py

Builds the 3-kernel CFD stage graph, profiles the naive kernels, runs the
full MKPipe pass (dependency analysis → Fig.5 decision tree → balancing →
splitting), executes both the KBK baseline and the optimized plan, and
verifies they compute identical results.
"""
import numpy as np

from repro import workloads
from repro.core import (ChipSpec, ResourceModel, compile_plan, optimize,
                        profile_graph)


def main() -> None:
    graph, buffers = workloads.cfd.build(n=1 << 16)
    print("stages:", [s.name for s in graph.stages])
    print("edges :", graph.edges())

    graph = profile_graph(graph, buffers)
    for s in graph.stages:
        print(f"  profile {s.name}: {s.profile.time_s*1e3:.2f} ms, "
              f"throughput {s.profile.throughput/1e6:.1f} MB/s")

    compiled, report = optimize(graph, model=ResourceModel(ChipSpec.cpu()))
    print("\ndependency categories:")
    for (p, c, b), cat in report.dep_categories.items():
        print(f"  {p} -> {c} via {b!r}: {cat}")
    print("mechanisms:", {f"{e.producer}->{e.consumer}": e.mechanism
                          for e in report.plan.edges})
    print("concurrency groups:", report.plan.groups)
    print("balancing mode:", report.plan.balancing)
    if report.balance:
        print("N_uni:", report.balance.n_uni())
    print(f"modeled speedup vs KBK: {report.modeled_speedup:.2f}x")
    if report.split:
        print(f"program splitting: split={report.split.split}")

    out_opt = compiled(buffers)
    out_kbk = compile_plan(report.plan, mode="kbk")(buffers)
    ref = graph.run_reference(buffers)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out_opt[k]),
                                   np.asarray(ref[k]), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out_kbk[k]),
                                   np.asarray(ref[k]), rtol=1e-5, atol=1e-5)
    print("\nnumerics: optimized == KBK == reference  ✓")


if __name__ == "__main__":
    main()
