"""End-to-end training driver example: train a reduced granite-3-8b for a
few hundred steps on CPU with the full substrate (sharded params, AdamW,
remat, async checkpointing, restart, straggler monitor).

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

This is the (b) end-to-end driver: ~1M-param LM, real tokens, loss curve
printed; re-running resumes from the checkpoint directory.
"""
import argparse
import logging

from repro.launch.train import build
from repro.runtime import FTConfig, TrainDriver


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="granite-3-8b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg, mesh, state, step_fn, data = build(
        args.arch, smoke=True, global_batch=8, seq_len=128, lr=3e-3)
    print(f"arch={cfg.name}  params={cfg.n_params()/1e6:.2f}M  "
          f"mesh={dict(mesh.shape)}")

    driver = TrainDriver.resume_or_init(
        step_fn, data, FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=50),
        state)
    driver.run(args.steps)

    losses = [m["loss"] for m in driver.metrics_log]
    stride = max(len(losses) // 10, 1)
    for i in range(0, len(losses), stride):
        print(f"  step {driver.metrics_log[i]['step']:4d}  "
              f"loss {losses[i]:.4f}")
    print(f"final loss: {losses[-1]:.4f} (started {losses[0]:.4f})")
    if driver.monitor.events:
        print(f"stragglers detected: {driver.monitor.events}")


if __name__ == "__main__":
    main()
