"""Workgroup-id remapping demo (paper §5.4.4 / Fig. 11) on blocked LUD.

Shows the dependency wavefront, the constructed id_queue, and the modeled
pipeline makespans with and without remapping; then executes the chunked
NaN-poisoned plan to prove the queue is dependency-legal.

    PYTHONPATH=src python examples/lud_remapping.py
"""
import numpy as np

from repro import workloads
from repro.core import analyze_graph, build_id_queue, compile_plan, \
    plan_cke, profile_graph, validate_queue
from repro.core.depanalysis import merge_deps
from repro.core.idremap import RemapPlan, pipeline_makespan


def main() -> None:
    nb = 8
    graph, buffers = workloads.lud.build(nb=nb)
    infos = analyze_graph(graph)
    merged = merge_deps(list(infos.values()))
    print(f"dependency: fan-in={merged.max_fan_in} "
          f"fan-out={merged.max_fan_out} → {merged.category}")

    q = build_id_queue(merged)
    assert validate_queue(merged, q)
    print("\nid_queue (consumer (i,j) in execution order):")
    coords = [(c // nb, c % nb) for c in q.queue]
    for row in range(0, len(coords), nb):
        print("  ", coords[row:row + nb])

    natural = RemapPlan(
        queue=tuple(range(merged.n_consumer_tiles)),
        ready_after=tuple(max(merged.deps[c], default=-1) + 1
                          for c in range(merged.n_consumer_tiles)))
    for rate in (0.5, 1.0, 2.0):
        t_nat = pipeline_makespan(merged, natural, producer_rate=rate)
        t_rem = pipeline_makespan(merged, q, producer_rate=rate)
        print(f"producer_rate={rate}: natural={t_nat:.1f} "
              f"remapped={t_rem:.1f} ({t_nat/t_rem:.2f}x)")

    graph = profile_graph(graph, buffers)
    plan = plan_cke(graph)
    out = compile_plan(plan)(buffers)
    ref = graph.run_reference(buffers)
    np.testing.assert_allclose(np.asarray(out["out"]),
                               np.asarray(ref["out"]), rtol=1e-5, atol=1e-5)
    print("\nchunked execution in queue order matches reference ✓ "
          "(NaN-poisoned buffers prove legality)")


if __name__ == "__main__":
    main()
