"""Batched serving example: prefill + KV-cache decode on a reduced config,
with the Eq. 2 program-splitting decision for prefill vs decode programs.

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-moe-30b-a3b
"""
import argparse
import logging

from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-moe-30b-a3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=24)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    gen, stats = serve(args.arch, batch=args.batch,
                       prompt_len=args.prompt_len, gen_len=args.gen_len,
                       smoke=True)
    print(f"generated {gen.shape[1]} tokens for {gen.shape[0]} requests")
    print(f"decode throughput: {stats['tok_per_s']:.1f} tok/s")
    print(f"Eq.2 choice: {'split' if stats['split'] else 'merged'} "
          "prefill/decode programs")


if __name__ == "__main__":
    main()
