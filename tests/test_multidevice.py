"""Multi-device integration: the sharded train step on 8 fake host devices
(subprocess — the device count must be set before jax initializes)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding
    from repro.configs import get_smoke
    from repro.dist.context import sharding_context
    from repro.dist.sharding import batch_spec, param_specs, with_shardings
    from repro.launch.mesh import make_mesh
    from repro.models.common import tp_align
    from repro.models.transformer import init_params
    from repro.train.optimizer import adamw_init
    from repro.train.step import make_train_step

    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = tp_align(get_smoke("qwen3-moe-30b-a3b"), tp=2)
    params = init_params(cfg, jax.random.key(0))
    pspecs = param_specs(params)
    params = with_shardings(params, pspecs, mesh)
    opt = adamw_init(params)
    step = make_train_step(cfg, remat=True)

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)),
                              jnp.int32),
    }
    with mesh, sharding_context(mesh):
        bspec = batch_spec(mesh, 8)
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspec))
                 for k, v in batch.items()}
        jitted = jax.jit(step)
        losses = []
        for _ in range(4):
            params, opt, metrics = jitted(params, opt, batch)
            losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses
    # verify params really are sharded across the 8 devices
    leaf = params["layers"][0]["mixer"]["wq"]
    assert len(leaf.sharding.device_set) == 8
    print("OK", losses[0], "->", losses[-1])
""")


def test_sharded_train_step_8_devices():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
    assert "OK" in r.stdout


# ZeRO-1 numerics: sharding the optimizer moments over the data axis is a
# layout decision, not a numerics one — moments and params after several
# steps must match the replicated-moment run, while the moment arrays
# really live scattered over the 8 devices.
ZERO1_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding
    from repro.configs import get_smoke
    from repro.dist.context import sharding_context
    from repro.dist.sharding import batch_spec, param_specs, with_shardings
    from repro.launch.mesh import make_mesh
    from repro.models.common import tp_align
    from repro.models.transformer import init_params
    from repro.train.optimizer import adamw_init
    from repro.train.step import make_train_step, zero1_specs

    mesh = make_mesh((8, 1), ("data", "model"))
    cfg = tp_align(get_smoke("granite-3-8b"), tp=1)   # vocab pads to 640
    params0 = init_params(cfg, jax.random.key(0))
    pspecs = param_specs(params0)

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (16, 64)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (16, 64)),
                              jnp.int32),
    }

    def run(zero1):
        params = with_shardings(params0, pspecs, mesh)
        opt = adamw_init(params)
        z1 = None
        if zero1:
            zs = zero1_specs(pspecs, params, mesh)
            z1 = jax.tree.map(lambda s: NamedSharding(mesh, s), zs)
        step = make_train_step(cfg, remat=True, zero1_constraints=z1)
        with mesh, sharding_context(mesh):
            b = {k: jax.device_put(v, NamedSharding(
                     mesh, batch_spec(mesh, 16)))
                 for k, v in batch.items()}
            jitted = jax.jit(step)
            for _ in range(3):
                params, opt, _ = jitted(params, opt, b)
        return params, opt

    p_rep, o_rep = run(False)
    p_z1, o_z1 = run(True)
    # moments are f32 accumulations of bf16 grads; resharding changes the
    # reduction order, so allow reduction-order-level noise
    for key in ("m", "v"):
        for a, b in zip(jax.tree.leaves(o_rep[key]),
                        jax.tree.leaves(o_z1[key])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-3, atol=5e-5)
    for a, b in zip(jax.tree.leaves(p_rep), jax.tree.leaves(p_z1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)
    # the constrained moments are genuinely scattered, not replicated
    m_embed = o_z1["m"]["embed"]
    assert not m_embed.sharding.is_fully_replicated, m_embed.sharding
    assert "data" in str(m_embed.sharding.spec), m_embed.sharding.spec
    assert len(m_embed.sharding.device_set) == 8
    print("ZERO1 OK")
""")


def test_zero1_moments_match_replicated():
    r = subprocess.run([sys.executable, "-c", ZERO1_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
    assert "ZERO1 OK" in r.stdout


# int8 error-feedback gradient reduction: forward numerics are untouched
# (step-0 loss identical), trajectories track fp32 closely, and the
# per-replica residual state is carried and data-sharded.
INT8_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    from repro.launch.train import build

    def run(flags=()):
        cfg, mesh, state, step, data = build(
            "granite-3-8b", smoke=True, global_batch=8, seq_len=64,
            seed=0, flags=flags)
        losses = []
        for i in range(4):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses, state

    lf, _ = run()
    li, si = run(("grad_int8",))
    assert abs(lf[0] - li[0]) < 1e-5, (lf[0], li[0])
    assert all(np.isfinite(li)), li
    assert abs(lf[-1] - li[-1]) / abs(lf[-1]) < 0.05, (lf, li)
    err = si[1]["err"]
    mx = max(float(np.max(np.abs(np.asarray(l))))
             for l in jax.tree.leaves(err))
    assert mx > 0.0                       # residual actually carried
    leaf = jax.tree.leaves(err)[0]
    assert "data" in str(leaf.sharding.spec), leaf.sharding.spec
    print("INT8 OK")
""")


def test_grad_int8_tracks_fp32():
    r = subprocess.run([sys.executable, "-c", INT8_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
    assert "INT8 OK" in r.stdout


# --kernels pallas on the hybrid jamba stack under PP×TP islands: the
# SSD kernel sees tp-local d_inner heads, the MoE gmm sees tp-local
# expert slices, and the gated d_inner norm must stay on the psum'd
# `_tp_rmsnorm` (the kernel rmsnorm is single-shard only).  Loss
# trajectory vs the plain-jnp run on the SAME mesh.
JAMBA_KERNELS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.launch.train import build

    def run(flags):
        cfg, mesh, state, step, data = build(
            "jamba-v0.1-52b", smoke=True, global_batch=4, seq_len=32,
            stages=2, microbatch=2, schedule="gpipe",
            mesh_shape=(2, 1, 2), axes=("stage", "data", "model"),
            seed=0, flags=flags)
        losses = []
        for i in range(2):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    base = run(())
    lk = run(("kernels_pallas",))
    diffs = [abs(a - b) / max(abs(a), 1e-9) for a, b in zip(base, lk)]
    assert all(d < 2e-2 for d in diffs), (base, lk, diffs)
    print("JAMBA KERNELS OK", base, lk)
""")


def test_jamba_kernels_pallas_matches_jnp():
    """Hybrid mamba+moe+attention stack with `--kernels pallas` inside
    (stage=2, model=2) islands tracks the jnp baseline."""
    r = subprocess.run([sys.executable, "-c", JAMBA_KERNELS_SCRIPT],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "JAMBA KERNELS OK" in r.stdout


# interleaved virtual stages on the full 8-device (stage=2, data=2,
# model=2) mesh: `--schedule interleaved --virtual-stages 2` splits
# jamba's 4 repeats into 4 virtual stages (2 chunks per device) and must
# track the plain-1f1b jnp run on the SAME mesh; the Pallas kernel path
# composes on top (kernel dispatch is per-island, schedule-agnostic).
INTERLEAVED_PPTP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.launch.train import build

    def run(schedule, virtual_stages=1, flags=()):
        cfg, mesh, state, step, data = build(
            "jamba-v0.1-52b", smoke=True, global_batch=8, seq_len=32,
            stages=2, microbatch=2, schedule=schedule,
            virtual_stages=virtual_stages,
            mesh_shape=(2, 2, 2), axes=("stage", "data", "model"),
            seed=0, flags=flags)
        losses = []
        for i in range(2):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    base = run("1f1b")
    li = run("interleaved", virtual_stages=2)
    lk = run("interleaved", virtual_stages=2, flags=("kernels_pallas",))
    for name, lp in (("interleaved", li), ("interleaved+pallas", lk)):
        diffs = [abs(a - b) / max(abs(a), 1e-9) for a, b in zip(base, lp)]
        assert all(d < 2e-2 for d in diffs), (name, base, lp, diffs)
    print("INTERLEAVED PPTP OK", base, li, lk)
""")


def test_interleaved_pptp_tracks_1f1b_and_composes_with_kernels():
    """`--schedule interleaved --virtual-stages 2` on the 2x2x2 pp x tp
    mesh tracks the 1f1b jnp baseline and composes with
    `--kernels pallas`."""
    r = subprocess.run([sys.executable, "-c", INTERLEAVED_PPTP_SCRIPT],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "INTERLEAVED PPTP OK" in r.stdout
