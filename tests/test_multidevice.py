"""Multi-device integration: the sharded train step on 8 fake host devices
(subprocess — the device count must be set before jax initializes)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding
    from repro.configs import get_smoke
    from repro.dist.context import sharding_context
    from repro.dist.sharding import batch_spec, param_specs, with_shardings
    from repro.launch.mesh import make_mesh
    from repro.models.common import tp_align
    from repro.models.transformer import init_params
    from repro.train.optimizer import adamw_init
    from repro.train.step import make_train_step

    mesh = make_mesh((4, 2), ("data", "model"))
    cfg = tp_align(get_smoke("qwen3-moe-30b-a3b"), tp=2)
    params = init_params(cfg, jax.random.key(0))
    pspecs = param_specs(params)
    params = with_shardings(params, pspecs, mesh)
    opt = adamw_init(params)
    step = make_train_step(cfg, remat=True)

    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)),
                              jnp.int32),
    }
    with mesh, sharding_context(mesh):
        bspec = batch_spec(mesh, 8)
        batch = {k: jax.device_put(v, NamedSharding(mesh, bspec))
                 for k, v in batch.items()}
        jitted = jax.jit(step)
        losses = []
        for _ in range(4):
            params, opt, metrics = jitted(params, opt, batch)
            losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses
    # verify params really are sharded across the 8 devices
    leaf = params["layers"][0]["mixer"]["wq"]
    assert len(leaf.sharding.device_set) == 8
    print("OK", losses[0], "->", losses[-1])
""")


def test_sharded_train_step_8_devices():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2000:]}"
    assert "OK" in r.stdout
