"""Sharded checkpoint format v2: mesh-agnostic save/restore.

The tentpole contract, pinned here:
  - save never host-gathers: every sharded leaf publishes per-shard
    files (one per unique shard index), replicated leaves exactly one;
  - restore reassembles the global arrays onto a *different* mesh —
    fewer stages, more data shards, or a single device — with numerics
    bit-identical to the saved state;
  - emergency saves never clobber a periodic checkpoint at the same
    step, and GC never collects the newest emergency;
  - corruption (flipped shard bytes, truncated manifest) is rejected
    with MK-R001 before any state is adopted;
  - async manager errors surface on the next wait()/save().

Cross-mesh tests run in subprocesses (the fake device count must be set
before jax initializes).
"""
import json
import pathlib
import subprocess
import sys
import textwrap
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.analysis import DiagnosticError
from repro.ckpt import (CheckpointManager, checkpoint_path, latest_step,
                        load_checkpoint, read_manifest, save_checkpoint,
                        save_checkpoint_v1, snapshot_nbytes,
                        snapshot_tree, spec_from_json)
from repro.ckpt.checkpoint import _norm_index, _spec_to_json
from repro.runtime import corrupt_shard, truncate_manifest


def small_tree():
    return {"w": jnp.arange(24.0).reshape(4, 6),
            "b16": jnp.ones((4, 2), jnp.bfloat16) * 0.5,
            "opt": {"count": jnp.zeros((), jnp.int32), "pyint": 3}}


# ------------------------------------------------------------ v2 basics

def test_v2_roundtrip_mixed_dtypes(tmp_path):
    tree = small_tree()
    save_checkpoint(tmp_path, 5, tree, extra={"note": "x"})
    man = read_manifest(tmp_path, 5)
    assert man["version"] == 2 and man["tag"] == "periodic"
    assert man["extra"] == {"note": "x"}
    out = load_checkpoint(tmp_path, 5, tree)
    assert np.array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert out["b16"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(out["b16"], np.float32),
                          np.asarray(tree["b16"], np.float32))
    assert int(out["opt"]["count"]) == 0 and int(out["opt"]["pyint"]) == 3


def test_v2_layout_is_per_shard_files(tmp_path):
    save_checkpoint(tmp_path, 1, small_tree())
    d = checkpoint_path(tmp_path, 1)
    assert (d / "manifest.json").exists()
    shard_files = sorted(p.name for p in (d / "shards").iterdir())
    assert shard_files and all(f.endswith(".npy") for f in shard_files)
    assert not (d / "arrays.npz").exists()   # the v1 host-gather blob
    man = json.loads((d / "manifest.json").read_text())
    for rec in man["leaves"]:
        for sh in rec["shards"]:
            assert {"file", "index", "nbytes", "crc32"} <= set(sh)


def test_v1_migration_read_path(tmp_path):
    tree = {"w": jnp.arange(6.0), "n": jnp.ones((2, 2))}
    save_checkpoint_v1(tmp_path, 3, tree)
    man = read_manifest(tmp_path, 3)
    assert "keys" in man and man.get("version", 1) == 1
    out = load_checkpoint(tmp_path, 3, tree)
    assert np.array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_snapshot_nbytes_counts_unique_shards():
    tree = {"w": jnp.ones((8, 4), jnp.float32)}
    snaps = snapshot_tree(tree)
    assert snapshot_nbytes(snaps) == 8 * 4 * 4


# ------------------------------------------------- emergency tag + GC

def test_emergency_save_does_not_clobber_periodic(tmp_path):
    tree = small_tree()
    save_checkpoint(tmp_path, 7, tree)
    bumped = dict(tree, w=tree["w"] + 1)
    save_checkpoint(tmp_path, 7, bumped, tag="emergency")
    names = sorted(p.name for p in tmp_path.glob("step_*"))
    assert names == ["step_00000007", "step_00000007_emergency"]
    # checkpoint_path prefers the canonical periodic publish
    out = load_checkpoint(tmp_path, 7, tree)
    assert np.array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
    assert read_manifest(tmp_path, 7)["tag"] == "periodic"


def test_gc_never_collects_newest_emergency(tmp_path):
    tree = small_tree()
    m = CheckpointManager(tmp_path, keep=2)
    m.save(5, tree, blocking=True, tag="emergency")
    for s in (10, 20, 30, 40):
        m.save(s, tree, blocking=True)
    names = sorted(p.name for p in tmp_path.glob("step_*"))
    # keep=2 periodic → [30, 40]; the newest (only) emergency survives
    assert names == ["step_00000005_emergency", "step_00000030",
                     "step_00000040"]


def test_async_manager_error_surfaces_on_wait(tmp_path):
    m = CheckpointManager(tmp_path / "sub", keep=1)
    m.save(1, {"w": jnp.ones(3)})
    # sabotage the directory: make the target path a file so the
    # background writer's rename fails
    m.wait()
    (tmp_path / "sub" / "step_00000002").write_text("in the way")
    m.save(2, {"w": jnp.ones(3)})
    with pytest.raises(OSError):
        m.wait()
    # the error is consumed — the manager is usable again
    m.save(3, {"w": jnp.ones(3)}, blocking=True)
    assert latest_step(tmp_path / "sub") == 3


# ------------------------------------------------- corruption rejection

def test_corrupt_shard_rejected_with_mkr001(tmp_path):
    tree = small_tree()
    save_checkpoint(tmp_path, 2, tree)
    corrupt_shard(tmp_path, step=2)
    with pytest.raises(DiagnosticError) as ei:
        load_checkpoint(tmp_path, 2, tree)
    assert "MK-R001" in str(ei.value)


def test_truncated_manifest_rejected_with_mkr001(tmp_path):
    tree = small_tree()
    save_checkpoint(tmp_path, 2, tree)
    truncate_manifest(tmp_path, step=2, keep_bytes=40)
    with pytest.raises(ValueError) as ei:     # DiagnosticError is one
        load_checkpoint(tmp_path, 2, tree)
    assert "MK-R001" in str(ei.value)


def test_missing_shard_file_rejected(tmp_path):
    tree = small_tree()
    save_checkpoint(tmp_path, 2, tree)
    d = checkpoint_path(tmp_path, 2)
    victim = sorted((d / "shards").iterdir())[0]
    victim.unlink()
    with pytest.raises(DiagnosticError) as ei:
        load_checkpoint(tmp_path, 2, tree)
    assert "MK-R001" in str(ei.value)


def test_tree_mismatch_rejected_before_reading_shards(tmp_path):
    tree = small_tree()
    save_checkpoint(tmp_path, 2, tree)
    wrong = dict(tree, extra_leaf=jnp.zeros(2))
    with pytest.raises(DiagnosticError) as ei:
        load_checkpoint(tmp_path, 2, wrong)
    assert "MK-R001" in str(ei.value)
    wrong_shape = dict(tree, w=jnp.zeros((2, 6)))
    with pytest.raises(DiagnosticError) as ei:
        load_checkpoint(tmp_path, 2, wrong_shape)
    assert "MK-R001" in str(ei.value)


# --------------------------------------------------- property: helpers

@given(entries=st.lists(
    st.one_of(st.none(), st.sampled_from(["stage", "data", "model"]),
              st.lists(st.sampled_from(["data", "model"]), min_size=1,
                       max_size=2, unique=True)),
    max_size=4))
@settings(max_examples=50, deadline=None)
def test_spec_json_roundtrip(entries):
    from jax.sharding import PartitionSpec
    spec = PartitionSpec(*[tuple(e) if isinstance(e, list) else e
                           for e in entries])
    assert spec_from_json(_spec_to_json(spec)) == spec


@given(dims=st.lists(st.integers(min_value=1, max_value=8), min_size=1,
                     max_size=3))
@settings(max_examples=50, deadline=None)
def test_norm_index_full_slice_covers_shape(dims):
    shape = tuple(dims)
    idx = _norm_index(tuple(slice(None) for _ in shape), shape)
    assert idx == tuple((0, d) for d in shape)


# --------------------------------------------- cross-mesh (subprocess)

CROSS_MESH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, pathlib
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.ckpt import (checkpoint_path, load_checkpoint,
                            read_manifest, save_checkpoint)
    from repro.launch.mesh import make_mesh

    out = pathlib.Path({out!r})
    mesh = make_mesh((2, 2, 2), ("stage", "data", "model"))
    tree = {{
        "layers": jax.device_put(
            jnp.arange(4 * 8 * 6.0).reshape(4, 8, 6),
            NamedSharding(mesh, P("stage", None, "model"))),
        "emb": jax.device_put(jnp.arange(16.0).reshape(4, 4),
                              NamedSharding(mesh, P(None, "model"))),
        "scalar": jnp.float32(7.0),
    }}
    save_checkpoint(out, 11, tree)

    # --- acceptance: per-shard layout, no host-gather blob -----------
    man = json.loads(
        (checkpoint_path(out, 11) / "manifest.json").read_text())
    recs = {{r["key"]: r for r in man["leaves"]}}
    # stage×model-sharded leaf → 4 unique shards (2 stage × 2 model);
    # each shard file holds 1/4 of the leaf, never the global array
    assert len(recs["layers"]["shards"]) == 4, recs["layers"]
    assert all(s["nbytes"] == 4 * 8 * 6 * 4 // 4
               for s in recs["layers"]["shards"])
    assert len(recs["emb"]["shards"]) == 2
    assert len(recs["scalar"]["shards"]) == 1
    assert recs["layers"]["mesh"]["axes"] == ["stage", "data", "model"]
    assert recs["layers"]["spec"] == ["stage", None, "model"]

    ref = {{k: np.asarray(v) for k, v in tree.items()}}

    # --- restore onto (2, 2) — no stage axis at all ------------------
    m2 = make_mesh((2, 2), ("data", "model"))
    sh2 = {{"layers": NamedSharding(m2, P(None, None, "model")),
           "emb": NamedSharding(m2, P(None, "model")),
           "scalar": NamedSharding(m2, P())}}
    r2 = load_checkpoint(out, 11, tree, sh2)
    for k in ref:
        assert np.array_equal(np.asarray(r2[k]), ref[k]), k
    assert len(r2["layers"].sharding.device_set) == 4

    # --- restore onto (4, 2) — different factorization ---------------
    m3 = make_mesh((4, 2), ("stage", "data"))
    sh3 = {{"layers": NamedSharding(m3, P("stage", None, None)),
           "emb": NamedSharding(m3, P()),
           "scalar": NamedSharding(m3, P())}}
    r3 = load_checkpoint(out, 11, tree, sh3)
    for k in ref:
        assert np.array_equal(np.asarray(r3[k]), ref[k]), k
    # the stage-sharded leaf really re-sharded 4 ways
    uniq = {{tuple((sl.start, sl.stop) for sl in s.index)
            for s in r3["layers"].addressable_shards}}
    assert len(uniq) == 4, uniq

    # --- restore onto a single device --------------------------------
    r1 = load_checkpoint(out, 11, tree)
    for k in ref:
        assert np.array_equal(np.asarray(r1[k]), ref[k]), k
    print("OK")
""")


def test_cross_mesh_roundtrips_8_devices(tmp_path):
    script = CROSS_MESH.format(out=str(tmp_path / "ck"))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK" in r.stdout
