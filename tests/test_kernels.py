"""Kernel parity suite.

Three layers of agreement, per kernel, forward AND gradient:

1. kernel vs oracle — the raw `pallas_call` (interpret mode) against the
   pure-jnp reference shipped next to it, sweeping shapes and dtypes
   (the seed tests, kept).
2. dispatch vs jnp layer path — `repro.kernels.dispatch` in both
   ``mode="ref"`` and ``mode="pallas"`` against the exact math the
   layers compute with kernels off, including gradients through the
   `custom_vjp` wrappers, float32 and bfloat16, and shapes that do NOT
   divide the default block sizes (the `_divisor` clamp path).
3. model level — `loss_fn` + grads on a 1-repeat granite smoke config
   under ``--kernels off/ref/pallas`` contexts agree.

Plus the SSD regression test: `layers._ssd_chunked` was deleted in
favour of `repro.kernels.ssd_chunk.ssd_chunked`; the old formula is
inlined here verbatim and pins the new path to the old numerics.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_attention, flash_attention_ref, fused_mlp,
                           fused_mlp_ref, fused_rmsnorm, fused_rmsnorm_ref,
                           moe_gmm, moe_gmm_ref, ssd_chunk, ssd_chunk_ref,
                           ssd_chunked)
from repro.kernels import dispatch
from repro.models import layers as L

RNG = np.random.default_rng(0)


def _tol(dtype, grad=False):
    """Shared tolerances: interpret-mode kernels reassociate reductions,
    and ref-VJP backwards recompute in f32 — grads get ~10x headroom."""
    if dtype == jnp.bfloat16:
        return dict(rtol=0.08, atol=0.08) if grad else \
            dict(rtol=0.05, atol=0.05)
    return dict(rtol=2e-3, atol=2e-3) if grad else \
        dict(rtol=2e-4, atol=2e-4)


def _close(a, b, dtype, grad=False, what=""):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32),
        err_msg=what, **_tol(dtype, grad))


def _value_and_grads(fn, *args):
    """(scalar value, grads wrt every arg) for an arbitrary-output fn —
    the loss is sum(out²) over all output leaves, so every element's
    cotangent is shape-dependent (catches transposed-block bugs a
    sum(out) cotangent of ones would miss)."""
    def scalar(*a):
        leaves = jax.tree_util.tree_leaves(fn(*a))
        return sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves)
    return jax.value_and_grad(scalar, argnums=tuple(range(len(args))))(*args)


def _parity(jnp_fn, modal_fn, args, dtype, what):
    """Assert fwd + grad agreement of `modal_fn(mode)` for both dispatch
    modes against the jnp layer-path `jnp_fn`."""
    v0, g0 = _value_and_grads(jnp_fn, *args)
    for mode in dispatch.MODES:
        v, g = _value_and_grads(modal_fn(mode), *args)
        _close(v, v0, dtype, what=f"{what} value mode={mode}")
        for i, (gm, gr) in enumerate(zip(g, g0)):
            _close(gm, gr, dtype, grad=True,
                   what=f"{what} grad[{i}] mode={mode}")


# ===================================================== 1. kernel vs oracle
FLASH_CASES = [
    # (B, S, Hq, Hkv, D, causal, window, q_blk, kv_blk)
    (1, 128, 2, 2, 64, True, 0, 64, 64),
    (2, 256, 4, 2, 64, True, 0, 64, 64),
    (2, 256, 8, 2, 128, True, 0, 128, 64),
    (1, 256, 4, 4, 64, False, 0, 64, 64),
    (2, 256, 4, 2, 64, True, 96, 64, 64),
    (1, 512, 2, 1, 64, True, 128, 128, 128),
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,window,qb,kb", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, Hq, Hkv, D, causal, window, qb, kb, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_blk=qb, kv_blk=kb)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    _close(out, ref, dtype)


def test_flash_attention_skips_blocks():
    """The kernel grid must be exactly the visible-pair count."""
    from repro.kernels.flash_attention.kernel import build_pair_tables
    pi, pj, _, _ = build_pair_tables(8, 8, causal=True, window=0,
                                     q_blk=64, kv_blk=64, kv_offset=0)
    assert len(pi) == 8 * 9 // 2          # triangle, not 64
    pi, _, _, _ = build_pair_tables(8, 8, causal=True, window=128,
                                    q_blk=64, kv_blk=64, kv_offset=0)
    assert len(pi) <= 8 * 3               # window: ≤3 blocks per row


@pytest.mark.parametrize("T,d,ff,act,gated", [
    (128, 128, 512, "silu", True),
    (256, 256, 512, "relu2", False),
    (128, 128, 1024, "gelu", False),
    (512, 64, 256, "silu", True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_mlp(T, d, ff, act, gated, dtype):
    x = jnp.asarray(RNG.normal(size=(T, d)) * 0.3, dtype)
    wu = jnp.asarray(RNG.normal(size=(d, ff)) * 0.05, dtype)
    wd = jnp.asarray(RNG.normal(size=(ff, d)) * 0.05, dtype)
    wg = jnp.asarray(RNG.normal(size=(d, ff)) * 0.05, dtype) if gated \
        else None
    out = fused_mlp(x, wu, wd, wg, act=act, bm=64, bff=256)
    ref = fused_mlp_ref(x, wu, wd, wg, act=act)
    _close(out, ref, dtype)


@pytest.mark.parametrize("E,C,d,f", [(4, 128, 128, 256), (8, 256, 64, 128),
                                     (2, 128, 256, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(E, C, d, f, dtype):
    buf = jnp.asarray(RNG.normal(size=(E, C, d)) * 0.3, dtype)
    w = jnp.asarray(RNG.normal(size=(E, d, f)) * 0.05, dtype)
    out = moe_gmm(buf, w, bc=64, bf=128, bd=64)
    ref = moe_gmm_ref(buf, w)
    _close(out, ref, dtype)


@pytest.mark.parametrize("BC,H,Q,P,N", [(2, 2, 64, 32, 16),
                                        (4, 4, 128, 64, 32),
                                        (1, 8, 256, 64, 128)])
def test_ssd_chunk(BC, H, Q, P, N):
    xh = jnp.asarray(RNG.normal(size=(BC, H, Q, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(BC, H, 1, Q)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(H,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(BC, Q, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(BC, Q, N)), jnp.float32)
    y, s = ssd_chunk(xh, dt, A, bm, cm)
    y_ref, s_ref = ssd_chunk_ref(xh, dt, A, bm, cm)
    _close(y, y_ref, jnp.float32)
    _close(s, s_ref, jnp.float32)


@pytest.mark.parametrize("T,d", [(256, 128), (512, 512), (128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm(T, d, dtype):
    x = jnp.asarray(RNG.normal(size=(T, d)), dtype)
    s = jnp.asarray(RNG.normal(size=(d,)) * 0.1 + 1.0, dtype)
    out = fused_rmsnorm(x, s, bm=64)
    ref = fused_rmsnorm_ref(x, s)
    _close(out, ref, dtype)


# ============================================ 2. dispatch vs jnp layer path
# shapes deliberately include dims that do NOT divide the kernels'
# default blocks (flash 256/256, mlp 128/512, rmsnorm 256, gmm
# 128/256/256) — the dispatch `_divisor` clamp must land on a legal
# non-default block, not trip the kernels' divisibility asserts
@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,window", [
    (1, 128, 2, 2, 16, True, 0),
    (2, 96, 4, 2, 16, True, 0),       # 96 ∤ 256 → one 96-row block
    (1, 320, 2, 1, 16, True, 64),     # 320 ∤ 256 → 160-blocks, window
    (1, 96, 2, 2, 16, False, 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dispatch_parity(B, S, Hq, Hkv, D, causal, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)

    def jnp_path(q, k, v):     # the layers' XLA flash path (kernels off)
        return L.chunked_attention(q, k, v, causal=causal, window=window,
                                   q_chunk=64, kv_chunk=64)

    _parity(jnp_path,
            lambda mode: (lambda q, k, v: dispatch.flash_mha(
                q, k, v, causal=causal, window=window, mode=mode)),
            (q, k, v), dtype, f"flash S={S}")


@pytest.mark.parametrize("T,d,ff,act,gated", [
    (64, 32, 96, "silu", True),       # 96 ∤ 512
    (96, 32, 64, "gelu", False),      # 96 ∤ 128
    (136, 32, 80, "relu2", True),     # 136 → bm=68, 80 → bff=80
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlp_dispatch_parity(T, d, ff, act, gated, dtype):
    x = jnp.asarray(RNG.normal(size=(T, d)) * 0.3, dtype)
    wu = jnp.asarray(RNG.normal(size=(d, ff)) * 0.05, dtype)
    wd = jnp.asarray(RNG.normal(size=(ff, d)) * 0.05, dtype)
    wg = (jnp.asarray(RNG.normal(size=(d, ff)) * 0.05, dtype)
          if gated else None)

    def jnp_path(x, wu, wd):   # the mlp_block math with kernels off
        h = x @ wu
        h = (L.activation(x @ wg, act) * h) if gated else \
            L.activation(h, act)
        return (h.astype(jnp.float32) @ wd.astype(jnp.float32)
                ).astype(x.dtype)

    _parity(jnp_path,
            lambda mode: (lambda x, wu, wd: dispatch.mlp(
                x, wu, wd, wg, act=act, mode=mode).astype(x.dtype)),
            (x, wu, wd), dtype, f"mlp T={T} ff={ff}")


@pytest.mark.parametrize("T,d", [(96, 48), (384, 64), (130, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_dispatch_parity(T, d, dtype):
    x = jnp.asarray(RNG.normal(size=(T, d)), dtype)
    s = jnp.asarray(RNG.normal(size=(d,)) * 0.1 + 1.0, dtype)
    _parity(lambda x, s: L.rmsnorm(x, s),
            lambda mode: (lambda x, s: dispatch.rmsnorm(
                x, s, mode=mode).astype(x.dtype)),
            (x, s), dtype, f"rmsnorm T={T}")


@pytest.mark.parametrize("G,E,C,d,f", [
    (2, 4, 24, 32, 48),               # G·C=48 ∤ 128, 48 ∤ 256
    (1, 2, 96, 40, 64),               # d=40 → bd=40
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_dispatch_parity(G, E, C, d, f, dtype):
    buf = jnp.asarray(RNG.normal(size=(G, E, C, d)) * 0.3, dtype)
    w = jnp.asarray(RNG.normal(size=(E, d, f)) * 0.05, dtype)

    def jnp_path(buf, w):      # the moe_block capacity-buffer einsum
        return jnp.einsum("gecd,edf->gecf", buf.astype(jnp.float32),
                          w.astype(jnp.float32)).astype(buf.dtype)

    _parity(jnp_path,
            lambda mode: (lambda buf, w: dispatch.gmm(
                buf, w, mode=mode).astype(buf.dtype)),
            (buf, w), dtype, f"gmm G={G} E={E}")


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 128, 2, 16, 8, 64),
    (2, 96, 2, 16, 8, 64),            # 64 ∤ 96 → clamps to chunk=48
])
def test_ssd_dispatch_parity(B, S, H, P, N, chunk):
    dtype = jnp.float32               # the SSD path is f32 by contract
    xh = jnp.asarray(RNG.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, H)), dtype)
    A = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(H,)), dtype)
    bm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    cm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    D = jnp.asarray(RNG.normal(size=(H,)) * 0.1, dtype)

    _parity(lambda xh, bm, cm: ssd_chunked(xh, dt, A, bm, cm, D, chunk),
            lambda mode: (lambda xh, bm, cm: ssd_chunked(
                xh, dt, A, bm, cm, D, chunk, mode=mode)),
            (xh, bm, cm), dtype, f"ssd S={S}")


# ================================== SSD regression: old layers formula
def _ssd_chunked_legacy(xh, dt, A, bmat, cmat, D, chunk, init_state=None):
    """The deleted `layers._ssd_chunked`, verbatim — the numerics the
    jnp mamba path had before it was routed through
    `repro.kernels.ssd_chunk.ssd_chunked`.  Pins old == new."""
    B, S, H, P = xh.shape
    N = bmat.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    xc = xh.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H)
    bc = bmat.reshape(B, nc, chunk, N)
    cc = cmat.reshape(B, nc, chunk, N)

    la = dtc * A[None, None, None, :]
    cum = jnp.cumsum(la, axis=2)
    seg_end = cum[:, :, -1, :]

    li, lj = cum[:, :, :, None, :], cum[:, :, None, :, :]
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    gate = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    sc = jnp.einsum("bcin,bcjn->bcij", cc, bc)
    att = sc[..., None] * gate * dtc[:, :, None, :, :]
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    decay_to_end = jnp.exp(jnp.clip(seg_end[:, :, None, :] - cum, -60.0,
                                    0.0))
    s_in = jnp.einsum("bcqh,bcqn,bcqhp->bchnp",
                      dtc * decay_to_end, bc, xc)

    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, N, P), s_in.dtype))

    def scan_fn(carry, inp):
        s_c, g_end = inp
        s_new = carry * jnp.exp(jnp.clip(g_end, -60.0, 0.0)
                                )[:, :, None, None] + s_c
        return s_new, carry

    (final_state, s_prevs) = jax.lax.scan(
        scan_fn, s0,
        (s_in.transpose(1, 0, 2, 3, 4), seg_end.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)

    y_off = jnp.einsum("bcqn,bchnp->bcqhp",
                       cc, s_prevs) * jnp.exp(
        jnp.clip(cum, -60.0, 0.0))[..., None]
    y = (y_diag + y_off).reshape(B, S, H, P)
    y = y + xh * D[None, None, :, None]
    return y, final_state


@pytest.mark.parametrize("with_init", [False, True])
def test_ssd_chunked_matches_legacy_layers_path(with_init):
    """The kernels-package `ssd_chunked` (jnp mode) reproduces the old
    in-layers `_ssd_chunked` bit-for-tolerance — the mamba jnp path did
    not change numerics when it moved."""
    B, S, H, P, N, Q = 2, 256, 2, 32, 16, 64
    xh = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(H,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(H,)) * 0.1, jnp.float32)
    s0 = (jnp.asarray(RNG.normal(size=(B, H, N, P)) * 0.1, jnp.float32)
          if with_init else None)
    y_old, s_old = _ssd_chunked_legacy(xh, dt, A, bm, cm, D, Q,
                                       init_state=s0)
    y_new, s_new = ssd_chunked(xh, dt, A, bm, cm, D, Q, init_state=s0)
    _close(y_new, y_old, jnp.float32, what="ssd_chunked y vs legacy")
    _close(s_new, s_old, jnp.float32, what="ssd_chunked state vs legacy")


def test_ssd_kernel_composes_with_recurrence():
    """Kernel chunks + XLA cross-chunk scan == the legacy full-SSD
    formula (pallas mode end to end, not just the intra-chunk term)."""
    B, S, H, P, N, Q = 2, 256, 2, 32, 16, 64
    xh = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(H,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    D = jnp.zeros((H,), jnp.float32)
    y_ref, s_ref = _ssd_chunked_legacy(xh, dt, A, bm, cm, D, Q)
    y, s = ssd_chunked(xh, dt, A, bm, cm, D, Q, mode="pallas")
    _close(y, y_ref, jnp.float32)
    _close(s, s_ref, jnp.float32)


# ========================================================= 3. model level
def test_model_level_kernel_modes_agree():
    """loss+grads on a 1-repeat granite smoke config under the three
    `--kernels` contexts (the launch-flag path end to end, single
    device)."""
    from jax.sharding import Mesh

    from repro.configs import get_smoke
    from repro.dist.context import kernel_mode_flags, sharding_context
    from repro.models.transformer import init_params, loss_fn

    cfg = dataclasses.replace(get_smoke("granite-3-8b"), n_repeats=1)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (1, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))

    out = {}
    for mode in ("off", "ref", "pallas"):
        with sharding_context(mesh, flags=kernel_mode_flags(mode)):
            loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree_util.tree_leaves(grads)))
        out[mode] = (float(loss), float(gnorm))
    for mode in ("ref", "pallas"):
        assert abs(out[mode][0] - out["off"][0]) < 1e-3 * abs(
            out["off"][0]), (mode, out)
        assert abs(out[mode][1] - out["off"][1]) < 5e-3 * abs(
            out["off"][1]), (mode, out)
