"""Per-kernel interpret-mode allclose tests against the pure-jnp oracles,
sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_attention, flash_attention_ref, fused_mlp,
                           fused_mlp_ref, fused_rmsnorm, fused_rmsnorm_ref,
                           moe_gmm, moe_gmm_ref, ssd_chunk, ssd_chunk_ref)

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=0.05, atol=0.05) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------ flash attn
FLASH_CASES = [
    # (B, S, Hq, Hkv, D, causal, window, q_blk, kv_blk)
    (1, 128, 2, 2, 64, True, 0, 64, 64),
    (2, 256, 4, 2, 64, True, 0, 64, 64),
    (2, 256, 8, 2, 128, True, 0, 128, 64),
    (1, 256, 4, 4, 64, False, 0, 64, 64),
    (2, 256, 4, 2, 64, True, 96, 64, 64),
    (1, 512, 2, 1, 64, True, 128, 128, 128),
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal,window,qb,kb", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, Hq, Hkv, D, causal, window, qb, kb, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_blk=qb, kv_blk=kb)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_skips_blocks():
    """The kernel grid must be exactly the visible-pair count."""
    from repro.kernels.flash_attention.kernel import build_pair_tables
    pi, pj, _, _ = build_pair_tables(8, 8, causal=True, window=0,
                                     q_blk=64, kv_blk=64, kv_offset=0)
    assert len(pi) == 8 * 9 // 2          # triangle, not 64
    pi, _, _, _ = build_pair_tables(8, 8, causal=True, window=128,
                                    q_blk=64, kv_blk=64, kv_offset=0)
    assert len(pi) <= 8 * 3               # window: ≤3 blocks per row


# ------------------------------------------------------------- fused mlp
@pytest.mark.parametrize("T,d,ff,act,gated", [
    (128, 128, 512, "silu", True),
    (256, 256, 512, "relu2", False),
    (128, 128, 1024, "gelu", False),
    (512, 64, 256, "silu", True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_mlp(T, d, ff, act, gated, dtype):
    x = jnp.asarray(RNG.normal(size=(T, d)) * 0.3, dtype)
    wu = jnp.asarray(RNG.normal(size=(d, ff)) * 0.05, dtype)
    wd = jnp.asarray(RNG.normal(size=(ff, d)) * 0.05, dtype)
    wg = jnp.asarray(RNG.normal(size=(d, ff)) * 0.05, dtype) if gated \
        else None
    out = fused_mlp(x, wu, wd, wg, act=act, bm=64, bff=256)
    ref = fused_mlp_ref(x, wu, wd, wg, act=act)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# -------------------------------------------------------------- moe gmm
@pytest.mark.parametrize("E,C,d,f", [(4, 128, 128, 256), (8, 256, 64, 128),
                                     (2, 128, 256, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(E, C, d, f, dtype):
    buf = jnp.asarray(RNG.normal(size=(E, C, d)) * 0.3, dtype)
    w = jnp.asarray(RNG.normal(size=(E, d, f)) * 0.05, dtype)
    out = moe_gmm(buf, w, bc=64, bf=128, bd=64)
    ref = moe_gmm_ref(buf, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ------------------------------------------------------------ ssd chunk
@pytest.mark.parametrize("BC,H,Q,P,N", [(2, 2, 64, 32, 16),
                                        (4, 4, 128, 64, 32),
                                        (1, 8, 256, 64, 128)])
def test_ssd_chunk(BC, H, Q, P, N):
    xh = jnp.asarray(RNG.normal(size=(BC, H, Q, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(BC, H, 1, Q)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(H,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(BC, Q, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(BC, Q, N)), jnp.float32)
    y, s = ssd_chunk(xh, dt, A, bm, cm)
    y_ref, s_ref = ssd_chunk_ref(xh, dt, A, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_kernel_composes_with_recurrence():
    """Kernel chunks + XLA cross-chunk scan == the full SSD reference."""
    from repro.models.layers import _ssd_chunked
    B, S, H, P, N, Q = 2, 256, 2, 32, 16, 64
    xh = jnp.asarray(RNG.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(H,)), jnp.float32)
    bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    D = jnp.zeros((H,), jnp.float32)
    y_ref, _ = _ssd_chunked(xh, dt, A, bm, cm, D, Q)

    nc = S // Q
    xc = xh.reshape(B, nc, Q, H, P).transpose(0, 1, 3, 2, 4).reshape(
        B * nc, H, Q, P)
    dtc = dt.reshape(B, nc, Q, H).transpose(0, 1, 3, 2).reshape(
        B * nc, H, 1, Q)
    bc = bm.reshape(B * nc, Q, N)
    cc = cm.reshape(B * nc, Q, N)
    y_diag, s_in = ssd_chunk(xc, dtc, A, bc, cc)
    y_diag = y_diag.reshape(B, nc, H, Q, P)
    s_in = s_in.reshape(B, nc, H, N, P)

    # cross-chunk recurrence + off-diagonal term (XLA side)
    la = dt * A[None, None, :]
    cum = la.reshape(B, nc, Q, H).cumsum(axis=2)
    seg_end = cum[:, :, -1, :]                                 # (B,nc,H)

    def scan_fn(s_prev, inp):
        s_c, g_end = inp
        return s_prev * jnp.exp(g_end)[:, :, None, None] + s_c, s_prev

    s0 = jnp.zeros((B, H, N, P))
    _, s_prevs = jax.lax.scan(
        scan_fn, s0, (s_in.transpose(1, 0, 2, 3, 4),
                      seg_end.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)
    ccg = cm.reshape(B, nc, Q, N)
    y_off = jnp.einsum("bcqn,bchnp->bchqp", ccg, s_prevs) * jnp.exp(
        cum).transpose(0, 1, 3, 2)[..., None]
    y = (y_diag + y_off).transpose(0, 1, 3, 2, 4).reshape(B, S, H, P)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------- fused rmsnorm
@pytest.mark.parametrize("T,d", [(256, 128), (512, 512), (128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_rmsnorm(T, d, dtype):
    x = jnp.asarray(RNG.normal(size=(T, d)), dtype)
    s = jnp.asarray(RNG.normal(size=(d,)) * 0.1 + 1.0, dtype)
    out = fused_rmsnorm(x, s, bm=64)
    ref = fused_rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
