"""mkplan tests: the unified cost-model API, the launch-space planner,
and every surface that consumes them.

- **parity**: `repro.analysis.costmodel` is the single home of every
  analytic formula — the old call sites (`dist/pipeline`,
  `train/pipeline`, `launch/dryrun`) re-export the *same objects*, and
  known values pin each model;
- **MK-T fixtures**: one known-bad config per rule, asserted by exact
  ID (the stable-contract convention of `tests/test_analysis.py`);
- **frontier invariant**: no returned frontier point is dominated by
  any other scored point (deterministic + hypothesis property form);
- **ranking**: the planner's static best-config ranking matches the
  exhaustive dryrun-measured ranking on the 8-device granite and jamba
  smoke meshes (compiled-HLO roofline terms vs the analytic models);
- **kernel footprints**: forward and backward phases priced separately
  from recorded block geometry;
- **MK-K008 + phase-keyed tuner cache**: the divisor-clamp warning and
  the explicit backward block entries the footprint model rests on.
"""
import json
import subprocess
import sys
import textwrap

import pytest

from _hypothesis_compat import given, settings, st

from repro.analysis import costmodel as cm
from repro.analysis.planner import (LaunchCandidate, Score,
                                    ScoredCandidate, check_launch,
                                    check_plan, enumerate_configs,
                                    frontier, plan_frontier, score)
from repro.configs import get_smoke

JAMBA = "jamba-v0.1-52b"
GRANITE = "granite-3-8b"


# ------------------------------------------------------------- parity
def test_costmodel_is_canonical_for_dist_pipeline():
    """dist/pipeline re-exports the costmodel objects — not copies."""
    from repro.dist import pipeline as dp

    assert dp.pipeline_bubble_fraction is cm.pipeline_bubble_fraction
    assert dp.pipeline_peak_inflight is cm.pipeline_peak_inflight
    assert (dp.pipeline_peak_activation_bytes
            is cm.pipeline_peak_activation_bytes)
    assert dp.program_peak_inflight is cm.program_peak_inflight
    assert dp.SCHEDULES is cm.SCHEDULES
    assert (dp.PIPE_IDLE, dp.PIPE_FWD, dp.PIPE_BWD) == \
        (cm.PIPE_IDLE, cm.PIPE_FWD, cm.PIPE_BWD)


def test_costmodel_is_canonical_for_train_pipeline():
    from repro.train import pipeline as tp

    assert tp.estimate_block_costs is cm.estimate_block_costs
    assert tp._analytic_block_cost is cm.analytic_block_cost


def test_costmodel_is_canonical_for_dryrun_constants():
    """launch/dryrun imports the hardware model instead of owning it."""
    import ast
    import inspect

    from repro.launch import dryrun

    assert dryrun.PEAK_FLOPS is cm.PEAK_FLOPS
    assert dryrun.HBM_BW is cm.HBM_BW
    assert dryrun.roofline_terms is cm.roofline_terms
    # no shadow copy left behind: dryrun's module body assigns none of
    # the migrated constant names itself
    tree = ast.parse(inspect.getsource(dryrun))
    assigned = {t.id for node in tree.body
                if isinstance(node, ast.Assign)
                for t in node.targets if isinstance(t, ast.Name)}
    assert not assigned & {"PEAK_FLOPS", "HBM_BW", "ICI_BW"}


def test_constants_match_core_resources():
    """costmodel mirrors the repo hardware model (import layering keeps
    them textually separate; this is the drift guard)."""
    from repro.core import resources

    assert cm.PEAK_FLOPS == resources.PEAK_FLOPS_BF16
    assert cm.HBM_BW == resources.HBM_BW
    assert cm.ICI_BW == resources.ICI_BW_PER_LINK
    assert cm.VMEM_BYTES == resources.VMEM_BYTES


def test_bubble_and_inflight_pins():
    # uniform: (S-1)/(vM+S-1)
    assert cm.pipeline_bubble_fraction(8, 4) == pytest.approx(3 / 11)
    assert cm.pipeline_bubble_fraction(
        8, 4, virtual_stages=2) == pytest.approx(3 / 19)
    assert cm.pipeline_bubble_fraction(1, 1) == 0.0
    # peak inflight: M / min(M, S) / min(vM, vS+S-1+v)
    assert cm.pipeline_peak_inflight(8, 4, "gpipe") == 8
    assert cm.pipeline_peak_inflight(8, 4, "1f1b") == 4
    assert cm.pipeline_peak_inflight(
        8, 4, "interleaved", virtual_stages=2) == min(16, 8 + 3 + 2)
    # activation stash = inflight × microbatch bytes
    assert cm.pipeline_peak_activation_bytes(8, 4, "1f1b", 100.0) == \
        pytest.approx(400.0)


def test_roofline_terms_bottleneck():
    t = cm.roofline_terms(cm.PEAK_FLOPS, cm.HBM_BW * 2.0, cm.ICI_BW)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.collective_s == pytest.approx(1.0)
    assert t.bottleneck == "memory"
    assert set(t.as_dict()) == {"compute", "memory", "collective"}


def test_analytic_block_cost_scales_with_tokens():
    cfg = get_smoke(GRANITE)
    c1 = cm.analytic_block_cost(cfg, 0, 64)
    c2 = cm.analytic_block_cost(cfg, 0, 128)
    assert c1 > 0 and c2 == pytest.approx(2 * c1)


# ------------------------------------------------- enumerate + score
def test_enumerate_respects_launch_arithmetic():
    cfg = get_smoke(JAMBA)
    cands = enumerate_configs(cfg, 8, global_batch=8)
    assert cands
    seen = set()
    for c in cands:
        assert c not in seen, f"duplicate candidate {c}"
        seen.add(c)
        assert c.n_devices == 8
        assert c.stages * c.virtual_stages <= cfg.n_repeats
        assert cfg.num_kv_heads % c.tp == 0 and cfg.d_ff % c.tp == 0
        assert 8 % c.dp == 0
        local = 8 // c.dp
        assert local % c.microbatch == 0
        if c.schedule == "interleaved":
            assert c.virtual_stages >= 2
        else:
            assert c.virtual_stages == 1
    # the interleaved v=2 family PR 8 built is in the space
    assert any(c.schedule == "interleaved" and c.virtual_stages == 2
               for c in cands)


def test_score_prices_all_three_axes():
    cfg = get_smoke(JAMBA)
    sc = score(cfg, LaunchCandidate(stages=2, microbatch=2,
                                    schedule="1f1b", tp=2, dp=2),
               global_batch=8, seq_len=64)
    assert sc.score.step_time_s > 0
    assert sc.score.peak_bytes > 0
    assert sc.score.collective_bytes > 0
    assert 0 <= sc.bubble < 1
    assert set(sc.collective_by_axis) == {"stage", "model", "data"}
    # more microbatches strictly shrink the uniform bubble
    sc4 = score(cfg, LaunchCandidate(stages=2, microbatch=4,
                                     schedule="1f1b", tp=2, dp=2),
                global_batch=8, seq_len=64)
    assert sc4.bubble < sc.bubble


# ------------------------------------------------------------ frontier
def _dominated_by_any(sc, scored):
    return any(o.score.dominates(sc.score) for o in scored)


def test_frontier_never_returns_dominated_point():
    cfg = get_smoke(JAMBA)
    scored = plan_frontier(cfg, 8, global_batch=8, seq_len=64)
    front = [s for s in scored if s.on_frontier]
    assert front, "empty frontier"
    for s in front:
        assert not _dominated_by_any(s, scored), s.candidate.label()
    # and every dominated point names a frontier dominator
    for s in scored:
        if not s.on_frontier:
            dom = [o for o in scored if o.candidate == s.dominated_by]
            assert dom and dom[0].on_frontier
            assert dom[0].score.dominates(s.score)


def test_domination_is_strict_on_equal_vectors():
    a = Score(1.0, 2.0, 3.0)
    assert not a.dominates(Score(1.0, 2.0, 3.0))
    assert a.dominates(Score(1.0, 2.0, 4.0))
    assert not a.dominates(Score(0.5, 2.0, 4.0))


def _toy_scored(vectors):
    out = []
    for i, (t, p, c) in enumerate(vectors):
        cand = LaunchCandidate(stages=1, microbatch=i + 1,
                               schedule="gpipe")
        out.append(ScoredCandidate(
            candidate=cand, score=Score(t, p, c), bubble=0.0,
            peak_activation_bytes=p, collective_by_axis={}))
    return out


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 8), st.integers(0, 8),
                          st.integers(0, 8)),
                min_size=1, max_size=12))
def test_frontier_invariant_property(vectors):
    """Property form: for arbitrary score vectors the frontier never
    contains a dominated point, domination pointers are sound, and the
    frontier is never empty."""
    scored = frontier(_toy_scored([tuple(map(float, v))
                                   for v in vectors]))
    front = [s for s in scored if s.on_frontier]
    assert front
    for s in front:
        assert not _dominated_by_any(s, scored)
    for s in scored:
        if not s.on_frontier:
            assert any(o.candidate == s.dominated_by
                       and o.score.dominates(s.score) for o in scored)


# ------------------------------------------------------- MK-T fixtures
def _rules(diags):
    return {d.rule for d in diags}


def test_mkt001_dominated_same_mesh_fires():
    cfg = get_smoke(JAMBA)
    # gpipe M=2 on the (2,2,2) mesh: 1f1b M=4 on the same mesh is ≤ on
    # every model and < on time — the canonical "wrong schedule knobs"
    diags = check_launch(
        cfg, LaunchCandidate(stages=2, microbatch=2, schedule="gpipe",
                             tp=2, dp=2),
        global_batch=8, seq_len=64)
    assert "MK-T001" in _rules(diags)
    d = next(d for d in diags if d.rule == "MK-T001")
    assert d.severity is not None and not d.is_error     # warning
    assert "repro.launch.train" in d.hint                # dominating argv


def test_mkt002_memory_budget_fires():
    cfg = get_smoke(JAMBA)
    diags = check_launch(
        cfg, LaunchCandidate(stages=2, microbatch=2, schedule="gpipe",
                             tp=2, dp=2),
        global_batch=8, seq_len=64, mem_budget_bytes=1.0)
    assert "MK-T002" in _rules(diags)


def test_mkt003_interleaving_would_lower_bubble_fires():
    cfg = get_smoke(JAMBA)      # n_repeats=4: v=2 fits at stages=2
    diags = check_launch(
        cfg, LaunchCandidate(stages=2, microbatch=2, schedule="gpipe",
                             tp=2, dp=2),
        global_batch=8, seq_len=64)
    assert "MK-T003" in _rules(diags)
    d = next(d for d in diags if d.rule == "MK-T003")
    assert "virtual_stages=2" in d.msg


def test_mkt004_tp_prices_worse_than_stages_fires():
    cfg = get_smoke(JAMBA)
    # M=1 at S=2: the tp=2 point eats a (S-1)/(M+S-1) = 1/2 bubble; the
    # same 8 devices as stages=4 micro=4 tp=1 dp=2 price strictly faster
    diags = check_launch(
        cfg, LaunchCandidate(stages=2, microbatch=1, schedule="gpipe",
                             tp=2, dp=2),
        global_batch=8, seq_len=64)
    assert "MK-T004" in _rules(diags)


def test_mkt_clean_on_frontier_config():
    cfg = get_smoke(JAMBA)
    # the jamba frontier's interleaved point: nothing to warn about
    diags = check_launch(
        cfg, LaunchCandidate(stages=2, microbatch=4,
                             schedule="interleaved", virtual_stages=2,
                             tp=2, dp=2),
        global_batch=8, seq_len=64)
    assert diags == []


def test_check_plan_wraps_report():
    cfg = get_smoke(JAMBA)
    report = check_plan(
        cfg, LaunchCandidate(stages=2, microbatch=2, schedule="gpipe",
                             tp=2, dp=2),
        global_batch=8, seq_len=64)
    assert report.ok                      # warnings only, never errors
    assert {"MK-T001", "MK-T003"} <= report.rules_fired()
    assert report.target.startswith("plan ")
    # the JSON schema the CLI emits
    d = report.as_dict()
    assert set(d) == {"target", "ok", "wall_s", "diagnostics"}
    assert all(set(x) == {"rule", "severity", "loc", "msg", "hint"}
               for x in d["diagnostics"])


# ------------------------------------------- static vs dryrun ranking
RANK_SCRIPT = textwrap.dedent("""
    import json, sys
    from repro.launch.dryrun import lower_cell   # sets 512 host devices
    from repro.models.common import ShapeSpec
    from repro.configs import get_smoke
    from repro.analysis.planner import LaunchCandidate, score

    small = ShapeSpec("train_smoke", 64, 8, "train")
    CANDS = {
        "gpipe-m2": dict(stages=2, n_micro=2, schedule="gpipe"),
        "1f1b-m4": dict(stages=2, n_micro=4, schedule="1f1b"),
        "inter-v2-m4": dict(stages=2, n_micro=4,
                            schedule="interleaved", virtual_stages=2),
    }
    out = {}
    for arch in ("jamba-v0.1-52b", "granite-3-8b"):
        cfg = get_smoke(arch)
        rank = {}
        for name, kw in CANDS.items():
            if cfg.n_repeats < 2 * kw.get("virtual_stages", 1):
                continue              # granite smoke: v=2 doesn't fit
            rec = lower_cell(arch, "train_4k", smoke=True,
                             shape_override=small, data_par=2,
                             model_par=2, **kw)
            assert "skipped" not in rec, rec
            # measured side: compiled-HLO roofline terms (loop-aware
            # per-device flops/bytes/collectives), inflated by the
            # schedule's idle fraction
            terms = rec["terms_s"]
            bubble = rec["pipeline"]["predicted_bubble"]
            measured = max(terms.values()) / (1.0 - bubble)
            st = score(cfg, LaunchCandidate(
                stages=kw["stages"], microbatch=kw["n_micro"],
                schedule=kw["schedule"],
                virtual_stages=kw.get("virtual_stages", 1),
                tp=2, dp=2), global_batch=8, seq_len=64)
            rank[name] = (measured, st.score.step_time_s)
        out[arch] = {
            "measured": sorted(rank, key=lambda k: rank[k][0]),
            "static": sorted(rank, key=lambda k: rank[k][1]),
        }
    print("RANKS=" + json.dumps(out))
""")


def test_static_ranking_matches_dryrun_measured_ranking():
    """Acceptance criterion: on the 8-device granite and jamba smoke
    meshes, scoring the launch space statically ranks the configs the
    same way exhaustively dry-running them (compile + HLO analysis)
    does."""
    r = subprocess.run([sys.executable, "-c", RANK_SCRIPT],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    line = next(ln for ln in r.stdout.splitlines()
                if ln.startswith("RANKS="))
    ranks = json.loads(line[len("RANKS="):])
    for arch, got in ranks.items():
        assert len(got["static"]) >= 2, (arch, got)
        assert got["static"] == got["measured"], (arch, got)


# --------------------------------------------------- kernel footprints
def test_flash_footprint_fwd_and_bwd_priced_separately():
    shape = (2, 128, 4, 16)                 # (B, S, Hq, D)
    fwd = cm.kernel_footprint("flash_attention", shape)
    bwd = cm.kernel_footprint("flash_attention", shape, phase="bwd")
    assert fwd.phase == "fwd" and not fwd.approximate
    assert fwd.n_calls >= 1 and fwd.grid
    assert fwd.bytes_touched > 0 and fwd.vmem_bytes > 0
    assert fwd.vmem_bytes <= cm.VMEM_BYTES
    # chunked recompute backward: 2× the streamed traffic at the
    # backward chunk geometry (same here — no bwd cache entry)
    assert bwd.phase == "bwd" and bwd.approximate
    assert bwd.bytes_touched == pytest.approx(2 * fwd.bytes_touched)


def test_ref_vjp_footprint_is_unblocked():
    shape = (128, 64, 192)                  # fused_mlp (T, d, ff)
    fwd = cm.kernel_footprint("fused_mlp", shape)
    bwd = cm.kernel_footprint("fused_mlp", shape, phase="bwd")
    assert fwd.vmem_bytes > 0
    assert bwd.approximate and bwd.vmem_bytes == 0.0
    assert bwd.bytes_touched > 0 and bwd.grid == ()


def test_footprint_scales_with_block_config():
    shape = (2, 128, 4, 16)
    small = cm.kernel_footprint("flash_attention", shape,
                                config={"q_blk": 32, "kv_blk": 32})
    big = cm.kernel_footprint("flash_attention", shape,
                              config={"q_blk": 128, "kv_blk": 128})
    # smaller q blocks → more grid points; VMEM working set shrinks
    assert small.vmem_bytes < big.vmem_bytes


def test_resolve_block_config_overlays_bwd_cache(tmp_path):
    from repro.kernels import tune

    shape = (2, 128, 4, 16)
    path = str(tmp_path / "tune.json")
    cache = {"version": tune.CACHE_VERSION, "entries": {
        tune.cache_key("flash_attention", shape, "float32"):
            {"config": {"q_blk": 64, "kv_blk": 64}},
        tune.cache_key("flash_attention", shape, "float32", phase="bwd"):
            {"config": {"q_blk": 32, "kv_blk": 128}},
    }}
    tune.save_cache(cache, path)
    tune._MEMO.clear()
    fwd = cm.resolve_block_config("flash_attention", shape,
                                  cache_path=path)
    bwd = cm.resolve_block_config("flash_attention", shape, phase="bwd",
                                  cache_path=path)
    tune._MEMO.clear()
    assert (fwd["q_blk"], fwd["kv_blk"]) == (64, 64)
    assert (bwd["q_blk"], bwd["kv_blk"]) == (32, 128)


# --------------------------------------- MK-K008 + phase-keyed tuning
def test_mkk008_clamp_warning_fires_and_names_padding():
    from repro.analysis.kernels import check_block_clamp

    # 131 is prime: the divisor clamp collapses any target to block 1
    diags = check_block_clamp("flash_attention", "q_blk", 131, 128)
    assert len(diags) == 1
    d = diags[0]
    assert d.rule == "MK-K008" and not d.is_error
    assert "pad" in d.hint
    # 33 → divisor 11 < 32/2: still a shrink worth naming
    assert check_block_clamp("fused_mlp", "bm", 33, 32)
    # exact/pow2-friendly dims stay silent
    assert check_block_clamp("fused_mlp", "bm", 128, 128) == []
    assert check_block_clamp("fused_mlp", "bm", 130, 128) == []


def test_mkk008_from_tuner_candidate_screen():
    from repro.kernels import tune

    # shape with a prime q-length: the clamped candidate carries the
    # warning, but stays *legal* (warnings never gate the tuner)
    shape = (1, 131, 2, 16)
    diags = tune.validate_candidate("flash_attention", shape,
                                    {"q_blk": 1, "kv_blk": 1})
    assert "MK-K008" in {getattr(d, "rule", None) for d in diags}
    assert not tune.screen_errors(diags)


def test_mkk008_not_fired_for_explicit_small_blocks():
    from repro.kernels import tune

    # a deliberately small block on a friendly dim is the user's choice,
    # not a clamp artifact — no warning
    diags = tune.validate_candidate("fused_mlp", (128, 64, 192),
                                    {"bm": 16, "bff": 64})
    assert not diags


def test_cache_keys_carry_phase(tmp_path):
    from repro.kernels import tune

    shape = (2, 128, 4, 16)
    kf = tune.cache_key("flash_attention", shape, "float32")
    kb = tune.cache_key("flash_attention", shape, "float32", phase="bwd")
    assert kf != kb and kf.endswith("|fwd") and kb.endswith("|bwd")
    with pytest.raises(ValueError):
        tune.cache_key("flash_attention", shape, "float32", phase="nope")
    # cached_config is phase-keyed: a fwd-only cache misses for bwd
    path = str(tmp_path / "tune.json")
    tune.save_cache({"version": tune.CACHE_VERSION, "entries": {
        kf: {"config": {"q_blk": 64, "kv_blk": 64}}}}, path)
    tune._MEMO.clear()
    assert tune.cached_config("flash_attention", shape, "float32",
                              path=path) == {"q_blk": 64, "kv_blk": 64}
    assert tune.cached_config("flash_attention", shape, "float32",
                              phase="bwd", path=path) == {}
    tune._MEMO.clear()


def test_bwd_validate_rejects_non_bwd_kernels():
    from repro.kernels import tune

    diags = tune.validate_candidate("fused_mlp", (128, 64, 192),
                                    {"bm": 64, "bff": 64}, phase="bwd")
    assert tune.screen_errors(diags)


# -------------------------------------------------------- CLI surfaces
def test_choose_cli_json_recommends_frontier_best():
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.choose", "--arch", JAMBA,
         "--smoke", "--devices", "8", "--global-batch", "8",
         "--seq-len", "64", "--json"],
        capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout)
    assert out["version"] == 1 and out["n_frontier"] >= 1
    rec = out["recommended"]
    assert rec and rec["argv"][:4] == ["python", "-m",
                                       "repro.launch.train", "--arch"]
    rows = out["rows"]
    assert len(rows) == out["n_candidates"]
    front_labels = {row["label"] for row in rows if row["on_frontier"]}
    assert rec["label"] in front_labels
    # dominated rows point at a frontier label
    for row in rows:
        if not row["on_frontier"]:
            assert row["dominated_by"] in front_labels


def test_mklint_json_and_plan(tmp_path):
    r = subprocess.run(
        [sys.executable, "tools/mklint.py", "--arch", JAMBA, "--smoke",
         "--stages", "2", "--microbatch", "2", "--mesh-shape", "2,2,2",
         "--axes", "stage,data,model", "--global-batch", "8",
         "--seq-len", "64", "--plan", "--no-kernels", "--format", "json"],
        capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout)
    assert out["version"] == 1 and len(out["reports"]) == 2
    verify_rep, plan_rep = out["reports"]
    assert verify_rep["ok"] and plan_rep["ok"]
    rules = {d["rule"] for d in plan_rep["diagnostics"]}
    assert {"MK-T001", "MK-T003"} <= rules
    assert all(d["severity"] == "warning"
               for d in plan_rep["diagnostics"])
