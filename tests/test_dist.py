"""Tests for the `repro.dist` substrate: context scoping, logical-axis
resolution, batch/param spec construction, and a real sharded round-trip
on a 2×2 host-device mesh (subprocess — the device count must be set
before jax initializes)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.context import (active_mesh, constrain, flag, moe_groups,
                                sharding_context)
from repro.dist.pipeline import balance_stages, pipeline_bubble_fraction
from repro.dist.sharding import batch_spec, data_axes, param_specs
from repro.launch.mesh import make_mesh


# ----------------------------------------------------------------- context
def test_constrain_identity_outside_context():
    x = jnp.ones((4, 8))
    assert constrain(x, "dp", None) is x
    assert constrain(x, "dp", "tp") is x
    assert active_mesh() is None


def test_flag_reflects_context_flags():
    assert not flag("ar_bf16")
    mesh = make_mesh((1, 1), ("data", "model"))
    with sharding_context(mesh, flags=("ar_bf16", "seq_shard")):
        assert flag("ar_bf16")
        assert flag("seq_shard")
        assert not flag("decode_bf16_scores")
        # nesting restores the outer context's flags on exit
        with sharding_context(mesh, flags=("no_flash_vjp",)):
            assert flag("no_flash_vjp") and not flag("ar_bf16")
        assert flag("ar_bf16") and not flag("no_flash_vjp")
    assert not flag("ar_bf16")
    assert active_mesh() is None


def test_constrain_rank_mismatch_raises():
    mesh = make_mesh((1, 1), ("data", "model"))
    with sharding_context(mesh):
        with pytest.raises(ValueError):
            constrain(jnp.ones((2, 2)), "dp")


def test_moe_groups_outside_context_is_default():
    assert moe_groups(16) == 16
    assert moe_groups(1) == 1


# -------------------------------------------------------------- batch_spec
def test_batch_spec_ndims():
    mesh = make_mesh((1, 1), ("data", "model"))
    assert batch_spec(mesh, 8, 1) == P(("data",))
    assert batch_spec(mesh, 8) == P(("data",), None)
    assert batch_spec(mesh, 8, 3) == P(("data",), None, None)
    assert data_axes(mesh) == ("data",)


def test_batch_spec_searches_axis_subsets():
    """Regression: a batch divisible only by an *inner or outer* subset of
    the data axes must shard over that subset, not fall to replicated
    (the old implementation dropped axes outermost-first, so batch 2 on a
    ("pod"=2, "data"=4) mesh went replicated even though "pod" divides).
    Ties on shard count keep the old innermost preference."""
    from jax.sharding import AbstractMesh

    pd = AbstractMesh((("pod", 2), ("data", 4), ("model", 1)))
    assert batch_spec(pd, 8, 2) == P(("pod", "data"), None)
    assert batch_spec(pd, 4, 2) == P(("data",), None)
    assert batch_spec(pd, 2, 2) == P(("pod",), None)       # the fix
    assert batch_spec(pd, 6, 2) == P(("pod",), None)       # 6 = 2·3
    assert batch_spec(pd, 3, 2) == P(None, None)
    sym = AbstractMesh((("pod", 2), ("data", 2), ("model", 2)))
    assert batch_spec(sym, 2, 2) == P(("data",), None)     # tie → inner
    assert batch_spec(sym, 4, 2) == P(("pod", "data"), None)


# ------------------------------------------------------------- param_specs
def test_param_specs_by_name():
    sds = jax.ShapeDtypeStruct
    tree = {
        "embed": sds((512, 64), jnp.float32),
        "final_norm": sds((64,), jnp.float32),
        "head": sds((64, 512), jnp.float32),
        "layers": [{
            "ln1": sds((4, 64), jnp.float32),
            "mixer": {"wq": sds((4, 64, 8, 16), jnp.float32),
                      "wo": sds((4, 8, 16, 64), jnp.float32)},
            "ffn": {"w_up": sds((4, 64, 256), jnp.float32),
                    "w_down": sds((4, 256, 64), jnp.float32),
                    "we_up": sds((4, 8, 64, 128), jnp.float32)},
        }],
    }
    specs = param_specs(tree)
    assert specs["embed"] == P("model", None)
    assert specs["final_norm"] == P(None)
    assert specs["head"] == P(None, "model")
    blk = specs["layers"][0]
    assert blk["ln1"] == P(None, None)
    assert blk["mixer"]["wq"] == P(None, None, "model", None)
    assert blk["mixer"]["wo"] == P(None, "model", None, None)
    assert blk["ffn"]["w_up"] == P(None, None, "model")
    assert blk["ffn"]["w_down"] == P(None, "model", None)
    assert blk["ffn"]["we_up"] == P(None, "model", None, None)


# ---------------------------------------------------------------- pipeline
def test_balance_stages_validates():
    with pytest.raises(ValueError):
        balance_stages([1.0, 2.0], 3)
    with pytest.raises(ValueError):
        pipeline_bubble_fraction(0, 4)
    assert balance_stages([5.0], 1) == [1]


# ----------------------------------------------- multi-device (subprocess)
MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.context import constrain, moe_groups, sharding_context
    from repro.dist.sharding import (batch_spec, cache_specs, param_specs,
                                     shard_tree_specs, with_shardings)
    from repro.launch.mesh import make_mesh

    # -- batch_spec divides the data axes correctly for 1-3D batches
    mesh = make_mesh((2, 2), ("data", "model"))
    assert batch_spec(mesh, 8, 1) == P(("data",))
    assert batch_spec(mesh, 8, 2) == P(("data",), None)
    assert batch_spec(mesh, 8, 3) == P(("data",), None, None)
    assert batch_spec(mesh, 3, 2) == P(None, None)  # indivisible: replicate

    pod = make_mesh((2, 2, 2), ("pod", "data", "model"))
    assert batch_spec(pod, 8, 2) == P(("pod", "data"), None)
    # batch 2 divides either single axis: ties keep the inner data axis
    assert batch_spec(pod, 2, 2) == P(("data",), None)
    assert batch_spec(pod, 3, 2) == P(None, None)
    # subset search: a batch divisible only by the outer pod axis still
    # shards over it (used to fall all the way to replicated)
    pd = make_mesh((2, 4, 1), ("pod", "data", "model"))
    assert batch_spec(pd, 2, 2) == P(("pod",), None)

    # -- moe_groups rounds up to a multiple of the dp shard count
    with sharding_context(pod):
        assert moe_groups(1) == 4
        assert moe_groups(6) == 8
        assert moe_groups(16) == 16

    # -- param_specs / with_shardings round-trip on the 2x2 mesh
    rng = np.random.default_rng(0)
    tree = {
        "embed": jnp.asarray(rng.normal(size=(256, 16)), jnp.float32),
        "layers": [{
            "ln1": jnp.ones((3, 16), jnp.float32),
            "mixer": {"wq": jnp.asarray(rng.normal(size=(3, 16, 4, 8)),
                                        jnp.float32)},
            "ffn": {"w_up": jnp.asarray(rng.normal(size=(3, 16, 32)),
                                        jnp.float32),
                    "w_down": jnp.asarray(rng.normal(size=(3, 32, 16)),
                                          jnp.float32)},
        }],
    }
    specs = param_specs(tree)
    sharded = with_shardings(tree, specs, mesh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(sharded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert len(b.sharding.device_set) == 4
    wq = sharded["layers"][0]["mixer"]["wq"]
    assert wq.sharding.spec == P(None, None, "model", None)
    # a dim that does not divide the axis drops to replicated
    odd = {"w_up": jnp.ones((5, 7, 9), jnp.float32)}
    odd_sharded = with_shardings(odd, param_specs(odd), mesh)
    assert odd_sharded["w_up"].sharding.spec in (P(), P(None, None, None))

    # -- shard_tree_specs attaches shardings without allocating
    sds_tree = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
    sds = shard_tree_specs(sds_tree, specs, mesh)
    assert sds["embed"].sharding.spec == P("model", None)

    # -- constrain inside jit shards the way batch_spec says
    with mesh, sharding_context(mesh):
        out = jax.jit(lambda x: constrain(x, "dp", "tp"))(
            jnp.ones((8, 16)))
        # GSPMD may normalize the singleton tuple to a bare axis name
        assert out.sharding.spec in (P(("data",), "model"),
                                     P("data", "model")), out.sharding

    print("DIST OK")
""")


def test_round_trip_on_2x2_host_mesh():
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "DIST OK" in r.stdout
