"""mklint static verifier: rule IDs are a public contract.

Each known-bad fixture must fire its exact rule; each known-good fixture
(including the masked heterogeneous stage scan shape from the padded
per-stage partitions) must pass clean.  The end-to-end tests run the CLI
and `--verify` launchers in subprocesses with faked device counts.
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from _hypothesis_compat import given, settings, st

from repro.analysis import (DiagnosticError, RULES, Severity,
                            check_mesh_cli, check_restore_manifest,
                            check_shrink, check_step_program,
                            resolve_mesh_cli, verify_launch)
from repro.analysis.collectives import check_closed_jaxpr
from repro.analysis.kernels import (PallasCallRecord, check_pallas_call,
                                    check_repo_kernels)
from repro.analysis.shardspec import check_spec
from repro.dist.pipeline import (PIPE_BWD, PIPE_FWD, PIPE_IDLE,
                                 _check_program, make_step_program)

REPO = Path(__file__).resolve().parent.parent


def rules_of(diags):
    return {d.rule for d in diags}


def errors_of(diags):
    return {d.rule for d in diags if d.is_error}


# ---------------------------------------------------------------- MK-C

def _trace(f, *args, axes=(("model", 2),)):
    return jax.make_jaxpr(f, axis_env=list(axes))(*args)


MODEL2 = {"model": 2}


def test_cond_one_sided_psum_over_varying_pred_fires_c002():
    def f(x):
        pred = jax.lax.axis_index("model") == 0
        return jax.lax.cond(
            pred, lambda v: jax.lax.psum(v, "model"), lambda v: v, x)

    diags = check_closed_jaxpr(_trace(f, jnp.ones(4)), MODEL2)
    assert "MK-C002" in errors_of(diags)


def test_uniform_pred_masked_cond_is_clean():
    # the heterogeneous-stage masked scan shape: the predicate comes from
    # replicated size constants, so one-sided collectives are uniform
    def f(x, k):
        return jax.lax.cond(
            k > 0, lambda v: jax.lax.psum(v, "model"), lambda v: v, x)

    diags = check_closed_jaxpr(
        _trace(f, jnp.ones(4), jnp.int32(1)), MODEL2)
    assert not errors_of(diags)


def test_balanced_cond_branches_are_clean_even_when_pred_varies():
    def f(x):
        pred = jax.lax.axis_index("model") == 0
        return jax.lax.cond(
            pred,
            lambda v: jax.lax.psum(v * 2, "model"),
            lambda v: jax.lax.psum(v, "model"), x)

    diags = check_closed_jaxpr(_trace(f, jnp.ones(4)), MODEL2)
    assert not errors_of(diags)


def test_collective_over_unknown_axis_fires_c001():
    def f(x):
        return jax.lax.psum(x, "modle")

    closed = _trace(f, jnp.ones(4), axes=(("modle", 2),))
    diags = check_closed_jaxpr(closed, MODEL2)
    assert "MK-C001" in errors_of(diags)


def test_ppermute_dropped_edge_fires_c003():
    def f(x):
        return jax.lax.ppermute(x, "model", [(0, 1)])

    diags = check_closed_jaxpr(_trace(f, jnp.ones(4)), MODEL2)
    assert "MK-C003" in errors_of(diags)


def test_ppermute_complete_ring_is_clean():
    def f(x):
        return jax.lax.ppermute(x, "model", [(0, 1), (1, 0)])

    diags = check_closed_jaxpr(_trace(f, jnp.ones(4)), MODEL2)
    assert not errors_of(diags)


def test_stage_swap_permutation_warns_c004():
    def f(x):
        return jax.lax.ppermute(
            x, "stage", [(0, 1), (1, 0), (2, 3), (3, 2)])

    diags = check_closed_jaxpr(
        _trace(f, jnp.ones(4), axes=(("stage", 4),)), {"stage": 4})
    assert "MK-C004" in rules_of(diags)
    assert "MK-C004" not in errors_of(diags)     # warning, not error


def test_collective_under_varying_trip_count_fires_c005():
    def f(x):
        def cond(c):
            i, _ = c
            return i < jax.lax.axis_index("model") + 1

        def body(c):
            i, v = c
            return i + 1, jax.lax.psum(v, "model")

        return jax.lax.while_loop(cond, body, (jnp.int32(0), x))

    diags = check_closed_jaxpr(_trace(f, jnp.ones(4)), MODEL2)
    assert "MK-C005" in errors_of(diags)


def test_scan_carrying_balanced_cond_is_clean():
    def f(x):
        def step(carry, _):
            pred = jax.lax.axis_index("model") == 0
            y = jax.lax.cond(
                pred,
                lambda v: jax.lax.psum(v, "model"),
                lambda v: jax.lax.psum(v + 1, "model"), carry)
            return y, y

        out, _ = jax.lax.scan(step, x, None, length=3)
        return out

    diags = check_closed_jaxpr(_trace(f, jnp.ones(4)), MODEL2)
    assert not errors_of(diags)


# ---------------------------------------------------------------- MK-P

F, B, I = PIPE_FWD, PIPE_BWD, PIPE_IDLE
IDLE = (I, -1)


def _prog(*rows):
    return tuple(tuple(r) for r in rows)


# valid S=2, M=1 program: F0 F1 B1 B0 down the diagonal
GOOD_2x1 = _prog(
    [(F, 0), IDLE],
    [IDLE, (F, 0)],
    [IDLE, (B, 0)],
    [(B, 0), IDLE],
)


def test_generated_programs_are_clean():
    for m in (1, 2, 4, 5):
        for s in (1, 2, 3, 4):
            for sched in ("gpipe", "1f1b"):
                prog = make_step_program(m, s, sched)
                diags = check_step_program(prog, m, s, schedule=sched)
                assert not errors_of(diags), (m, s, sched, diags)


def test_hand_built_program_is_clean():
    assert not errors_of(check_step_program(GOOD_2x1, 1, 2))


def test_short_tick_row_fires_p001():
    bad = GOOD_2x1[:1] + (((F, 0),),) + GOOD_2x1[2:]
    assert "MK-P001" in errors_of(check_step_program(bad, 1, 2))


def test_duplicate_microstep_fires_p002():
    bad = _prog([(F, 0), IDLE], [(F, 0), (F, 0)],
                [IDLE, (B, 0)], [(B, 0), IDLE])
    assert "MK-P002" in errors_of(check_step_program(bad, 1, 2))


def test_missing_microstep_fires_p003():
    bad = _prog([(F, 0), IDLE], [IDLE, (F, 0)],
                [IDLE, (B, 0)], [IDLE, IDLE])
    assert "MK-P003" in errors_of(check_step_program(bad, 1, 2))


def test_forward_before_ring_delivery_fires_p004():
    bad = _prog([(F, 0), (F, 0)], [IDLE, IDLE],
                [IDLE, (B, 0)], [(B, 0), IDLE])
    assert "MK-P004" in errors_of(check_step_program(bad, 1, 2))


def test_late_backward_fires_p005():
    bad = _prog([(F, 0), IDLE], [IDLE, (F, 0)],
                [IDLE, (B, 0)], [IDLE, IDLE], [(B, 0), IDLE])
    assert "MK-P005" in errors_of(check_step_program(bad, 1, 2))


def test_backward_before_own_forward_fires_p005():
    bad = _prog([(F, 0), (B, 0)], [IDLE, (F, 0)],
                [(B, 0), IDLE])
    assert "MK-P005" in errors_of(check_step_program(bad, 1, 2))


def test_malformed_entry_fires_p006():
    bad = _prog([(F, 0), (7, 0)], [IDLE, (F, 0)],
                [IDLE, (B, 0)], [(B, 0), IDLE])
    assert "MK-P006" in errors_of(check_step_program(bad, 1, 2))


def test_stash_bound_violation_fires_p007():
    # a valid gpipe program stashes M=4 per stage; judged against the
    # 1f1b analytic bound min(M, S)=2 it must overflow
    prog = make_step_program(4, 2, "gpipe")
    assert "MK-P007" in errors_of(
        check_step_program(prog, 4, 2, schedule="1f1b"))


def test_chunkless_entry_in_interleaved_program_fires_p008():
    prog = [list(r) for r in
            make_step_program(2, 2, "interleaved", virtual_stages=2)]
    t, s = next((t, s) for t, row in enumerate(prog)
                for s, e in enumerate(row) if e[0] == F)
    prog[t][s] = (F, prog[t][s][1])          # drop the chunk index
    assert "MK-P008" in errors_of(check_step_program(
        prog, 2, 2, schedule="interleaved", virtual_stages=2))


def test_chunk_index_out_of_range_fires_p008():
    prog = [list(r) for r in
            make_step_program(2, 2, "interleaved", virtual_stages=2)]
    t, s = next((t, s) for t, row in enumerate(prog)
                for s, e in enumerate(row) if e[0] == F)
    op, m, _ = prog[t][s]
    prog[t][s] = (op, m, 5)                  # only chunks 0..1 exist
    assert "MK-P008" in errors_of(check_step_program(
        prog, 2, 2, schedule="interleaved", virtual_stages=2))


def test_early_chunk_wrap_forward_fires_p009():
    # S=2, v=2, M=1: chunk 1's first forward (virtual stage q=2, back on
    # device 0) moved to the tick its producer (q=1, device 1) runs —
    # the wrap transfer rides the same one-tick ring and can't be early
    CI = (I, 0, 0)
    bad = (
        ((F, 0, 0), CI),
        ((F, 0, 1), (F, 0, 0)),   # F(q=2) early: producer F(q=1) same tick
        (CI, (F, 0, 1)),
        (CI, (B, 0, 1)),
        ((B, 0, 1), CI),
        (CI, (B, 0, 0)),
        ((B, 0, 0), CI),
    )
    errs = errors_of(check_step_program(
        bad, 1, 2, schedule="interleaved", virtual_stages=2))
    assert "MK-P009" in errs, errs


def test_unnamed_schedule_reports_peak_as_info():
    diags = check_step_program(GOOD_2x1, 1, 2, schedule=None)
    peak = [d for d in diags if d.rule == "MK-P007"]
    assert peak and all(d.severity is Severity.INFO for d in peak)


def test_check_program_raises_diagnostic_valueerror():
    bad = _prog([(F, 0), IDLE], [IDLE, (F, 0)],
                [IDLE, (B, 0)], [IDLE, IDLE])
    with pytest.raises(ValueError) as ei:
        _check_program(bad, 1, 2, schedule="gpipe")
    assert isinstance(ei.value, DiagnosticError)
    assert "MK-P003" in str(ei.value)
    assert ei.value.diagnostics          # structured records ride along


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 8), st.integers(1, 5),
       st.sampled_from(["gpipe", "1f1b"]), st.integers(0, 10_000))
def test_property_generated_programs_verify_and_mutations_fail(
        m, s, sched, seed):
    prog = make_step_program(m, s, sched)
    diags = check_step_program(prog, m, s, schedule=sched)
    assert not errors_of(diags), (m, s, sched, diags)

    # knock out one scheduled micro-step: the verifier must object
    busy = [(t, st_) for t, row in enumerate(prog)
            for st_, (op, _) in enumerate(row) if op != I]
    t, st_ = busy[seed % len(busy)]
    bad = [list(row) for row in prog]
    bad[t][st_] = IDLE
    mutated = _prog(*bad)
    assert errors_of(check_step_program(mutated, m, s, schedule=sched))


# ---------------------------------------------------------------- MK-M

def test_mesh_cli_malformed_literal_fires_m001():
    assert "MK-M001" in rules_of(check_mesh_cli("2,x", "data,model", 1))
    assert "MK-M001" in rules_of(check_mesh_cli("0,2", "data,model", 1))


def test_mesh_cli_rank_disagreements_fire_m002():
    assert "MK-M002" in rules_of(check_mesh_cli(None, "data,model", 1))
    assert "MK-M002" in rules_of(check_mesh_cli("2,2,2", "data,model", 1))
    assert "MK-M002" in rules_of(check_mesh_cli("2,2,2,2", None, 1))


def test_mesh_cli_axis_typo_fires_m003_with_hint():
    diags = check_mesh_cli("2,2", "data,modle", 1)
    (d,) = [d for d in diags if d.rule == "MK-M003"]
    assert "model" in d.hint


def test_mesh_cli_duplicate_axis_fires_m004():
    assert "MK-M004" in rules_of(check_mesh_cli("2,2", "data,data", 1))


def test_mesh_cli_stage_size_mismatch_fires_m005_both_ways():
    assert "MK-M005" in rules_of(
        check_mesh_cli("2,2,2", "stage,data,model", 4))
    assert "MK-M005" in rules_of(
        check_mesh_cli("2,2,2", "stage,data,model", 1))


def test_mesh_cli_ignored_model_par_warns_m006():
    diags = check_mesh_cli("2,4,2", "stage,data,model", 2, model_par=4)
    (d,) = [d for d in diags if d.rule == "MK-M006"]
    assert d.severity is Severity.WARNING


def test_resolve_mesh_cli_accepts_the_conventional_forms():
    assert resolve_mesh_cli(None, None, 1) == (None, None, [])
    shape, names, diags = resolve_mesh_cli("2,2,2", None, 2)
    assert (shape, names) == ((2, 2, 2), ("stage", "data", "model"))
    assert not diags


def test_parse_mesh_cli_raises_diagnostic_valueerror():
    from repro.launch.train import parse_mesh_cli
    with pytest.raises(ValueError) as ei:
        parse_mesh_cli("2,2", "data,modle", 1)
    assert "MK-M003" in str(ei.value)


# ---------------------------------------------------------------- MK-S

def test_spec_unknown_axis_fires_s001():
    diags = check_spec(P("modle"), (8,), {"data": 2}, "t")
    assert "MK-S001" in errors_of(diags)


def test_spec_known_but_absent_axis_is_the_sanitize_path():
    # param_specs names "model" even on model-less meshes by design
    assert not check_spec(P("model"), (8,), {"data": 2}, "t")


def test_spec_axis_in_two_dims_fires_s004():
    diags = check_spec(P("data", "data"), (4, 4), {"data": 2}, "t")
    assert "MK-S004" in errors_of(diags)


def test_spec_rank_excess_fires_s005():
    diags = check_spec(P("data", None, None), (8,), {"data": 2}, "t")
    assert "MK-S005" in errors_of(diags)


def test_nondividing_dim_warns_s002_outside_islands():
    diags = check_spec(P("model"), (6,), {"model": 4}, "t")
    assert rules_of(diags) == {"MK-S002"}
    assert not errors_of(diags)


def test_nondividing_model_dim_inside_island_fires_s003():
    diags = check_spec(P("model"), (6,), {"model": 4}, "t",
                       manual_axes=("stage", "model"))
    assert "MK-S003" in errors_of(diags)


def test_constraint_naming_manual_axis_fires_s006():
    diags = check_spec(P("stage"), (8,), {"stage": 2}, "t",
                       manual_axes=("stage",), constraint=True)
    assert "MK-S006" in errors_of(diags)


# ---------------------------------------------------------------- MK-K

def test_repo_kernels_pass_geometry_checks():
    diags = check_repo_kernels()
    assert not errors_of(diags), [d.format() for d in diags]


def _rec(out_spec, out_shape=(128,), grid=(2,)):
    return PallasCallRecord(
        name="fixture", grid=grid, in_specs=[], out_specs=[out_spec],
        out_shapes=[out_shape], operand_shapes=[])


def test_nondividing_block_fires_k001():
    from jax.experimental import pallas as pl
    rec = _rec(pl.BlockSpec((48,), lambda i: (i,)))
    assert "MK-K001" in errors_of(check_pallas_call(rec))


def test_out_of_bounds_index_map_fires_k002():
    from jax.experimental import pallas as pl
    rec = _rec(pl.BlockSpec((64,), lambda i: (i + 1,)))
    assert "MK-K002" in errors_of(check_pallas_call(rec))


def test_uncovered_output_block_fires_k003():
    from jax.experimental import pallas as pl
    rec = _rec(pl.BlockSpec((64,), lambda i: (0,)))
    assert "MK-K003" in errors_of(check_pallas_call(rec))


def test_good_record_is_clean():
    from jax.experimental import pallas as pl
    rec = _rec(pl.BlockSpec((64,), lambda i: (i,)))
    assert not check_pallas_call(rec)


# ------------------------------------------------------- verify_launch

def test_verify_launch_single_stage_is_clean_and_timed():
    report = verify_launch("granite-3-8b", smoke=True, global_batch=4,
                           seq_len=64, check_kernels=False)
    assert report.ok, report.format()
    assert report.wall_s > 0


def test_verify_launch_unknown_schedule_fires_l004():
    report = verify_launch("granite-3-8b", smoke=True, global_batch=4,
                           seq_len=64, schedule="zigzag",
                           check_kernels=False, trace_collectives=False)
    assert "MK-L004" in report.rules_fired()
    assert not report.ok


def test_verify_launch_conflicting_kernel_modes_fires_l006():
    report = verify_launch("granite-3-8b", smoke=True, global_batch=4,
                           seq_len=64,
                           flags=("kernels_ref", "kernels_pallas"),
                           check_kernels=False, trace_collectives=False)
    assert "MK-L006" in report.rules_fired()
    assert not report.ok


def test_verify_launch_virtual_stages_misuse_fires_l007():
    # v>1 outside the interleaved schedule; single-stage keeps the mesh
    # in-process friendly — the rule fires before any plan is built
    report = verify_launch("granite-3-8b", smoke=True, global_batch=8,
                           seq_len=64, schedule="1f1b", virtual_stages=2,
                           check_kernels=False, trace_collectives=False)
    assert "MK-L007" in report.rules_fired()
    assert not report.ok
    # nonsensical v
    report = verify_launch("granite-3-8b", smoke=True, global_batch=8,
                           seq_len=64, schedule="interleaved",
                           virtual_stages=0,
                           check_kernels=False, trace_collectives=False)
    assert "MK-L007" in report.rules_fired()


def test_verify_launch_kernels_pallas_flag_is_clean():
    report = verify_launch("granite-3-8b", smoke=True, global_batch=4,
                           seq_len=64, flags=("kernels_pallas",),
                           check_kernels=False)
    assert report.ok, report.format()


def test_verify_launch_mesh_errors_short_circuit():
    report = verify_launch("granite-3-8b", smoke=True,
                           mesh_shape="2,2", axes="data,modle",
                           check_kernels=False, trace_collectives=False)
    assert report.rules_fired() == {"MK-M003"}
    assert not report.ok


def test_rule_ids_are_stable():
    # the catalog is a public contract: additions fine, renames are not
    expected = {f"MK-{fam}{i:03d}"
                for fam, n in (("C", 5), ("P", 9), ("S", 6), ("K", 3),
                               ("M", 6), ("L", 7), ("R", 2))
                for i in range(1, n + 1)}
    assert expected <= set(RULES)


# ---------------------------------------------------------------- MK-R

V2_MANIFEST = {
    "version": 2, "step": 10, "tag": "periodic", "extra": {},
    "leaves": [
        {"key": "w", "shape": [8, 4], "dtype": "float32",
         "spec": ["stage", None],
         "mesh": {"axes": ["stage", "data"], "shape": [4, 2]},
         "shards": [{"file": "shards/L0000_S000.npy",
                     "index": [[0, 8], [0, 4]], "nbytes": 128,
                     "crc32": 0}]},
    ],
}


def test_restore_manifest_good_is_clean():
    diags = check_restore_manifest(V2_MANIFEST, like={"w": (8, 4)},
                                   mesh={"stage": 4, "data": 2})
    assert diags == []


def test_restore_manifest_truncated_fires_r001():
    diags = check_restore_manifest({"version": 2}, like={"w": (8, 4)})
    assert errors_of(diags) == {"MK-R001"}


def test_restore_manifest_missing_and_extra_leaves_fire_r001():
    diags = check_restore_manifest(V2_MANIFEST,
                                   like={"w": (8, 4), "gone": (2,)})
    assert errors_of(diags) == {"MK-R001"}
    diags = check_restore_manifest(V2_MANIFEST, like={})
    assert errors_of(diags) == {"MK-R001"}


def test_restore_manifest_shape_mismatch_fires_r001():
    diags = check_restore_manifest(V2_MANIFEST, like={"w": (8, 8)})
    assert errors_of(diags) == {"MK-R001"}
    assert any("global shape" in d.msg for d in diags)


def test_restore_manifest_malformed_leaf_record_fires_r001():
    bad = dict(V2_MANIFEST, leaves=[{"key": "w"}])
    diags = check_restore_manifest(bad, like=None)
    assert errors_of(diags) == {"MK-R001"}


def test_restore_manifest_unrealizable_spec_warns_not_errors():
    # restore mesh has no 'stage' axis: legal, lands replicated
    diags = check_restore_manifest(V2_MANIFEST, like={"w": (8, 4)},
                                   mesh={"data": 2, "model": 2})
    assert rules_of(diags) == {"MK-R001"}
    assert not errors_of(diags)
    # stage axis present but 8 % 3 != 0: same — warning only
    diags = check_restore_manifest(V2_MANIFEST, like={"w": (8, 4)},
                                   mesh={"stage": 3, "data": 2})
    assert rules_of(diags) == {"MK-R001"} and not errors_of(diags)


def test_elastic_shrink_too_deep_fires_r002():
    diags = check_shrink(n_repeats=2, n_stages=3)
    assert errors_of(diags) == {"MK-R002"}
    assert check_shrink(n_repeats=4, n_stages=3) == []


def test_elastic_shrink_virtual_stage_overflow_fires_r002():
    diags = check_shrink(n_repeats=4, n_stages=2, virtual_stages=3)
    assert errors_of(diags) == {"MK-R002"}
    assert "--virtual-stages" in diags[0].hint


# ------------------------------------------------- subprocess end-to-end

def _run(script_or_cmd, env_devices=None, timeout=600):
    if isinstance(script_or_cmd, str):
        cmd = [sys.executable, "-c", textwrap.dedent(script_or_cmd)]
    else:
        cmd = script_or_cmd
    return subprocess.run(cmd, capture_output=True, text=True,
                          cwd=REPO, timeout=timeout)


def test_cli_bench_smoke_preset_is_clean_and_fast():
    r = _run([sys.executable, str(REPO / "tools" / "mklint.py"),
              "--preset", "bench-smoke"])
    out = r.stdout + r.stderr
    assert r.returncode == 0, out
    assert "7/7 configs clean" in out
    # satellite contract: per-config static verification stays under ~2s
    import re
    walls = [float(w) for w in re.findall(r"clean \((\d+\.\d+)s\)", out)]
    assert len(walls) == 7, out
    assert all(w < 2.0 for w in walls), walls


def test_cli_interleaved_needs_enough_repeats_l001():
    # granite smoke has n_repeats=2 < virtual_stages*stages=4
    r = _run([sys.executable, str(REPO / "tools" / "mklint.py"),
              "--arch", "granite-3-8b", "--smoke", "--stages", "2",
              "--data-par", "1", "--microbatch", "2",
              "--schedule", "interleaved", "--virtual-stages", "2",
              "--global-batch", "8", "--seq-len", "64"])
    out = r.stdout + r.stderr
    assert r.returncode == 1, out
    assert "MK-L001" in out


def test_cli_reports_bad_arithmetic_and_exits_nonzero():
    r = _run([sys.executable, str(REPO / "tools" / "mklint.py"),
              "--arch", "granite-3-8b", "--smoke", "--stages", "2",
              "--data-par", "4", "--microbatch", "3",
              "--global-batch", "8", "--seq-len", "64"])
    out = r.stdout + r.stderr
    assert r.returncode == 1, out
    assert "MK-L003" in out


def test_train_verify_refuses_misaligned_branch_collective():
    # sabotage the block apply inside the pipeline island with a
    # data-dependent one-sided psum; --verify must catch it (MK-C002)
    # and refuse before anything is built
    script = """
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=8"
        import sys
        import jax
        import repro.models.pipeline as MP

        real = MP._apply_block

        def evil(p, spec, cfg, x, enc):
            x, a = real(p, spec, cfg, x, enc)
            pred = jax.lax.axis_index("model") == 0
            x = jax.lax.cond(
                pred, lambda v: jax.lax.psum(v, "model"),
                lambda v: v, x)
            return x, a

        MP._apply_block = evil
        sys.argv = ["train", "--arch", "granite-3-8b", "--smoke",
                    "--steps", "1", "--global-batch", "8",
                    "--seq-len", "64", "--stages", "2",
                    "--model-par", "2", "--microbatch", "2", "--verify"]
        from repro.launch.train import main
        main()
    """
    r = _run(script)
    out = r.stdout + r.stderr
    assert r.returncode != 0, out
    assert "MK-C002" in out
    assert "refusing to launch" in out


def test_train_verify_clean_config_proceeds(tmp_path):
    script = f"""
        import sys
        sys.argv = ["train", "--arch", "granite-3-8b", "--smoke",
                    "--steps", "1", "--global-batch", "4",
                    "--seq-len", "64", "--verify",
                    "--ckpt-dir", {str(tmp_path / "ckpt")!r}]
        from repro.launch.train import main
        main()
    """
    r = _run(script)
    out = r.stdout + r.stderr
    assert r.returncode == 0, out
    assert "clean" in out
