"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, list_archs
from repro.models import (abstract_params, decode_step, forward, init_cache,
                          init_params, loss_fn)
from repro.models.common import LayerKind, ShapeSpec, tp_align

SMOKE_SHAPE = ShapeSpec("smoke", seq_len=64, global_batch=2, kind="train")


def _batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.num_patches:
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_patches, cfg.d_model)) * 0.02,
            cfg.dtype)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_frames, cfg.d_model)) * 0.02,
            cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    h, aux = jax.jit(lambda p, b: forward(
        p, cfg, b["tokens"], b.get("patch_embeds"), b.get("frames")))(
        params, batch)
    S_total = 64 + (cfg.num_patches or 0)
    assert h.shape == (2, S_total, cfg.d_model)
    assert np.isfinite(np.asarray(h, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_no_nans(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(lambda q: loss_fn(q, cfg, b))(p)
        new_p = jax.tree.map(lambda a, g: a - 1e-3 * g.astype(a.dtype),
                             p, grads)
        return loss, new_p

    loss, new_p = step(params, batch)
    assert np.isfinite(float(loss)), f"{arch} loss={loss}"
    # loss should start near ln(vocab) for random params
    assert 0.0 < float(loss) < 2 * np.log(cfg.vocab_size) + 5
    flat = jax.tree.leaves(new_p)
    assert all(np.isfinite(np.asarray(x, np.float32)).all() for x in flat)
    # a second step must change the loss (training is live)
    loss2, _ = step(new_p, batch)
    assert float(loss2) != float(loss)


@pytest.mark.parametrize("arch", list_archs())
def test_decode_step(arch):
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.key(0))
    B, S_max = 2, 32
    cache = init_cache(cfg, B, S_max)
    if cfg.is_encdec:
        cache["enc_out"] = jnp.asarray(
            np.random.default_rng(0).normal(
                size=(B, cfg.enc_frames, cfg.d_model)) * 0.02, cfg.dtype)
    token = jnp.ones((B, 1), jnp.int32)
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t))
    logits, cache = step(params, cache, token)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["cur"]) == 1
    # a few more steps: cache advances, logits stay finite
    for _ in range(3):
        logits, cache = step(params, cache, token)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache["cur"]) == 4


@pytest.mark.parametrize("arch", list_archs())
def test_abstract_params_match_real(arch):
    cfg = get_smoke(arch)
    abs_tree = abstract_params(cfg)
    real = init_params(cfg, jax.random.key(0))
    abs_leaves = jax.tree.leaves(abs_tree)
    real_leaves = jax.tree.leaves(real)
    assert len(abs_leaves) == len(real_leaves)
    for a, r in zip(abs_leaves, real_leaves):
        assert a.shape == r.shape and a.dtype == r.dtype


def test_tp_align_paddings():
    from repro.configs import get_config
    cfg = tp_align(get_config("llama4-scout-17b-a16e"), tp=16)
    assert cfg.q_heads == 48 and cfg.kv_heads == 16
    assert cfg.vocab % (16 * 128) == 0
    cfg = tp_align(get_config("whisper-base"), tp=16)
    assert cfg.q_heads == 16 and cfg.kv_heads == 16
    cfg = tp_align(get_config("granite-3-8b"), tp=16)
    assert cfg.vocab % 2048 == 0 and cfg.vocab >= 49155


def test_head_padding_is_inert():
    """Padded q-heads must not change the forward output."""
    cfg = get_smoke("llama4-scout-17b-a16e")      # 5 heads, kv 1
    cfg_pad = tp_align(cfg, tp=2)                 # pads heads 5→6, kv 1→2
    params = init_params(cfg_pad, jax.random.key(0))
    batch = _batch(cfg_pad)
    h, _ = forward(params, cfg_pad, batch["tokens"])
    # zero the padded head's o-proj (init already does) and perturb its
    # q-proj: output must be identical
    import jax.tree_util as jtu
    def perturb(p):
        wq = p["layers"][0]["mixer"]["wq"]
        wq = wq.at[:, :, cfg.num_heads:, :].add(1.0)
        p = jax.tree.map(lambda x: x, p)  # copy
        p["layers"][0]["mixer"]["wq"] = wq
        return p
    h2, _ = forward(perturb(params), cfg_pad, batch["tokens"])
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(h2, np.float32), atol=1e-5)
