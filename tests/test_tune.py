"""Autotuner tests: candidate legality (every enumerated block config
passes the mklint MK-K geometry screen), deterministic cache round-trips,
and rejection of corrupted/stale cache entries.

Property-based variants run under hypothesis when it is installed
(`pip install -e .[dev]`); the deterministic unit tests below cover the
same invariants on fixed cases either way.
"""
import json

import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import tune

# fixed per-kernel shapes, including dims that don't divide the defaults
SHAPES = {
    "flash_attention": [(1, 128, 2, 16), (2, 96, 4, 16)],
    "fused_mlp": [(128, 64, 192), (136, 32, 80)],
    "fused_rmsnorm": [(128, 64), (96, 48)],
    "moe_gmm": [(4, 64, 64, 128), (2, 24, 40, 48)],
}


# --------------------------------------------------- candidate legality
@pytest.mark.parametrize("kernel", list(tune.KERNELS))
def test_all_candidates_pass_mkk(kernel):
    """Every config `enumerate_candidates` emits survives the MK-K
    screen — the tuner never times (let alone caches) an illegal
    geometry."""
    for shape in SHAPES[kernel]:
        cands = tune.enumerate_candidates(kernel, shape)
        assert cands, (kernel, shape)
        for config in cands:
            diags = tune.validate_candidate(kernel, shape, config)
            assert not diags, (kernel, shape, config, diags)


def test_candidates_divide_their_dims():
    for kernel, dims in tune.PARAM_DIMS.items():
        for shape in SHAPES[kernel]:
            for config in tune.enumerate_candidates(kernel, shape):
                for param, axis in dims.items():
                    assert shape[axis] % config[param] == 0, (
                        kernel, shape, config)


def test_enumerate_deterministic_and_capped():
    a = tune.enumerate_candidates("moe_gmm", (4, 64, 64, 128),
                                  max_candidates=8)
    b = tune.enumerate_candidates("moe_gmm", (4, 64, 64, 128),
                                  max_candidates=8)
    assert a == b and len(a) <= 8


def test_validate_rejects_bad_configs():
    # wrong keys
    assert tune.validate_candidate("fused_rmsnorm", (128, 64), {"bff": 64})
    # non-dividing block
    assert tune.validate_candidate("fused_rmsnorm", (128, 64), {"bm": 48})
    # unknown kernel
    assert tune.validate_candidate("nope", (8,), {})


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=8))
def test_candidates_pass_mkk_property(tm, fm, dm):
    """Property form: arbitrary small fused_mlp shapes (multiples of odd
    and even factors) always yield a non-empty, fully-legal candidate
    set."""
    shape = (8 * tm, 16, 16 * fm * dm)
    for config in tune.enumerate_candidates("fused_mlp", shape):
        assert not tune.validate_candidate("fused_mlp", shape, config)


# ----------------------------------------------------- cache round-trip
def test_cache_roundtrip(tmp_path):
    path = str(tmp_path / "tune.json")
    cache = tune.load_cache(path)
    assert cache == {"version": tune.CACHE_VERSION, "entries": {}}
    key = tune.cache_key("fused_rmsnorm", (128, 64), "float32", tp=1)
    cache["entries"][key] = {"config": {"bm": 64}, "us": 12.5,
                             "n_candidates": 4}
    tune.save_cache(cache, path)
    assert tune.load_cache(path) == cache
    # byte-deterministic: saving the same cache twice is identical
    first = open(path).read()
    tune.save_cache(tune.load_cache(path), path)
    assert open(path).read() == first
    got = tune.cached_config("fused_rmsnorm", (128, 64), "float32",
                             tp=1, path=path)
    assert got == {"bm": 64}


def test_cached_config_misses(tmp_path):
    path = str(tmp_path / "tune.json")
    assert tune.cached_config("fused_rmsnorm", (128, 64), "float32",
                              path=path) == {}
    # tp degree is part of the key: tp=2 never sees a tp=1 entry
    cache = tune.load_cache(path)
    key = tune.cache_key("fused_rmsnorm", (128, 64), "float32", tp=1)
    cache["entries"][key] = {"config": {"bm": 64}}
    tune.save_cache(cache, path)
    assert tune.cached_config("fused_rmsnorm", (128, 64), "float32",
                              tp=2, path=path) == {}


# ------------------------------------------- corrupt / stale rejection
@pytest.mark.parametrize("payload", [
    "not json at all{",
    json.dumps([1, 2, 3]),
    json.dumps({"version": 999, "entries": {}}),
    json.dumps({"version": tune.CACHE_VERSION, "entries": "nope"}),
])
def test_corrupt_cache_degrades_to_empty(tmp_path, payload):
    path = str(tmp_path / "tune.json")
    with open(path, "w") as fh:
        fh.write(payload)
    assert tune.load_cache(path) == {"version": tune.CACHE_VERSION,
                                     "entries": {}}
    assert tune.cached_config("fused_rmsnorm", (128, 64), "float32",
                              path=path) == {}


def test_stale_entry_rejected_and_retuned(tmp_path, monkeypatch):
    """An entry whose config no longer passes MK-K for its own key (a
    hand-edited cache, or kernel geometry rules that tightened) is
    ignored by `cached_config` and overwritten by the next `tune`."""
    path = str(tmp_path / "tune.json")
    key = tune.cache_key("fused_rmsnorm", (128, 64), "float32", tp=1)
    cache = {"version": tune.CACHE_VERSION,
             "entries": {key: {"config": {"bm": 48},     # 48 ∤ 128
                               "us": 1.0, "n_candidates": 1}}}
    tune.save_cache(cache, path)
    assert tune.cached_config("fused_rmsnorm", (128, 64), "float32",
                              tp=1, path=path) == {}
    # re-tune (stub timing: no kernel execution in this unit test)
    monkeypatch.setattr(tune, "_get_time_fn",
                        lambda: (lambda fn, *a, **k: 1.0))
    entry = tune.tune("fused_rmsnorm", (128, 64), "float32", path=path)
    assert 128 % entry["config"]["bm"] == 0
    stored = tune.load_cache(path)["entries"][key]
    assert stored["config"] == entry["config"]
    assert tune.cached_config("fused_rmsnorm", (128, 64), "float32",
                              tp=1, path=path) == entry["config"]


def test_tune_deterministic_with_stubbed_timer(tmp_path, monkeypatch):
    """With timing held constant, `tune` is a pure function of the
    candidate enumeration — two runs pick the same config."""
    calls = []

    def fake_time_fn(fn, *args, **kw):
        calls.append(1)
        return 1.0
    monkeypatch.setattr(tune, "_get_time_fn", lambda: fake_time_fn)
    p1, p2 = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    e1 = tune.tune("fused_mlp", (128, 64, 192), "float32", path=p1)
    e2 = tune.tune("fused_mlp", (128, 64, 192), "float32", path=p2)
    assert e1 == e2 and calls
    assert e1["n_candidates"] >= 1


@settings(max_examples=10, deadline=None)
@given(st.sampled_from(sorted(SHAPES)), st.integers(0, 3))
def test_cache_roundtrip_property(tmp_path_factory, kernel, i):
    """Property form: any entry written for any kernel/shape cell reads
    back identically through `cached_config`."""
    shape = SHAPES[kernel][i % len(SHAPES[kernel])]
    path = str(tmp_path_factory.mktemp("tune") / "c.json")
    config = tune.enumerate_candidates(kernel, shape)[0]
    cache = tune.load_cache(path)
    cache["entries"][tune.cache_key(kernel, shape, "float32", 1)] = {
        "config": config, "us": 1.0, "n_candidates": 1}
    tune.save_cache(cache, path)
    assert tune.cached_config(kernel, shape, "float32", 1,
                              path=path) == config


# ------------------------------------------------- dispatch integration
def test_dispatch_block_config_uses_cache(tmp_path, monkeypatch):
    from repro.kernels import dispatch
    path = str(tmp_path / "tune.json")
    monkeypatch.setattr(tune, "DEFAULT_CACHE", path)
    # miss → kernel defaults
    assert dispatch.block_config("fused_rmsnorm", (128, 64),
                                 "float32") == {"bm": 256}
    cache = tune.load_cache(path)
    cache["entries"][tune.cache_key("fused_rmsnorm", (512, 64),
                                    "float32", 1)] = {
        "config": {"bm": 64}, "us": 1.0, "n_candidates": 2}
    tune.save_cache(cache, path)
    assert dispatch.block_config("fused_rmsnorm", (512, 64),
                                 "float32") == {"bm": 64}
