"""Unit tests for the loop-aware HLO analyzer — the roofline meter."""
import textwrap

from repro.launch.hloanalysis import analyze_hlo, parse_computations


HLO = textwrap.dedent("""
    HloModule test

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %r = f32[] add(%a, %b)
    }

    %body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
      %p = (s32[], f32[128,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
      %d = f32[128,128]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[128,128]{1,0} all-reduce(%d), replica_groups={}, to_apply=%add
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[128,128]) tuple(%ni, %ar)
    }

    %cond (p: (s32[], f32[128,128])) -> pred[] {
      %p = (s32[], f32[128,128]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %k = s32[] constant(10)
      ROOT %lt = pred[] compare(%i, %k), direction=LT
    }

    ENTRY %main (x: f32[128,128]) -> f32[128,128] {
      %x = f32[128,128]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %t0 = (s32[], f32[128,128]) tuple(%zero, %x)
      %w = (s32[], f32[128,128]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out = f32[128,128]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_parse_computations():
    comps, entry = parse_computations(HLO)
    assert entry == "main"
    assert {"add", "body", "cond", "main"} <= set(comps)
    assert any(i.op == "while" for i in comps["main"].instrs)
    assert any(i.op == "dot" for i in comps["body"].instrs)


def test_loop_aware_flops():
    st = analyze_hlo(HLO)
    # one 128³ dot per iteration × 10 iterations
    assert st.flops == 2 * 128 ** 3 * 10


def test_loop_aware_collectives():
    st = analyze_hlo(HLO)
    # all-reduce of 64KiB × 2 (ring multiplier) × 10 trips
    assert st.coll_bytes_by_op["all-reduce"] == 128 * 128 * 4 * 2 * 10
    assert st.coll_count_by_op["all-reduce"] == 10


def test_promoted_allreduce_counts_half():
    txt = HLO.replace("to_apply=%add", "to_apply=%add.clone_promoted")
    st = analyze_hlo(txt)
    assert st.coll_bytes_by_op["all-reduce"] == 128 * 128 * 4 * 2 * 10 / 2


def test_dus_counts_in_place():
    hlo = textwrap.dedent("""
        HloModule t
        ENTRY %main (x: f32[64,128], u: f32[1,128]) -> f32[64,128] {
          %x = f32[64,128]{1,0} parameter(0)
          %u = f32[1,128]{1,0} parameter(1)
          %i = s32[] constant(3)
          %z = s32[] constant(0)
          ROOT %d = f32[64,128]{1,0} dynamic-update-slice(%x, %u, %i, %z)
        }
    """)
    st = analyze_hlo(hlo)
    # 2 × update slice, NOT 2 × full buffer
    assert st.hbm_bytes == 2 * 128 * 4


def test_convert_only_fusion_charged_at_source_width():
    hlo = textwrap.dedent("""
        HloModule t
        %fc (p0: bf16[128,128]) -> f32[128,128] {
          %p0 = bf16[128,128]{1,0} parameter(0)
          ROOT %c = f32[128,128]{1,0} convert(%p0)
        }
        ENTRY %main (x: bf16[128,128]) -> f32[128,128] {
          %x = bf16[128,128]{1,0} parameter(0)
          %f = f32[128,128]{1,0} fusion(%x), kind=kLoop, calls=%fc
          ROOT %d = f32[128,128]{1,0} dot(%f, %f), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        }
    """)
    st = analyze_hlo(hlo)
    # dot: result f32 (64KiB) + operand charged twice at bf16 width (32KiB);
    # the convert fusion itself is free (promotion artifact)
    assert st.hbm_bytes == 128 * 128 * 4 + 2 * 128 * 128 * 2
    assert st.flops == 2 * 128 ** 3
