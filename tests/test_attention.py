"""Flash (chunked) attention vs the dense oracle: forward and gradients,
across causal/SWA/bidirectional, GQA groupings, and chunk shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import attention_ref, chunked_attention


def _qkv(B, Sq, Skv, Hq, Hkv, D, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, Hkv, D)), dtype)
    return q, k, v


CASES = [
    # (Sq, Skv, Hq, Hkv, causal, window, qc, kc)
    (128, 128, 4, 4, True, 0, 32, 32),
    (128, 128, 8, 2, True, 0, 32, 64),      # GQA
    (128, 128, 4, 4, False, 0, 32, 32),     # bidirectional (encoder)
    (128, 128, 4, 2, True, 48, 32, 32),     # sliding window
    (64, 128, 4, 4, False, 0, 32, 32),      # cross-attention Sq != Skv
    (128, 128, 4, 4, True, 0, 128, 128),    # single chunk
]


@pytest.mark.parametrize("Sq,Skv,Hq,Hkv,causal,window,qc,kc", CASES)
def test_forward_matches_oracle(Sq, Skv, Hq, Hkv, causal, window, qc, kc):
    q, k, v = _qkv(2, Sq, Skv, Hq, Hkv, 16)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_chunk=qc, kv_chunk=kc)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("Sq,Skv,Hq,Hkv,causal,window,qc,kc", CASES)
def test_flash_vjp_matches_autodiff(Sq, Skv, Hq, Hkv, causal, window, qc, kc):
    q, k, v = _qkv(2, Sq, Skv, Hq, Hkv, 16, seed=1)

    def f_flash(q, k, v):
        return (chunked_attention(q, k, v, causal=causal, window=window,
                                  q_chunk=qc, kv_chunk=kc) ** 2).sum()

    def f_ref(q, k, v):
        return (attention_ref(q, k, v, causal=causal,
                              window=window) ** 2).sum()

    g_flash = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g_flash, g_ref, "qkv"):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4,
            err_msg=f"grad d{name}")


def test_bf16_forward_close():
    q, k, v = _qkv(2, 128, 128, 4, 2, 32, seed=2, dtype=jnp.bfloat16)
    out = chunked_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=0.05, atol=0.05)


def test_decode_offset_matches_full():
    """kv_offset path: last-token attention == full causal last row."""
    q, k, v = _qkv(2, 128, 128, 4, 4, 16, seed=3)
    full = attention_ref(q, k, v, causal=True)
    tail = chunked_attention(q[:, -32:], k, v, causal=True,
                             q_chunk=32, kv_chunk=32, kv_offset=96)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[:, -32:]),
                               rtol=2e-5, atol=2e-5)


def test_swa_flops_are_subquadratic():
    """visible_pairs must exclude out-of-window chunk pairs entirely."""
    from repro.models.layers import visible_pairs
    pairs_full = visible_pairs(16, 16, causal=True, window=0,
                               q_chunk=64, kv_chunk=64)
    pairs_swa = visible_pairs(16, 16, causal=True, window=128,
                              q_chunk=64, kv_chunk=64)
    assert len(pairs_swa) < len(pairs_full)
    assert len(pairs_swa) <= 16 * 3          # ≤ ceil(window/chunk)+1 per row
