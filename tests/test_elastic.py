"""Elastic fault tolerance: shrink, re-plan, reshard, resume.

The headline test kills one stage's devices mid-run on an 8-device CPU
mesh (deterministic `FaultInjector`), and asserts the driver shrinks
the stage axis, re-plans through the mkplan cost models, reshards from
the latest sharded checkpoint, resumes at the restored data step, and
finishes with a loss trajectory within tolerance of an uninterrupted
run.  Unit tests cover the pieces jax-free where possible:
`check_shrink` (MK-R002), `choose_elastic_config`, `shrink_mesh`,
`stage_devices`, and the injector's fire-once contract.
"""
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import DiagnosticError
from repro.analysis.elastic import check_shrink
from repro.configs import get_smoke
from repro.runtime import (DeviceLossError, FaultInjector, FaultSpec,
                           choose_elastic_config, is_device_loss)


# --------------------------------------------------------------- units

def test_check_shrink_ok_heterogeneous():
    # 3 stages over 4 repeats: legal (padded per-stage stacks)
    assert check_shrink(4, 3) == []


def test_check_shrink_too_deep_fires_r002():
    diags = check_shrink(2, 3)
    assert [d.rule for d in diags] == ["MK-R002"]
    assert diags[0].is_error


def test_check_shrink_virtual_stages_fires_r002():
    assert not check_shrink(4, 2, virtual_stages=2)
    diags = check_shrink(4, 2, virtual_stages=3)
    assert [d.rule for d in diags] == ["MK-R002"]


def test_check_shrink_nothing_survives_fires_r002():
    diags = check_shrink(4, 0)
    assert [d.rule for d in diags] == ["MK-R002"]


def test_choose_elastic_config_respects_fixed_mesh():
    cfg = get_smoke("jamba-v0.1-52b")          # n_repeats = 4
    cand = choose_elastic_config(
        cfg, {"stage": 3, "data": 2, "model": 1},
        global_batch=8, seq_len=16)
    assert (cand.stages, cand.dp, cand.tp) == (3, 2, 1)
    assert cand.virtual_stages * cand.stages <= cfg.n_repeats


def test_choose_elastic_config_single_stage_collapses():
    cfg = get_smoke("granite-3-8b")
    cand = choose_elastic_config(cfg, {"stage": 1, "data": 2},
                                 global_batch=8, seq_len=16)
    assert (cand.stages, cand.schedule, cand.microbatch) == (1, "gpipe", 1)


def test_choose_elastic_config_doomed_shrink_raises():
    cfg = get_smoke("granite-3-8b")            # n_repeats = 2
    with pytest.raises(DiagnosticError) as ei:
        choose_elastic_config(cfg, {"stage": 3, "data": 1},
                              global_batch=8, seq_len=16)
    assert "MK-R002" in str(ei.value)


def test_fault_spec_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultSpec(step=1, kind="meteor_strike")


def test_injector_fires_once():
    inj = FaultInjector([FaultSpec(step=2, kind="step_error")])
    inj.poke(0)
    inj.poke(1)
    with pytest.raises(RuntimeError):
        inj.poke(2)
    inj.poke(2)                                # re-visit: already fired


def test_is_device_loss_classification():
    assert is_device_loss(DeviceLossError([0, 1]))
    assert is_device_loss(RuntimeError("DATA_LOSS: device failed"))
    assert not is_device_loss(RuntimeError("NaN loss"))
    assert not is_device_loss(ValueError("device failed"))


# ------------------------------------------ mesh surgery (8 devices)

MESH_UNITS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.launch.mesh import make_mesh
    from repro.runtime import shrink_mesh, stage_devices

    mesh = make_mesh((4, 2), ("stage", "data"))
    dead = stage_devices(mesh, 2)
    assert len(dead) == 2, dead
    small = shrink_mesh(mesh, dead, "stage")
    assert dict(small.shape) == {"stage": 3, "data": 2}
    alive = {d.id for d in small.devices.flatten()}
    assert not (alive & dead)
    # losing every stage leaves nothing
    every = set(range(8))
    assert shrink_mesh(mesh, every, "stage") is None
    try:
        stage_devices(mesh, 9)
    except ValueError:
        pass
    else:
        raise AssertionError("stage out of range accepted")
    print("OK")
""")


def test_shrink_mesh_and_stage_devices_8_devices():
    r = subprocess.run([sys.executable, "-c", MESH_UNITS],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK" in r.stdout


# ------------------------------------- end-to-end: kill a stage mid-run

E2E = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import numpy as np
    import jax
    from repro.launch.train import build_elastic
    from repro.runtime import (FTConfig, FaultInjector, FaultSpec,
                               TrainDriver)

    def run(inject):
        with tempfile.TemporaryDirectory() as d:
            (cfg, mesh, state, step_fn, data, bindings,
             shardings) = build_elastic(
                "jamba-v0.1-52b", smoke=True, global_batch=8,
                seq_len=16, stages=4, microbatch=4, mesh_shape=(4, 2, 1),
                axes=("stage", "data", "model"), schedule="1f1b")
            inj = None
            if inject:
                inj = FaultInjector(
                    [FaultSpec(step=5, kind="device_loss", stage=2)],
                    mesh=mesh, ckpt_dir=d)
            drv = TrainDriver(
                step_fn, data,
                FTConfig(ckpt_dir=d, ckpt_every=3, elastic=True),
                state, shardings=shardings, mesh=mesh, elastic=bindings,
                fault_injector=inj)
            drv.run(8)
            return drv

    base = run(inject=False)
    drv = run(inject=True)

    # the shrink happened, was re-planned, and training resumed
    ev = [e for e in drv.events if e["kind"] == "shrink"]
    assert len(ev) == 1, drv.events
    assert ev[0]["at_step"] == 5 and ev[0]["lost"], ev
    assert dict(drv.mesh.shape)["stage"] == 3
    assert "stages=3" in ev[0]["config"]
    # resumed from the step-3 checkpoint, replayed deterministically:
    # exactly one metrics row per data step, no gaps, no duplicates
    steps = [m["step"] for m in drv.metrics_log]
    assert steps == list(range(8)), steps

    # loss trajectory stays within tolerance of the uninterrupted run:
    # identical data replay, same global shapes — only the partition
    # changed, so per-step losses track closely
    a = np.array([m["loss"] for m in base.metrics_log])
    b = np.array([m["loss"] for m in drv.metrics_log])
    assert np.isfinite(a).all() and np.isfinite(b).all()
    # pre-fault steps ran on the identical config: near-bitwise
    np.testing.assert_allclose(a[:5], b[:5], rtol=1e-4)
    # post-shrink steps: same data, re-partitioned math
    np.testing.assert_allclose(a[5:], b[5:], rtol=0.05, atol=0.05)
    print("OK", [round(float(x), 4) for x in b])
""")


def test_elastic_kill_one_stage_e2e_8_devices():
    r = subprocess.run([sys.executable, "-c", E2E],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK" in r.stdout


# -------------------------------------------- CLI smoke: --elastic

def test_train_cli_elastic_shrink_smoke(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "jamba-v0.1-52b", "--smoke", "--steps", "6",
           "--global-batch", "4", "--seq-len", "16",
           "--stages", "3", "--microbatch", "2",
           "--mesh-shape", "3,1,1", "--axes", "stage,data,model",
           "--schedule", "1f1b", "--elastic",
           "--inject-fail-step", "4", "--inject-fail-stage", "1",
           "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "2"]
    env = dict(__import__("os").environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=3")
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                       env=env)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "shrunk to" in r.stderr and "'stage': 2" in r.stderr
