"""MKPipe applied to the LM block itself: the planner fuses the
norm→mixer and norm→ffn stage pairs, and the fused plan is bit-equivalent
to the sequential baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core import compile_plan, optimize, plan_cke, profile_graph
from repro.models.stages import block_stage_graph, hbm_round_trips_eliminated
from repro.models.transformer import init_params


def _block(arch, seq=512, batch=2, seed=0):
    # widen the FFN so no single stage crosses the 95% dominance threshold
    # on the CPU profile (on TPU the block is naturally balanced)
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32",
                              d_ff=2048, moe_d_ff=512)
    params = init_params(cfg, jax.random.key(seed))
    block_params = jax.tree.map(lambda x: x[0], params["layers"][0])
    build = block_stage_graph(cfg, block_params, tile=128)
    graph = build(seq)
    rng = np.random.default_rng(seed)
    buffers = {"x": jnp.asarray(
        rng.normal(size=(batch, seq, cfg.d_model)) * 0.2, jnp.float32)}
    return cfg, graph, buffers


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-370m",
                                  "qwen3-moe-30b-a3b"])
def test_planner_decides_lm_block(arch):
    cfg, graph, buffers = _block(arch)
    graph = profile_graph(graph, buffers, repeats=1)
    plan = plan_cke(graph, channel_threshold_s=0.0)   # prefer fusion
    mechs = {f"{e.producer}->{e.consumer}": e.mechanism for e in plan.edges}
    if arch == "mamba2-370m":
        # 2-stage block: the SSD mixer is >95% of the profile → the Fig. 5
        # tree correctly declares a dominant kernel (balancing, no CKE)
        assert plan.dominant == "mixer"
        assert plan.balancing == "resource"
        return
    # norm→mixer and norm→ffn are one-to-one over token tiles → fused
    assert mechs.get("ln1->mixer") in ("fuse", "channel")
    if "ln2->ffn" in mechs:
        assert mechs["ln2->ffn"] in ("fuse", "channel")


@pytest.mark.parametrize("arch", ["granite-3-8b", "mamba2-370m"])
def test_fused_block_matches_sequential(arch):
    cfg, graph, buffers = _block(arch)
    ref = graph.run_reference(buffers)
    graph = profile_graph(graph, buffers, repeats=1)
    plan = plan_cke(graph, channel_threshold_s=0.0)
    for mode in (None, "kbk"):
        out = compile_plan(plan, mode=mode)(buffers)
        np.testing.assert_allclose(
            np.asarray(out["x_out"]), np.asarray(ref["x_out"]),
            rtol=2e-4, atol=2e-4, err_msg=f"{arch} mode={mode}")


def test_fusion_saves_hbm_round_trips():
    cfg, graph, buffers = _block("granite-3-8b")
    graph = profile_graph(graph, buffers, repeats=1)
    plan = plan_cke(graph, channel_threshold_s=0.0)
    saved = hbm_round_trips_eliminated(cfg, 2, 512, plan)
    assert saved, "no fused pairs reported"
    # each fused pair removes 2 × (B·S·d) bytes of intermediate traffic
    assert all(v == 2 * 2 * 512 * cfg.d_model * 4 for v in saved.values())
