"""Core MKPipe compiler tests: dependency analysis, decision tree, id
remapping, balancing, splitting, and plan-equivalence numerics."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (
    AffineTileMap, Stage, StageGraph, StageProfile,
    analyze_edge, analyze_graph, build_id_queue, validate_queue,
    compile_plan, plan_cke, profile_graph,
    Factors, realize_factors, resource_balance, throughput_balance,
    ResourceModel, ChipSpec, explore_split, eru, kbk_timeline, cke_timeline,
)
from repro.core.depanalysis import (_dependency_sets_enum, dependency_sets,
                                    merge_deps)
from repro.core.executor import _run_globalmem_pair
from repro.core.idremap import RemapPlan, is_identity, pipeline_makespan
from repro import workloads


# ---------------------------------------------------------------- helpers
def _simple_stage(name, grid, maps, reads=("a",), writes=("b",), t=1.0,
                  fn=None):
    return Stage(name, fn or (lambda env: {}), reads=reads, writes=writes,
                 grid=grid, tile_maps=maps,
                 profile=StageProfile(time_s=t, out_bytes=1024))


# ------------------------------------------------- dependency classification
def test_one_to_one_classification():
    m = AffineTileMap.identity_1d(8)
    p = _simple_stage("p", (16,), {"b": m}, reads=("a",), writes=("b",))
    c = _simple_stage("c", (16,), {"b": m}, reads=("b",), writes=("d",))
    g = StageGraph([p, c], inputs=("a",), outputs=("d",))
    info = analyze_edge(g, "p", "c", "b")
    assert info.category == "few-to-few"
    assert info.one_to_one


def test_few_to_many_classification():
    # producer tile b writes row-block b; consumer (i,j) reads block i
    wm = AffineTileMap(coeff=((8,),), const=(0,), block=(8,))
    rm = AffineTileMap(coeff=((8, 0),), const=(0,), block=(8,))
    p = _simple_stage("p", (16,), {"b": wm})
    c = Stage("c", lambda env: {}, reads=("b",), writes=("d",),
              grid=(16, 16), tile_maps={"b": rm},
              profile=StageProfile(1.0))
    g = StageGraph([p, c], inputs=("a",), outputs=("d",))
    info = analyze_edge(g, "p", "c", "b")
    assert info.max_fan_in == 1
    assert info.max_fan_out == 16
    assert info.category == "few-to-many"


def test_many_to_few_classification():
    # consumer tile reads the WHOLE producer output (reduction-like)
    wm = AffineTileMap.identity_1d(8)
    rm = AffineTileMap.broadcast(1, (128,))
    p = _simple_stage("p", (16,), {"b": wm})
    c = _simple_stage("c", (4,), {"b": rm}, reads=("b",), writes=("d",))
    g = StageGraph([p, c], inputs=("a",), outputs=("d",))
    info = analyze_edge(g, "p", "c", "b")
    assert info.category in ("many-to-few", "many-to-many")


def test_missing_tile_maps_is_conservative():
    p = Stage("p", lambda e: {}, reads=("a",), writes=("b",), grid=(8,))
    c = Stage("c", lambda e: {}, reads=("b",), writes=("d",), grid=(8,))
    g = StageGraph([p, c], inputs=("a",), outputs=("d",))
    assert analyze_edge(g, "p", "c", "b").category == "many-to-many"


@settings(max_examples=60, deadline=None)
@given(
    a1=st.integers(1, 6), b1=st.integers(0, 8), s1=st.integers(1, 12),
    a2=st.integers(1, 6), b2=st.integers(0, 8), s2=st.integers(1, 12),
    n1=st.integers(1, 12), n2=st.integers(1, 12),
)
def test_affine_matches_enumeration(a1, b1, s1, a2, b2, s2, n1, n2):
    """Closed-form strided-interval dependency == brute-force enumeration."""
    wm = AffineTileMap(coeff=((a1,),), const=(b1,), block=(s1,))
    rm = AffineTileMap(coeff=((a2,),), const=(b2,), block=(s2,))
    p = _simple_stage("p", (n1,), {"b": wm})
    c = _simple_stage("c", (n2,), {"b": rm}, reads=("b",), writes=("d",))
    fast = dependency_sets(p, c, "b")
    slow = _dependency_sets_enum(p, c, "b")
    assert fast == slow


# --------------------------------------------------------------- id queue
def test_id_queue_identity_for_one_to_one():
    m = AffineTileMap.identity_1d(8)
    p = _simple_stage("p", (16,), {"b": m})
    c = _simple_stage("c", (16,), {"b": m}, reads=("b",), writes=("d",))
    g = StageGraph([p, c], inputs=("a",), outputs=("d",))
    info = analyze_edge(g, "p", "c", "b")
    q = build_id_queue(info)
    assert is_identity(q)
    assert validate_queue(info, q)


def test_lud_queue_is_wavefront():
    graph, _ = workloads.lud.build(nb=6)
    infos = analyze_graph(graph)
    merged = merge_deps(list(infos.values()))
    q = build_id_queue(merged)
    assert validate_queue(merged, q)
    wave = [max(cid // 6, cid % 6) for cid in q.queue]
    assert wave == sorted(wave)
    # remapping must strictly beat natural order on pipeline makespan
    natural = RemapPlan(
        queue=tuple(range(merged.n_consumer_tiles)),
        ready_after=tuple(max(merged.deps[c], default=-1) + 1
                          for c in range(merged.n_consumer_tiles)))
    assert (pipeline_makespan(merged, q, producer_rate=0.5)
            <= pipeline_makespan(merged, natural, producer_rate=0.5))


@settings(max_examples=40, deadline=None)
@given(data=st.data(), n_p=st.integers(1, 8), n_c=st.integers(1, 12))
def test_id_queue_always_legal(data, n_p, n_c):
    """Property: for any dependency structure the built queue is legal."""
    deps = tuple(
        tuple(sorted(data.draw(st.sets(st.integers(0, n_p - 1), max_size=n_p))))
        for _ in range(n_c))
    fan_out = {}
    for s in deps:
        for pid in s:
            fan_out[pid] = fan_out.get(pid, 0) + 1
    from repro.core.depanalysis import DepInfo
    info = DepInfo("p", "c", "b", deps,
                   max_fan_in=max((len(s) for s in deps), default=0),
                   max_fan_out=max(fan_out.values(), default=0),
                   n_producer_tiles=n_p, n_consumer_tiles=n_c)
    q = build_id_queue(info)
    assert validate_queue(info, q)


def test_illegal_queue_poisons_output():
    """The NaN-poisoned chunked executor must catch dependency violations."""
    graph, buffers = workloads.lud.build(nb=4)
    infos = analyze_graph(graph)
    merged = merge_deps(list(infos.values()))
    # illegal schedule: claim every consumer is ready before any producer
    bad = RemapPlan(queue=tuple(range(16)), ready_after=(0,) * 16)
    env = dict(buffers)
    _run_globalmem_pair(graph.stage("perimeter"), graph.stage("internal"),
                        bad, env)
    assert np.isnan(np.asarray(env["out"])).any()


# ----------------------------------------------------------- decision tree
@pytest.mark.parametrize("name", sorted(workloads.ALL))
def test_decision_matches_paper(name):
    mod = workloads.ALL[name]
    graph, buffers = mod.build()
    graph = profile_graph(graph, buffers, repeats=1)
    plan = plan_cke(graph)
    if name == "bfs":
        assert plan.dominant == "expand"
        assert plan.balancing == "resource"
    elif name == "hist":
        assert plan.mechanism("compute", "accumulate") == "fuse"
    elif name == "cfd":
        assert plan.mechanism("compute_flux", "time_step") in (
            "channel", "fuse")
        assert plan.mechanism("compute_step_factor", "time_step") == "sync"
    elif name == "lud":
        assert plan.mechanism("perimeter", "internal") == "globalmem"
        e = plan.edge("perimeter", "internal")
        assert e.remap is not None and not is_identity(e.remap)
    elif name == "bp":
        assert plan.groups == (("K1",), ("K2", "K3"), ("K4",))
    elif name == "tdm":
        assert plan.mechanism("filter", "detect") == "sync"
    elif name == "color":
        assert plan.mechanism("maxmin", "color") == "fuse"
    elif name == "dijkstra":
        assert plan.mechanism("relax", "select") == "channel"


# -------------------------------------------------- plan-equivalence (CKE)
@pytest.mark.parametrize("name", sorted(workloads.ALL))
def test_all_plans_bit_equivalent(name):
    mod = workloads.ALL[name]
    graph, buffers = mod.build()
    ref = graph.run_reference(buffers)
    graph = profile_graph(graph, buffers, repeats=1)
    plan = plan_cke(graph)
    for mode in (None, "kbk"):
        out = compile_plan(plan, mode=mode)(buffers)
        for k, v in ref.items():
            np.testing.assert_allclose(
                np.asarray(out[k]), np.asarray(v), rtol=2e-5, atol=1e-5,
                err_msg=f"{name} mode={mode} buffer={k}")


# ----------------------------------------------------------------- balance
def _pipeline_stages(tps=(1.0, 4.0, 2.0)):
    m = AffineTileMap.identity_1d(8)
    out = []
    for i, tp in enumerate(tps):
        out.append(Stage(
            f"s{i}", lambda e: {}, reads=("a",), writes=(f"b{i}",),
            grid=(16,), tile_maps={"a": m, f"b{i}": m},
            profile=StageProfile(time_s=1.0 / tp, out_bytes=1 << 20,
                                 flops=1e9, hbm_bytes=2 << 20)))
    return out


def test_throughput_balance_lifts_slowest():
    stages = _pipeline_stages()
    model = ResourceModel()
    res = throughput_balance(stages, model)
    n = res.n_uni()
    # slowest stage (s0, tp=1) must receive the largest factor
    assert n["s0"] >= n["s2"] >= n["s1"]
    # final configuration must not overflow resources
    assert all(v <= 1.0 for v in res.totals.values())
    # balanced throughputs should be within one grant of each other
    tps = {f"s{i}": n[f"s{i}"] * tp for i, tp in enumerate((1.0, 4.0, 2.0))}
    assert max(tps.values()) / min(tps.values()) <= 4.0


def test_throughput_balance_respects_saturation():
    stages = _pipeline_stages()
    # tiny chip: almost no headroom -> factors stay at 1
    chip = ChipSpec(peak_flops=1e9, hbm_bw=1e6)
    res = throughput_balance(stages, ResourceModel(chip))
    assert all(v == 1 for v in res.n_uni().values())


def test_resource_balance_prefers_high_impact():
    m = AffineTileMap.identity_1d(8)
    # each grant consumes ~6% of VMEM; `slow` has 100× the runtime so its
    # ΔT/ΔU dominates until its marginal benefit decays
    slow = Stage("slow", lambda e: {}, ("a",), ("b",), grid=(16,),
                 tile_maps={"a": m, "b": m},
                 profile=StageProfile(time_s=10.0, out_bytes=1 << 20,
                                      flops=1e12, hbm_bytes=64 << 20))
    fast = Stage("fast", lambda e: {}, ("b",), ("c",), grid=(16,),
                 tile_maps={"b": m, "c": m},
                 profile=StageProfile(time_s=0.1, out_bytes=1 << 20,
                                      flops=1e10, hbm_bytes=64 << 20))
    res = resource_balance([slow, fast], ResourceModel(),
                           max_unroll={"slow": 32, "fast": 32})
    n = res.n_uni()
    assert n["slow"] > n["fast"]     # ΔT/ΔU favors the long-running kernel
    assert all(v <= 1.0 for v in res.totals.values())
    for step in res.trace:
        assert step["granted"] in ("slow", "fast")


def test_realize_factors_simd_power_of_two():
    s = _pipeline_stages()[0]
    for n_uni in (1, 2, 3, 5, 8, 13, 32, 64):
        f = realize_factors(s, n_uni, max_unroll=4, vectorizable=True)
        assert f.simd & (f.simd - 1) == 0           # power of two
        assert f.unroll <= 4
        assert f.n_uni >= 1


def test_realize_factors_realizes_full_grant():
    """Regression: the greedy `unroll = min(n_uni, max_unroll)` silently
    dropped granted factors through the truncating `n_uni // unroll` —
    realize_factors(_, 12, max_unroll=8, vectorizable=False) returned
    product 8, not 12.  The realized product must equal the grant
    whenever it is realizable within the unroll/SIMD/CU bounds."""
    s = _pipeline_stages()[0]
    # the ISSUE's exact repro: 12 = 6 (a divisor ≤ 8) × cu 2
    f = realize_factors(s, 12, max_unroll=8, vectorizable=False)
    assert f.n_uni == 12 and f.simd == 1
    assert (f.unroll, f.cu) == (6, 2)    # ties prefer unroll, cheapest

    # exhaustive: every grant realizable within the bounds is realized
    def realizable(n, max_unroll, vect, max_cu=4):
        best = 0
        for u in range(1, max_unroll + 1):
            for sd in ((1, 2, 4, 8, 16) if vect else (1,)):
                for cu in range(1, max_cu + 1):
                    if u * sd * cu <= n:
                        best = max(best, u * sd * cu)
        return best

    for vect in (False, True):
        for max_unroll in (1, 2, 4, 8):
            for n in range(1, 65):
                f = realize_factors(s, n, max_unroll=max_unroll,
                                    vectorizable=vect)
                assert f.unroll <= max_unroll and f.cu <= 4
                assert f.simd & (f.simd - 1) == 0 and f.simd <= 16
                if not vect:
                    assert f.simd == 1
                assert f.n_uni == realizable(n, max_unroll, vect), \
                    (n, max_unroll, vect, f)
    # power-of-two grants (the ×2-if-SIMD path) stay exactly realized
    for n in (2, 4, 8, 16, 32):
        f = realize_factors(s, n, max_unroll=8, vectorizable=True)
        assert f.n_uni == n


# ---------------------------------------------------------------- splitting
def test_bp_splitting_isolates_k4():
    graph, _ = workloads.bp.build()
    dec = explore_split(
        graph, workloads.bp.PAPER_PROFILE, workloads.bp.PAPER_UTILS,
        pipelines=[("K2", "K3")], t_reprogram=1.4)
    assert dec.split, f"expected split, got coreside {dec.t_coreside} vs {dec.t_split}"
    a, b = dec.partition
    assert ("K4",) in (a, b)          # K4 monopolizes its own program


def test_short_workload_coresides():
    graph, _ = workloads.bp.build()
    times = {k: v / 1000.0 for k, v in workloads.bp.PAPER_PROFILE.items()}
    dec = explore_split(graph, times, workloads.bp.PAPER_UTILS,
                        pipelines=[("K2", "K3")], t_reprogram=1.4)
    assert not dec.split              # reprogram overhead dominates


def test_splitting_never_breaks_pipeline():
    graph, _ = workloads.bp.build()
    dec = explore_split(
        graph, workloads.bp.PAPER_PROFILE, workloads.bp.PAPER_UTILS,
        pipelines=[("K2", "K3")], t_reprogram=1e-9)
    a, b = dec.partition
    assert not (set(a) & {"K2", "K3"} and set(b) & {"K2", "K3"})


# --------------------------------------------------------------------- ERU
def test_eru_is_max():
    assert eru({"mxu": 0.2, "hbm_bw": 0.7, "vmem": 0.1,
                "hbm_cap": 0.3, "ici": 0.0}) == 0.7


def test_timelines_model_cke_win():
    times = {"k1": 1.0, "k2": 2.0, "k3": 2.0}
    utils = {k: {"mxu": 0.3, "hbm_bw": 0.2, "vmem": 0.1, "hbm_cap": 0.1,
                 "ici": 0.0} for k in times}
    kbk = kbk_timeline(["k1", "k2", "k3"], times, utils)
    cke = cke_timeline([("k1",), ("k2", "k3")], times, utils)
    assert kbk.makespan == 5.0
    assert cke.makespan == 3.0                      # k2 ∥ k3
    assert cke.time_weighted_eru > kbk.time_weighted_eru
