"""MoE: grouped-scatter dispatch vs dense all-experts oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import layers as L


def _setup(capacity_factor=8.0, seed=0):
    cfg = get_smoke("qwen3-moe-30b-a3b")
    cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor,
                              dtype="float32")
    p = L.init_moe(jax.random.key(seed), cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)) * 0.5, jnp.float32)
    return cfg, p, x


@pytest.mark.parametrize("n_groups", [1, 2, 4])
def test_scatter_matches_dense_with_headroom(n_groups):
    """With capacity >> demand nothing drops: scatter == dense exactly."""
    cfg, p, x = _setup(capacity_factor=16.0)
    y_dense, aux_d = L.moe_block(p, x, cfg, impl="dense")
    y_scatter, aux_s = L.moe_block(p, x, cfg, impl="scatter",
                                   n_groups=n_groups)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_scatter),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_d), float(aux_s), rtol=1e-6)


def test_capacity_drops_tokens():
    """With tight capacity some tokens drop — outputs differ from dense."""
    cfg, p, x = _setup(capacity_factor=0.25)
    y_dense, _ = L.moe_block(p, x, cfg, impl="dense")
    y_scatter, _ = L.moe_block(p, x, cfg, impl="scatter", n_groups=2)
    assert not np.allclose(np.asarray(y_dense), np.asarray(y_scatter),
                           atol=1e-4)
    assert np.isfinite(np.asarray(y_scatter)).all()


def test_moe_grads_flow():
    cfg, p, x = _setup(capacity_factor=4.0)

    def loss(p):
        y, aux = L.moe_block(p, x, cfg, impl="scatter", n_groups=2)
        return (y ** 2).sum() + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("we_up", "we_down", "we_gate", "router"):
        assert np.isfinite(np.asarray(g[name], np.float32)).all()
        assert float(jnp.abs(g[name]).sum()) > 0, f"zero grad for {name}"


def test_router_is_normalized():
    cfg, p, x = _setup()
    xf = x.reshape(-1, cfg.d_model)
    w, ids, aux = L._router(p, xf, cfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(ids.max()) < cfg.num_experts
    assert float(aux) > 0


def test_shared_expert_added():
    cfg, p, x = _setup()
    cfg_sh = dataclasses.replace(cfg, moe_shared_expert=True)
    p_sh = L.init_moe(jax.random.key(0), cfg_sh)
    y0, _ = L.moe_block({k: v for k, v in p_sh.items() if k != "shared"}
                        | {"shared": p_sh["shared"]}, x, cfg_sh)
    # zero the shared expert → same as no shared expert
    p_zero = dict(p_sh)
    p_zero["shared"] = jax.tree.map(jnp.zeros_like, p_sh["shared"])
    y_zero, _ = L.moe_block(p_zero, x, cfg_sh)
    base = {k: v for k, v in p_sh.items() if k != "shared"}
    y_base, _ = L.moe_block(base, x, dataclasses.replace(
        cfg_sh, moe_shared_expert=False))
    np.testing.assert_allclose(np.asarray(y_zero), np.asarray(y_base),
                               atol=1e-6)
    assert not np.allclose(np.asarray(y0), np.asarray(y_base), atol=1e-5)
