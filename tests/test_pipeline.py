"""Pipeline parallelism (GLOBALMEM plan across devices): numerics under
shard_map + the Alg.1 stage-balancing partition + the end-to-end
launch-layer wiring (`--stages N --microbatch M`)."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.dist.pipeline import balance_stages, pipeline_bubble_fraction


def test_balance_stages_equalizes():
    # heavy tail: naive equal split would bottleneck the last stage
    times = [1.0] * 6 + [4.0, 4.0]
    sizes = balance_stages(times, 2)
    assert sum(sizes) == 8 and len(sizes) == 2
    s0 = sum(times[:sizes[0]])
    s1 = sum(times[sizes[0]:])
    assert max(s0, s1) <= 8.0        # optimal is 6/2 split → max 8
    assert sizes[1] < sizes[0]       # fewer heavy layers on one stage


def test_balance_stages_uniform():
    assert balance_stages([1.0] * 8, 4) == [2, 2, 2, 2]


def test_bubble_fraction():
    assert pipeline_bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert pipeline_bubble_fraction(32, 4) == pytest.approx(3 / 35)
    assert pipeline_bubble_fraction(128, 2) < 0.01


PIPE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_apply
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    S, M, D = 4, 8, 16
    rng = np.random.default_rng(0)
    # one matmul + tanh per stage
    w = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p)

    def run(w, xs):
        return pipeline_apply(stage_fn, {"w": w}, xs)["w"] if False else \
            pipeline_apply(lambda pp, x: jnp.tanh(x @ pp["w"]), {"w": w}, xs)

    out = jax.jit(shard_map(
        run, mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P(),
        check_vma=False,
    ))(w, xs)

    # reference: sequential application of the 4 stages
    ref = xs
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("PIPE OK")
""")


def test_pipeline_apply_matches_sequential():
    r = subprocess.run([sys.executable, "-c", PIPE_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "PIPE OK" in r.stdout


# ------------------------------------------- microbatched GPipe schedule
MICRO_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_apply_microbatched
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    S, B, D, M = 4, 8, 16, 4
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def stage_fn(p, c):
        return {"x": jnp.tanh(c["x"] @ p["w"])}

    f = shard_map(
        lambda w, xs: pipeline_apply_microbatched(
            stage_fn, {"w": w}, {"x": xs}, M)["x"],
        mesh=mesh, in_specs=(P("stage"), P()), out_specs=P(),
        check_vma=False)

    ref = xs
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])
    out = jax.jit(f)(w, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

    # reverse-mode through the schedule (ppermute/psum transposes) must
    # match the sequential gradient
    g_pipe = jax.jit(jax.grad(lambda w: jnp.sum(f(w, xs) ** 2)))(w)
    def seq_loss(w):
        r = xs
        for s in range(S):
            r = jnp.tanh(r @ w[s])
        return jnp.sum(r ** 2)
    g_seq = jax.jit(jax.grad(seq_loss))(w)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               rtol=1e-4, atol=1e-5)
    print("MICRO OK")
""")


def test_microbatched_schedule_fwd_and_grad():
    r = subprocess.run([sys.executable, "-c", MICRO_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "MICRO OK" in r.stdout


# ------------------------------------------------- stage partition plan
def test_plan_pipeline_partitions_and_prices():
    from repro.configs import get_smoke
    from repro.train.pipeline import plan_pipeline

    cfg = get_smoke("granite-3-8b")          # n_repeats=2, homogeneous
    plan = plan_pipeline(cfg, 2, 4, global_batch=8, seq_len=64)
    assert plan.sizes == (1, 1)
    assert plan.repeats_per_stage == 1
    assert plan.bubble == pytest.approx(pipeline_bubble_fraction(4, 2))
    assert len(plan.block_costs_s) == len(cfg.pattern)
    assert all(c > 0 for c in plan.block_costs_s)
    assert plan.stage_time_s == pytest.approx(sum(plan.block_costs_s))


def test_plan_pipeline_rejects_bad_partitions():
    from repro.configs import get_smoke
    from repro.train.pipeline import plan_pipeline

    cfg = get_smoke("granite-3-8b")
    with pytest.raises(ValueError):          # 2 repeats don't split 3 ways
        plan_pipeline(cfg, 3, 1, global_batch=8, seq_len=64)
    with pytest.raises(ValueError):          # microbatch doesn't divide
        plan_pipeline(cfg, 2, 3, global_batch=8, seq_len=64)
    with pytest.raises(ValueError):          # batch doesn't divide dp
        plan_pipeline(cfg, 2, 1, global_batch=9, seq_len=64, dp=2)


def test_stage_stack_specs():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import stage_stack_specs

    specs = {"ln1": P(None, None), "mixer": {"wq": P(None, None, "model")}}
    out = stage_stack_specs(specs)
    assert out["ln1"] == P("stage", None)
    assert out["mixer"]["wq"] == P("stage", None, "model")
    with pytest.raises(ValueError):
        stage_stack_specs({"bad": P("model", None)})


# --------------------------------------- end-to-end launch-layer wiring
TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    from repro.launch.train import build

    def run(stages, microbatch=0):
        cfg, mesh, state, step, data = build(
            "granite-3-8b", smoke=True, global_batch=8, seq_len=64,
            stages=stages, microbatch=microbatch, seed=0)
        losses = []
        for i in range(3):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses, state, mesh

    l1, _, _ = run(1)
    l2, s2, mesh2 = run(2, microbatch=2)
    diffs = [abs(a - b) / max(abs(a), 1e-9) for a, b in zip(l1, l2)]
    assert all(d < 2e-2 for d in diffs), (l1, l2, diffs)
    assert dict(mesh2.shape) == {"stage": 2, "data": 1, "model": 1}
    # the layer stack is genuinely sharded over the stage devices
    leaf = s2[0]["layers"][0]["mixer"]["wq"]
    assert str(leaf.sharding.spec[0]) == "stage"
    assert len(leaf.sharding.device_set) == 2
    print("LAUNCH PIPE OK", l1, l2)
""")


def test_pipelined_train_step_matches_baseline():
    """`--stages 2` trains on a ("stage", "data") host-device mesh and its
    loss trajectory matches `--stages 1` within tolerance (acceptance
    criterion for the launch-layer wiring)."""
    r = subprocess.run([sys.executable, "-c", TRAIN_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "LAUNCH PIPE OK" in r.stdout


# MoE across a (stage=2, data=2) mesh: exercises the stage×data
# composition (per-shard microbatching, aux averaged over both), and the
# constrain self-suppression under manual axes — MoE's custom_vjp
# backward rules call `constrain` while the transpose of the island is
# being traced, outside any caller-held context.
MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    from repro.launch.train import build

    def run(stages, mesh_shape=None, axes=None, microbatch=0):
        kw = dict(mesh_shape=mesh_shape, axes=axes) if mesh_shape else {}
        cfg, mesh, state, step, data = build(
            "qwen3-moe-30b-a3b", smoke=True, global_batch=8, seq_len=32,
            stages=stages, microbatch=microbatch, seed=0, **kw)
        losses = []
        for i in range(2):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    l1 = run(1)
    l2 = run(2, mesh_shape=(2, 2), axes=("stage", "data"), microbatch=2)
    diffs = [abs(a - b) / abs(a) for a, b in zip(l1, l2)]
    assert all(d < 2e-2 for d in diffs), (l1, l2, diffs)
    print("MOE PIPE DP OK")
""")


def test_moe_pipeline_composes_with_data_axis():
    r = subprocess.run([sys.executable, "-c", MOE_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "MOE PIPE DP OK" in r.stdout


# enc-dec (whisper): the encoder output enters the schedule as the
# *static* side input — read locally per in-flight microbatch, never
# ppermuted through the ring — and cross-attention must still match the
# non-pipelined baseline.
ENCDEC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    from repro.launch.train import build

    def run(stages, microbatch=0):
        cfg, mesh, state, step, data = build(
            "whisper-base", smoke=True, global_batch=4, seq_len=32,
            stages=stages, microbatch=microbatch, seed=0)
        losses = []
        for i in range(2):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    l1 = run(1)
    l2 = run(2, microbatch=2)
    diffs = [abs(a - b) / abs(a) for a, b in zip(l1, l2)]
    assert all(d < 2e-2 for d in diffs), (l1, l2, diffs)
    print("ENCDEC PIPE OK")
""")


def test_encdec_pipeline_static_encoder_input():
    r = subprocess.run([sys.executable, "-c", ENCDEC_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "ENCDEC PIPE OK" in r.stdout
