"""Pipeline parallelism (GLOBALMEM plan across devices): numerics under
shard_map + the Alg.1 stage-balancing partition."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.dist.pipeline import balance_stages, pipeline_bubble_fraction


def test_balance_stages_equalizes():
    # heavy tail: naive equal split would bottleneck the last stage
    times = [1.0] * 6 + [4.0, 4.0]
    sizes = balance_stages(times, 2)
    assert sum(sizes) == 8 and len(sizes) == 2
    s0 = sum(times[:sizes[0]])
    s1 = sum(times[sizes[0]:])
    assert max(s0, s1) <= 8.0        # optimal is 6/2 split → max 8
    assert sizes[1] < sizes[0]       # fewer heavy layers on one stage


def test_balance_stages_uniform():
    assert balance_stages([1.0] * 8, 4) == [2, 2, 2, 2]


def test_bubble_fraction():
    assert pipeline_bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert pipeline_bubble_fraction(32, 4) == pytest.approx(3 / 35)
    assert pipeline_bubble_fraction(128, 2) < 0.01


PIPE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_apply
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    S, M, D = 4, 8, 16
    rng = np.random.default_rng(0)
    # one matmul + tanh per stage
    w = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p)

    def run(w, xs):
        return pipeline_apply(stage_fn, {"w": w}, xs)["w"] if False else \
            pipeline_apply(lambda pp, x: jnp.tanh(x @ pp["w"]), {"w": w}, xs)

    out = jax.jit(shard_map(
        run, mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P(),
        check_vma=False,
    ))(w, xs)

    # reference: sequential application of the 4 stages
    ref = xs
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("PIPE OK")
""")


def test_pipeline_apply_matches_sequential():
    r = subprocess.run([sys.executable, "-c", PIPE_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "PIPE OK" in r.stdout
