"""Pipeline parallelism (GLOBALMEM plan across devices): numerics under
shard_map + the Alg.1 stage-balancing partition + schedules (GPipe,
1F1B, and interleaved virtual-stage step programs) + the end-to-end
launch-layer wiring (`--stages N --microbatch M
--schedule {gpipe,1f1b,interleaved} [--virtual-stages v]`)."""
import itertools
import random
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.dist.pipeline import (PIPE_BWD, PIPE_FWD, PIPE_IDLE,
                                 balance_stages, make_step_program,
                                 pipeline_bubble_fraction,
                                 pipeline_peak_activation_bytes,
                                 pipeline_peak_inflight,
                                 program_peak_inflight)


def test_balance_stages_equalizes():
    # heavy tail: naive equal split would bottleneck the last stage
    times = [1.0] * 6 + [4.0, 4.0]
    sizes = balance_stages(times, 2)
    assert sum(sizes) == 8 and len(sizes) == 2
    s0 = sum(times[:sizes[0]])
    s1 = sum(times[sizes[0]:])
    assert max(s0, s1) <= 8.0        # optimal is 6/2 split → max 8
    assert sizes[1] < sizes[0]       # fewer heavy layers on one stage


def test_balance_stages_uniform():
    assert balance_stages([1.0] * 8, 4) == [2, 2, 2, 2]


def test_balance_stages_front_loads_ties():
    # among the optimal partitions, extra layers land on earlier stages
    # (last group minimal, recursively for the prefix at its optimum)
    assert balance_stages([1.0] * 4, 3) == [2, 1, 1]
    assert balance_stages([1.0] * 3, 2) == [2, 1]
    assert balance_stages([1.0] * 7, 3) == [3, 3, 1]
    assert balance_stages([1.0] * 5, 3) == [2, 2, 1]


def _brute_force_partitions(n, k):
    """All compositions of n into k positive parts."""
    for cuts in itertools.combinations(range(1, n), k - 1):
        bounds = (0, *cuts, n)
        yield [bounds[i + 1] - bounds[i] for i in range(k)]


def _group_sums(times, sizes):
    i, out = 0, []
    for s in sizes:
        out.append(sum(times[i:i + s]))
        i += s
    return out


@given(times=st.lists(st.integers(min_value=1, max_value=8),
                      min_size=1, max_size=9),
       n_stages=st.integers(min_value=1, max_value=9))
@settings(max_examples=200, deadline=None)
def test_balance_stages_optimal_and_front_loaded(times, n_stages):
    """Property (brute force): the returned contiguous partition
    minimizes the max group sum, and ties are front-loaded — no optimal
    partition puts fewer layers on the last stage.  (Integer costs keep
    float sums exact, so the tie comparison is meaningful.)"""
    times = [float(t) for t in times]
    if n_stages > len(times):
        return
    sizes = balance_stages(times, n_stages)
    assert len(sizes) == n_stages and sum(sizes) == len(times)
    assert all(s >= 1 for s in sizes)
    got = max(_group_sums(times, sizes))
    optimal = [sz for sz in _brute_force_partitions(len(times), n_stages)]
    best_val = min(max(_group_sums(times, sz)) for sz in optimal)
    assert got == best_val
    tied = [sz for sz in optimal
            if max(_group_sums(times, sz)) == best_val]
    assert sizes[-1] == min(sz[-1] for sz in tied)


def test_bubble_fraction():
    assert pipeline_bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert pipeline_bubble_fraction(32, 4) == pytest.approx(3 / 35)
    assert pipeline_bubble_fraction(128, 2) < 0.01


def test_bubble_fraction_stage_times():
    # uniform stage times pin the overload to the old closed form
    for M, S in [(1, 4), (4, 3), (32, 4), (8, 2)]:
        assert pipeline_bubble_fraction(M, S, stage_times=[2.5] * S) == \
            pytest.approx(pipeline_bubble_fraction(M, S))
    # a bottleneck stage makes the uniform formula optimistic: the
    # other stages idle while the slow stage sets the period
    het = pipeline_bubble_fraction(4, 3, stage_times=[2.0, 1.0, 1.0])
    assert het > pipeline_bubble_fraction(4, 3)
    # closed form: 1 - M·Σt / (S·((M-1)·max t + Σ t))
    assert het == pytest.approx(1.0 - 4 * 4.0 / (3 * (3 * 2.0 + 4.0)))
    with pytest.raises(ValueError):
        pipeline_bubble_fraction(4, 3, stage_times=[1.0, 1.0])
    with pytest.raises(ValueError):
        pipeline_bubble_fraction(4, 2, stage_times=[0.0, 0.0])


# ------------------------------------------- step programs & memory model
def test_step_program_invariants():
    """Both schedules produce valid, complete step programs: every (s, m)
    forward and backward fires exactly once, forwards respect the ring
    ppermute latency, backwards consume cotangents the tick they arrive,
    and the total tick count (hence the bubble) is identical."""
    for M, S in [(1, 1), (1, 4), (2, 4), (4, 2), (4, 4), (8, 2), (8, 4),
                 (3, 3), (5, 3)]:
        for sched in ("gpipe", "1f1b"):
            prog = make_step_program(M, S, sched)
            assert len(prog) == 2 * (M + S - 1)
            f_tick, b_tick = {}, {}
            for t, row in enumerate(prog):
                assert len(row) == S
                for s, (op, m) in enumerate(row):
                    if op == PIPE_FWD:
                        f_tick[(s, m)] = t
                    elif op == PIPE_BWD:
                        b_tick[(s, m)] = t
            assert len(f_tick) == len(b_tick) == M * S
            for s in range(S):
                for m in range(M):
                    if s > 0:
                        assert f_tick[(s, m)] >= f_tick[(s - 1, m)] + 1
                    if s < S - 1:
                        assert b_tick[(s, m)] == b_tick[(s + 1, m)] + 1
                    else:
                        assert b_tick[(s, m)] >= f_tick[(s, m)] + 1


def test_step_program_inflight_bound():
    """The 1F1B program keeps the per-stage activation stash at
    min(M, S) ≤ S in-flight microbatches; GPipe stashes all M.  The
    host-side occupancy simulator agrees with the analytic model."""
    for M, S in [(1, 4), (2, 4), (4, 4), (8, 4), (8, 2), (5, 3), (16, 4)]:
        got = program_peak_inflight(make_step_program(M, S, "1f1b"), S)
        assert got == pipeline_peak_inflight(M, S, "1f1b") == min(M, S)
        assert got <= S
        got = program_peak_inflight(make_step_program(M, S, "gpipe"), S)
        assert got == pipeline_peak_inflight(M, S, "gpipe") == M


def test_peak_activation_model():
    assert pipeline_peak_activation_bytes(8, 2, "gpipe", 100.0) == 800.0
    assert pipeline_peak_activation_bytes(8, 2, "1f1b", 100.0) == 200.0
    assert pipeline_peak_activation_bytes(2, 4, "1f1b", 100.0) == 200.0
    # interleaved: v=1 degenerates to 1f1b's min(M, S); v>1 pays the
    # steady state v·S + S-1 plus the retiring microbatch's v chunks
    assert pipeline_peak_inflight(8, 2, "interleaved") == 2
    assert pipeline_peak_inflight(
        8, 2, "interleaved", virtual_stages=2) == min(16, 4 + 1 + 2)
    assert pipeline_peak_inflight(
        2, 4, "interleaved", virtual_stages=2) == 4   # v·M caps it
    assert pipeline_peak_activation_bytes(
        8, 2, "interleaved", 100.0, virtual_stages=2) == 700.0
    with pytest.raises(ValueError):
        pipeline_peak_inflight(8, 2, "zigzag")
    with pytest.raises(ValueError):          # v>1 is interleaved-only
        pipeline_peak_inflight(8, 2, "1f1b", virtual_stages=2)


PIPE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_apply
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    S, M, D = 4, 8, 16
    rng = np.random.default_rng(0)
    # one matmul + tanh per stage
    w = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)

    def stage_fn(p, x):
        return jnp.tanh(x @ p)

    def run(w, xs):
        return pipeline_apply(stage_fn, {"w": w}, xs)["w"] if False else \
            pipeline_apply(lambda pp, x: jnp.tanh(x @ pp["w"]), {"w": w}, xs)

    out = jax.jit(shard_map(
        run, mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P(),
        check_vma=False,
    ))(w, xs)

    # reference: sequential application of the 4 stages
    ref = xs
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("PIPE OK")
""")


def test_pipeline_apply_matches_sequential():
    r = subprocess.run([sys.executable, "-c", PIPE_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "PIPE OK" in r.stdout


# ------------------------------------------- microbatched GPipe schedule
MICRO_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_apply_microbatched
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    S, B, D, M = 4, 8, 16, 4
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def stage_fn(p, c):
        return {"x": jnp.tanh(c["x"] @ p["w"])}

    f = shard_map(
        lambda w, xs: pipeline_apply_microbatched(
            stage_fn, {"w": w}, {"x": xs}, M)["x"],
        mesh=mesh, in_specs=(P("stage"), P()), out_specs=P(),
        check_vma=False)

    ref = xs
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])
    out = jax.jit(f)(w, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

    # reverse-mode through the schedule (ppermute/psum transposes) must
    # match the sequential gradient
    g_pipe = jax.jit(jax.grad(lambda w: jnp.sum(f(w, xs) ** 2)))(w)
    def seq_loss(w):
        r = xs
        for s in range(S):
            r = jnp.tanh(r @ w[s])
        return jnp.sum(r ** 2)
    g_seq = jax.jit(jax.grad(seq_loss))(w)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               rtol=1e-4, atol=1e-5)
    print("MICRO OK")
""")


def test_microbatched_schedule_fwd_and_grad():
    r = subprocess.run([sys.executable, "-c", MICRO_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "MICRO OK" in r.stdout


# ------------------------------------------------ 1F1B schedule variant
# gradient equivalence on a tiny model: the custom-vjp backward step
# program (stash/pop + reverse ppermute) must reproduce both the
# sequential gradient and the gpipe (scan-transpose) gradient.
F1B_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_apply_microbatched
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("stage",))
    S, B, D, M = 4, 8, 16, 4
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(S, D, D)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def stage_fn(p, c):
        return {"x": jnp.tanh(c["x"] @ p["w"])}

    def make(sched):
        return shard_map(
            lambda w, xs: pipeline_apply_microbatched(
                stage_fn, {"w": w}, {"x": xs}, M, schedule=sched)["x"],
            mesh=mesh, in_specs=(P("stage"), P()), out_specs=P(),
            check_vma=False)

    ref = xs
    for s in range(S):
        ref = jnp.tanh(ref @ w[s])

    f1 = make("1f1b")
    out = jax.jit(f1)(w, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)

    def seq_loss(w):
        r = xs
        for s in range(S):
            r = jnp.tanh(r @ w[s])
        return jnp.sum(r ** 2)
    g_seq = jax.jit(jax.grad(seq_loss))(w)
    g_1f1b = jax.jit(jax.grad(lambda w: jnp.sum(f1(w, xs) ** 2)))(w)
    np.testing.assert_allclose(np.asarray(g_1f1b), np.asarray(g_seq),
                               rtol=1e-4, atol=1e-5)
    g_gpipe = jax.jit(jax.grad(
        lambda w: jnp.sum(make("gpipe")(w, xs) ** 2)))(w)
    np.testing.assert_allclose(np.asarray(g_1f1b), np.asarray(g_gpipe),
                               rtol=1e-4, atol=1e-6)
    # input cotangents too (they ride the reverse ppermute to stage 0)
    gx = jax.jit(jax.grad(lambda xs: jnp.sum(f1(w, xs) ** 2)))(xs)
    gx_seq = jax.jit(jax.grad(lambda x0: jnp.sum(
        jnp.tanh(jnp.tanh(jnp.tanh(jnp.tanh(
            x0 @ w[0]) @ w[1]) @ w[2]) @ w[3]) ** 2)))(xs)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_seq),
                               rtol=1e-4, atol=1e-5)
    print("F1B OK")
""")


def test_1f1b_schedule_fwd_and_grad():
    r = subprocess.run([sys.executable, "-c", F1B_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "F1B OK" in r.stdout


# the fused executor (loss inside the schedule): loss + grads match the
# sequential value_and_grad for both step programs, and the compiled
# 1F1B step's stash is genuinely smaller at M > S (the memory bound the
# benchmark measures at scale).
FUSED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_train_microbatched
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2,), ("stage",))
    S, B, D, M, REP = 2, 64, 32, 8, 2
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(S, REP, D, D)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def stage_fn(p, c):
        x = c["x"]
        for r in range(REP):
            x = jnp.tanh(x @ p["w"][r])
        return {"x": x}

    def loss_fn(c):
        return jnp.sum(c["x"] ** 2)

    def make(sched):
        return jax.jit(shard_map(
            lambda w, xs: pipeline_train_microbatched(
                stage_fn, {"w": w}, {"x": xs}, loss_fn, M,
                schedule=sched),
            mesh=mesh, in_specs=(P("stage"), P()),
            out_specs=(P(), {"w": P("stage")}), check_vma=False))

    def seq(w, xs):
        total = jnp.zeros((), jnp.float32)
        xmb = xs.reshape(M, B // M, D)
        for m in range(M):
            c = {"x": xmb[m]}
            for s in range(S):
                c = stage_fn({"w": w[s]}, c)
            total = total + loss_fn(c)
        return total

    l_ref, g_ref = jax.jit(jax.value_and_grad(seq))(w, xs)
    temps = {}
    for sched in ("gpipe", "1f1b"):
        f = make(sched).lower(w, xs).compile()   # one AOT compile
        loss, grads = f(w, xs)
        np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(grads["w"]),
                                   np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-5)
        ma = f.memory_analysis()
        temps[sched] = None if ma is None else ma.temp_size_in_bytes
    if temps["gpipe"] is not None:
        assert temps["1f1b"] < temps["gpipe"], temps
    print("FUSED OK", temps)
""")


def test_fused_train_executor_matches_autodiff():
    r = subprocess.run([sys.executable, "-c", FUSED_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "FUSED OK" in r.stdout


# ------------------------------------- interleaved virtual-stage 1F1B
def test_interleaved_v1_is_plain_1f1b():
    """virtual_stages=1 must degenerate to the flat 1F1B program,
    tick for tick, and overlap (which spaces forwards for the extra
    transfer hop) is rejected there — it would break the identity."""
    for M, S in [(1, 1), (4, 2), (8, 4), (5, 3), (16, 2)]:
        assert make_step_program(M, S, "interleaved") == \
            make_step_program(M, S, "1f1b")
    with pytest.raises(ValueError, match="virtual_stages >= 2"):
        make_step_program(4, 2, "interleaved", overlap=True)


def test_interleaved_program_invariants():
    """Generated interleaved programs pass the MK-P dataflow checker and
    the occupancy simulator stays within the analytic stash bound
    min(v·M, v·S + S - 1 + v)."""
    from repro.analysis.dataflow import check_step_program

    for M, S, v, ov in [(4, 2, 2, False), (8, 4, 2, False),
                        (8, 2, 4, True), (5, 3, 2, True),
                        (16, 4, 4, False), (2, 2, 2, True), (1, 4, 3, False)]:
        prog = make_step_program(M, S, "interleaved", virtual_stages=v,
                                 overlap=ov)
        errs = [d for d in check_step_program(
            prog, M, S, schedule="interleaved", virtual_stages=v)
            if d.is_error]
        assert not errs, (M, S, v, ov, [str(d) for d in errs])
        assert program_peak_inflight(prog, S) <= pipeline_peak_inflight(
            M, S, "interleaved", virtual_stages=v), (M, S, v, ov)


def _interleaved_errors(prog, M, S, v):
    from repro.analysis.dataflow import check_step_program
    return [d for d in check_step_program(
        prog, M, S, schedule="interleaved", virtual_stages=v)
        if d.is_error]


@given(M=st.integers(min_value=1, max_value=10),
       S=st.integers(min_value=1, max_value=5),
       v=st.integers(min_value=1, max_value=4),
       overlap=st.booleans(),
       seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_interleaved_program_properties(M, S, v, overlap, seed):
    """Property: every generated (M, S, v) interleaved program passes
    the dataflow checker and the peak-inflight bound, and the checker
    genuinely discriminates — dropping one event or swapping a device's
    first and last events must produce errors (mutate-to-fail)."""
    if overlap and v == 1:
        with pytest.raises(ValueError):
            make_step_program(M, S, "interleaved", overlap=True)
        return
    prog = make_step_program(M, S, "interleaved", virtual_stages=v,
                             overlap=overlap)
    assert not _interleaved_errors(prog, M, S, v)
    assert program_peak_inflight(prog, S) <= pipeline_peak_inflight(
        M, S, "interleaved", virtual_stages=v)

    rng = random.Random(seed)
    events = [(t, s) for t, row in enumerate(prog)
              for s, e in enumerate(row) if e[0] != PIPE_IDLE]
    # drop one random event: its (chunk, microbatch) never fires
    t, s = rng.choice(events)
    mut = [list(row) for row in prog]
    mut[t][s] = (PIPE_IDLE, 0, 0)
    assert _interleaved_errors(mut, M, S, v), ("drop", M, S, v, t, s)
    # swap a device's first event (always a forward) with its last
    # (always a backward): the backward now precedes its forward
    dev = [(tt, ss) for tt, ss in events if ss == s]
    (t0, _), (t1, _) = dev[0], dev[-1]
    if t0 != t1:
        mut = [list(row) for row in prog]
        mut[t0][s], mut[t1][s] = mut[t1][s], mut[t0][s]
        assert _interleaved_errors(mut, M, S, v), ("swap", M, S, v)


# interleaved fused executor vs gpipe / 1f1b / sequential: same summed
# per-microbatch loss, same layer gradients (reassembled from the
# (S, v, n_c, ...) chunk-stacked layout), with and without the
# double-buffered activation ppermute; v=1 is numerically identical to
# the flat 1f1b executor it delegates to.
INTERLEAVED_FUSED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.dist.compat import shard_map
    from repro.dist.pipeline import pipeline_train_microbatched
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2,), ("stage",))
    S, B, D, M, N, V = 2, 32, 16, 4, 8, 2    # N layers, V chunks/device
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.normal(size=(N, D, D)) * 0.3, jnp.float32)
    xs = jnp.asarray(rng.normal(size=(B, D)), jnp.float32)

    def stage_fn(p, c):                      # generic over stack depth
        x = c["x"]
        for r in range(p["w"].shape[0]):
            x = jnp.tanh(x @ p["w"][r])
        return {"x": x}

    def loss_fn(c):
        return jnp.sum(c["x"] ** 2)

    def make(sched, v=1, overlap=False):
        return jax.jit(shard_map(
            lambda w, xs: pipeline_train_microbatched(
                stage_fn, {"w": w}, {"x": xs}, loss_fn, M,
                schedule=sched, virtual_stages=v, overlap=overlap),
            mesh=mesh, in_specs=(P("stage"), P()),
            out_specs=(P(), {"w": P("stage")}), check_vma=False))

    # flat stage stacks for gpipe/1f1b; interleaved chunk-stacks layer
    # q*n_c+j into virtual stage q = c*S + s -> device s, slot (s, c)
    w_flat = ws.reshape(S, N // S, D, D)
    n_c = N // (V * S)
    w_il = ws.reshape(V, S, n_c, D, D).transpose(1, 0, 2, 3, 4)

    def seq(w, xs):                          # summed per-microbatch loss
        total = jnp.zeros((), jnp.float32)
        for xm in xs.reshape(M, B // M, D):
            c = {"x": xm}
            for r in range(N):
                c = {"x": jnp.tanh(c["x"] @ w[r])}
            total = total + loss_fn(c)
        return total

    l_ref, g_ref = jax.jit(jax.value_and_grad(seq))(ws, xs)

    l_f, g_f = make("1f1b")(w_flat, xs)
    outs = {"gpipe": make("gpipe")(w_flat, xs), "1f1b": (l_f, g_f)}
    flat = {k: (l, g["w"].reshape(N, D, D)) for k, (l, g) in outs.items()}
    for ov in (False, True):
        l_i, g_i = make("interleaved", v=V, overlap=ov)(w_il, xs)
        flat[f"interleaved ov={ov}"] = (
            l_i, g_i["w"].transpose(1, 0, 2, 3, 4).reshape(N, D, D))
    for name, (l, g) in flat.items():
        np.testing.assert_allclose(float(l), float(l_ref), rtol=1e-5,
                                   err_msg=name)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-4, atol=1e-5, err_msg=name)

    # v=1 delegates to the flat 1f1b executor: identical numerics
    l_v1, g_v1 = make("interleaved", v=1)(w_flat[:, None], xs)
    assert float(l_v1) == float(l_f), (float(l_v1), float(l_f))
    np.testing.assert_array_equal(np.asarray(g_v1["w"][:, 0]),
                                  np.asarray(g_f["w"]))
    print("INTERLEAVED FUSED OK")
""")


def test_interleaved_executor_schedule_equivalence():
    """Schedule-equivalence matrix (acceptance criterion): interleaved
    v=2 loss and grads match gpipe, 1f1b, and the sequential reference,
    both with and without overlap, and v=1 == plain 1f1b exactly."""
    r = subprocess.run([sys.executable, "-c", INTERLEAVED_FUSED_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "INTERLEAVED FUSED OK" in r.stdout


# launch-level interleaved wiring: `--schedule interleaved
# --virtual-stages 2` on jamba (the only smoke config with
# n_repeats >= v*S) tracks both the stages=1 baseline and plain 1f1b;
# the heterogeneous --stages 3 case (4 repeats over 3 stages, staggered
# partition) runs the interleaved schedule path at v=1.
INTERLEAVED_TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    from repro.launch.train import build

    def run(stages, microbatch=0, schedule="gpipe", virtual_stages=1):
        cfg, mesh, state, step, data = build(
            "jamba-v0.1-52b", smoke=True, global_batch=4, seq_len=32,
            stages=stages, microbatch=microbatch, schedule=schedule,
            virtual_stages=virtual_stages, seed=0)
        losses = []
        for i in range(2):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    l1 = run(1)
    lf = run(2, microbatch=2, schedule="1f1b")
    li = run(2, microbatch=2, schedule="interleaved", virtual_stages=2)
    lh = run(3, microbatch=2, schedule="interleaved")   # hetero, v=1
    for name, lp in (("1f1b", lf), ("interleaved", li), ("het", lh)):
        diffs = [abs(a - b) / max(abs(a), 1e-9) for a, b in zip(l1, lp)]
        assert all(d < 2e-2 for d in diffs), (name, l1, lp, diffs)
    print("INTERLEAVED TRAIN OK", l1, lf, li, lh)
""")


def test_interleaved_train_matches_baseline():
    r = subprocess.run([sys.executable, "-c", INTERLEAVED_TRAIN_SCRIPT],
                       capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "INTERLEAVED TRAIN OK" in r.stdout


# ------------------------------------------------- stage partition plan
def test_plan_pipeline_partitions_and_prices():
    from repro.configs import get_smoke
    from repro.train.pipeline import plan_pipeline

    cfg = get_smoke("granite-3-8b")          # n_repeats=2, homogeneous
    plan = plan_pipeline(cfg, 2, 4, global_batch=8, seq_len=64)
    assert plan.sizes == ((1, 1),) * len(cfg.pattern)
    assert plan.repeats_per_stage == 1
    assert plan.partition == "uniform" and plan.padding_overhead == 0.0
    assert plan.bubble == pytest.approx(pipeline_bubble_fraction(4, 2))
    assert len(plan.block_costs_s) == len(cfg.pattern)
    assert all(c > 0 for c in plan.block_costs_s)
    assert plan.stage_time_s == pytest.approx(sum(plan.block_costs_s))
    assert plan.stage_times_s == pytest.approx(
        (plan.stage_time_s,) * 2)
    assert plan.padded_stage_time_s == pytest.approx(plan.stage_time_s)
    # schedule threading: same partition/bubble, smaller predicted stash
    assert plan.schedule == "gpipe" and plan.peak_inflight == 4
    p2 = plan_pipeline(cfg, 2, 4, global_batch=8, seq_len=64,
                       schedule="1f1b", block_costs=plan.block_costs_s)
    assert p2.sizes == plan.sizes and p2.bubble == plan.bubble
    assert p2.peak_inflight == 2
    assert p2.peak_activation_bytes == pytest.approx(
        plan.peak_activation_bytes / 2)


def test_plan_pipeline_rejects_bad_partitions():
    from repro.configs import get_smoke
    from repro.train.pipeline import plan_pipeline

    cfg = get_smoke("granite-3-8b")
    # 3 stages > 2 repeats: even padded stacks need one repeat per stage
    with pytest.raises(ValueError, match="padded per-stage stacks"):
        plan_pipeline(cfg, 3, 1, global_batch=8, seq_len=64)
    with pytest.raises(ValueError):          # microbatch doesn't divide
        plan_pipeline(cfg, 2, 3, global_batch=8, seq_len=64)
    with pytest.raises(ValueError):          # batch doesn't divide dp
        plan_pipeline(cfg, 2, 1, global_batch=9, seq_len=64, dp=2)
    with pytest.raises(ValueError):          # unknown schedule
        plan_pipeline(cfg, 2, 1, global_batch=8, seq_len=64,
                      schedule="zigzag")
    with pytest.raises(ValueError):          # v>1 needs interleaved
        plan_pipeline(cfg, 2, 2, global_batch=8, seq_len=64,
                      virtual_stages=2)
    with pytest.raises(ValueError):          # v*S=4 > n_repeats=2
        plan_pipeline(cfg, 2, 2, global_batch=8, seq_len=64,
                      schedule="interleaved", virtual_stages=2)


# --------------------------------------- heterogeneous stage partitions
def test_choose_partition_uniform_when_divisible():
    """R % S == 0 sits at the total/S lower bound: the uniform unpadded
    split is always kept, whatever the per-position costs."""
    from repro.train.pipeline import choose_partition

    part = choose_partition([1.0, 5.0, 2.0], 4, 2)
    assert part.kind == "uniform"
    assert part.sizes == ((2, 2),) * 3
    assert part.padded_repeats == (2, 2, 2)
    assert part.bottleneck_s == pytest.approx(2 * 8.0)
    assert part.padded_stage_time_s([1.0, 5.0, 2.0]) == \
        pytest.approx(part.bottleneck_s)


def test_choose_partition_heterogeneous_beats_uniform_padding():
    """Acceptance criterion: on a heterogeneous per-position cost vector
    the chosen partition's predicted bottleneck never exceeds the
    uniform-padded alternative's — and genuinely improves on it for a
    jamba-style cost spread — while its *realized* per-microbatch island
    time (the per-position maxima sum today's executor pays) never
    exceeds the uniform split's either."""
    from repro.train.pipeline import choose_partition

    costs = [1.0, 3.0, 1.0, 5.0]             # mamba / attn+moe-ish spread
    R, S = 4, 3
    part = choose_partition(costs, R, S)
    uni = balance_stages([sum(costs)] * R, S)
    uni_bottleneck = max(uni) * sum(costs)
    assert part.bottleneck_s <= uni_bottleneck
    assert part.kind == "staggered" and part.bottleneck_s < uni_bottleneck
    # staggered rows stay within {floor(R/S), ceil(R/S)}: the realized
    # island time equals the uniform split's, only the placement moves
    assert part.padded_stage_time_s(costs) == pytest.approx(
        max(uni) * sum(costs))
    for row, kmax in zip(part.sizes, part.padded_repeats):
        assert len(row) == S and sum(row) == R
        assert kmax == max(row)
        assert set(row) <= {R // S, R // S + 1}
    assert part.stage_times_s == tuple(
        sum(part.sizes[p][s] * costs[p] for p in range(len(costs)))
        for s in range(S))


@given(costs=st.lists(st.integers(min_value=1, max_value=9),
                      min_size=1, max_size=5),
       n_repeats=st.integers(min_value=1, max_value=8),
       n_stages=st.integers(min_value=1, max_value=8))
@settings(max_examples=100, deadline=None)
def test_choose_partition_never_worse_than_uniform_padded(
        costs, n_repeats, n_stages):
    from repro.train.pipeline import choose_partition

    costs = [float(c) for c in costs]
    if n_stages > n_repeats:
        return
    part = choose_partition(costs, n_repeats, n_stages)
    uni = balance_stages([sum(costs)] * n_repeats, n_stages)
    # never worse than uniform-padded on EITHER metric: the fused
    # bottleneck bound (acceptance criterion) or the realized
    # per-position island time today's executor pays
    assert part.bottleneck_s <= max(uni) * sum(costs) + 1e-9
    assert part.padded_stage_time_s(costs) <= \
        max(uni) * sum(costs) + 1e-9
    for row in part.sizes:
        assert sum(row) == n_repeats and len(row) == n_stages


def test_plan_pipeline_heterogeneous_jamba():
    from repro.configs import get_smoke
    from repro.train.pipeline import plan_pipeline

    cfg = get_smoke("jamba-v0.1-52b")        # n_repeats=4, hybrid
    plan = plan_pipeline(cfg, 3, 2, global_batch=4, seq_len=32)
    assert plan.n_stages == 3
    for row in plan.sizes:
        assert len(row) == 3 and sum(row) == cfg.n_repeats
    assert len(plan.sizes) == len(cfg.pattern)
    assert plan.stage_time_s == pytest.approx(max(plan.stage_times_s))
    assert plan.padded_stage_time_s >= plan.stage_time_s
    assert plan.padding_overhead >= 0.0
    assert plan.repeats_per_stage == max(plan.padded_repeats)
    # the bottleneck-based bubble prices the unequal stages
    assert plan.bubble == pytest.approx(pipeline_bubble_fraction(
        2, 3, stage_times=plan.stage_times_s))


def test_stage_stack_heterogeneous_pads_and_replicates_edge():
    import jax.numpy as jnp
    from repro.models.pipeline import stage_stack

    w = jnp.arange(4 * 3, dtype=jnp.float32).reshape(4, 3)  # R=4
    wn = np.asarray(w)
    st_ = stage_stack({"w": w}, 3, sizes=(2, 1, 1))
    assert st_["w"].shape == (3, 2, 3)
    np.testing.assert_array_equal(np.asarray(st_["w"][0]), wn[0:2])
    # padded slots replicate the chunk's last valid repeat
    np.testing.assert_array_equal(np.asarray(st_["w"][1]), wn[[2, 2]])
    np.testing.assert_array_equal(np.asarray(st_["w"][2]), wn[[3, 3]])
    # a zero-size stage gets repeat 0 as (masked) filler
    st0 = stage_stack({"w": w}, 2, sizes=(4, 0))
    assert st0["w"].shape == (2, 4, 3)
    np.testing.assert_array_equal(np.asarray(st0["w"][1]),
                                  wn[[0, 0, 0, 0]])
    # uniform sizes fall back to the free reshape
    stu = stage_stack({"w": w}, 2, sizes=(2, 2))
    np.testing.assert_array_equal(np.asarray(stu["w"]),
                                  wn.reshape(2, 2, 3))
    with pytest.raises(ValueError, match="sum to"):
        stage_stack({"w": w}, 2, sizes=(3, 2))
    # regression: all-equal sizes must still fail the sum-to-R check
    # (the uniform-reshape shortcut used to bypass it, silently running
    # a different split than requested)
    with pytest.raises(ValueError, match="sum to"):
        stage_stack({"w": w}, 2, sizes=(1, 1))
    with pytest.raises(ValueError, match="padded per-stage"):
        stage_stack({"w": w}, 3)             # 4 % 3, no sizes given


def test_stage_stack_specs():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import stage_stack_specs

    specs = {"ln1": P(None, None), "mixer": {"wq": P(None, None, "model")}}
    out = stage_stack_specs(specs)
    assert out["ln1"] == P("stage", None)
    assert out["mixer"]["wq"] == P("stage", None, "model")
    with pytest.raises(ValueError):
        stage_stack_specs({"bad": P("model", None)})
    # a rank-0 leaf's P() must raise (P("stage") is invalid for a scalar
    # and used to surface only much later, inside with_shardings)
    with pytest.raises(ValueError, match="rank-0"):
        stage_stack_specs({"scalar": P()})


# ------------------------------------- island in_specs: param ∘ stage specs
def _stacked_abs(arch: str, n_stages: int, tp: int):
    """Abstract stage-stacked block trees per pattern position."""
    import jax
    from repro.configs import get_smoke
    from repro.models.common import tp_align
    from repro.models.pipeline import stage_stack
    from repro.models.transformer import abstract_params

    cfg = tp_align(get_smoke(arch), tp)
    params = abstract_params(cfg)
    return cfg, [jax.eval_shape(lambda t, _s=n_stages: stage_stack(t, _s),
                                pos) for pos in params["layers"]]


def test_pipeline_stage_specs_compose():
    """`param_specs ∘ stage_stack_specs`: every Megatron model entry lands
    on the right-indexed dim next to the leading stage entry, for every
    layer kind (attn / MoE / mamba)."""
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.dist.sharding import pipeline_stage_specs

    mesh = AbstractMesh((("stage", 2), ("data", 2), ("model", 2)))

    _, attn = _stacked_abs("granite-3-8b", 2, 2)
    specs = pipeline_stage_specs(attn[0], mesh)
    # stacked leaves are (S, R/S, ...): stage leads, model keeps its
    # right-indexed dim (wq (.., d, H, hd) → heads; wo (.., H, hd, d) rows)
    assert specs["ln1"] == P("stage", None, None)
    assert specs["mixer"]["wq"] == P("stage", None, None, "model", None)
    assert specs["mixer"]["wo"] == P("stage", None, "model", None, None)
    assert specs["ffn"]["w_up"] == P("stage", None, None, "model")
    assert specs["ffn"]["w_down"] == P("stage", None, "model", None)

    _, moe = _stacked_abs("qwen3-moe-30b-a3b", 2, 2)
    specs = pipeline_stage_specs(moe[0], mesh)
    assert specs["ffn"]["we_up"] == P("stage", None, "model", None, None)
    assert specs["ffn"]["we_down"] == P("stage", None, "model", None, None)
    assert specs["ffn"]["router"] == P("stage", None, None, None)

    _, mam = _stacked_abs("mamba2-370m", 2, 2)
    specs = pipeline_stage_specs(mam[0], mesh)
    assert specs["mixer"]["w_z"] == P("stage", None, None, "model")
    assert specs["mixer"]["out_proj"] == P("stage", None, "model", None)
    assert specs["mixer"]["conv_x"] == P("stage", None, None, "model")
    # per-head tensors shard with d_inner so manual islands see
    # consistent local head counts
    assert specs["mixer"]["A_log"] == P("stage", None, "model")
    assert specs["mixer"]["dt_bias"] == P("stage", None, "model")
    assert specs["mixer"]["w_B"] == P("stage", None, None, None)


def test_pipeline_stage_specs_sanitize_and_strict():
    """On a mesh without a model axis the model entries drop (and nothing
    else); on a model mesh whose size doesn't divide the sharded dims the
    helper raises instead of silently replicating (the island's explicit
    psums would double-count)."""
    import jax
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.dist.sharding import pipeline_stage_specs

    _, attn = _stacked_abs("granite-3-8b", 2, 1)
    dp_mesh = AbstractMesh((("stage", 2), ("data", 2)))
    specs = pipeline_stage_specs(attn[0], dp_mesh)
    flat = jax.tree.leaves(specs, is_leaf=lambda l: isinstance(l, P))
    assert all("model" not in tuple(s) for s in flat)
    assert all(tuple(s)[0] == "stage" for s in flat)

    huge_tp = AbstractMesh((("stage", 2), ("data", 1), ("model", 7)))
    with pytest.raises(ValueError, match="model axis"):
        pipeline_stage_specs(attn[0], huge_tp)


# --------------------------------------- end-to-end launch-layer wiring
TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    from repro.launch.train import build

    def run(stages, microbatch=0):
        cfg, mesh, state, step, data = build(
            "granite-3-8b", smoke=True, global_batch=8, seq_len=64,
            stages=stages, microbatch=microbatch, seed=0)
        losses = []
        for i in range(3):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses, state, mesh

    l1, _, _ = run(1)
    l2, s2, mesh2 = run(2, microbatch=2)
    diffs = [abs(a - b) / max(abs(a), 1e-9) for a, b in zip(l1, l2)]
    assert all(d < 2e-2 for d in diffs), (l1, l2, diffs)
    assert dict(mesh2.shape) == {"stage": 2, "data": 1, "model": 1}
    # the layer stack is genuinely sharded over the stage devices
    leaf = s2[0]["layers"][0]["mixer"]["wq"]
    assert str(leaf.sharding.spec[0]) == "stage"
    assert len(leaf.sharding.device_set) == 2
    print("LAUNCH PIPE OK", l1, l2)
""")


def test_pipelined_train_step_matches_baseline():
    """`--stages 2` trains on a ("stage", "data") host-device mesh and its
    loss trajectory matches `--stages 1` within tolerance (acceptance
    criterion for the launch-layer wiring)."""
    r = subprocess.run([sys.executable, "-c", TRAIN_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "LAUNCH PIPE OK" in r.stdout


# `--schedule 1f1b` end to end: the loss trajectory must match both the
# gpipe schedule and the stages=1 baseline within tolerance (acceptance
# criterion for the schedule variant).
F1B_TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    from repro.launch.train import build

    def run(stages, microbatch=0, schedule="gpipe"):
        cfg, mesh, state, step, data = build(
            "granite-3-8b", smoke=True, global_batch=8, seq_len=64,
            stages=stages, microbatch=microbatch, schedule=schedule,
            seed=0)
        losses = []
        for i in range(3):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    l1 = run(1)
    lg = run(2, microbatch=2, schedule="gpipe")
    lf = run(2, microbatch=2, schedule="1f1b")
    for ref in (l1, lg):
        diffs = [abs(a - b) / max(abs(a), 1e-9) for a, b in zip(ref, lf)]
        assert all(d < 2e-2 for d in diffs), (ref, lf, diffs)
    print("F1B TRAIN OK", l1, lg, lf)
""")


def test_1f1b_train_matches_gpipe_and_baseline():
    r = subprocess.run([sys.executable, "-c", F1B_TRAIN_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "F1B TRAIN OK" in r.stdout


# heterogeneous partition end to end (acceptance criterion): a
# jamba-style hybrid with n_repeats=4 over 3 stages (4 % 3 != 0) trains
# through BOTH schedules — padded per-stage stacks, cond-masked stage
# scans, block-granularity partition — and matches the sequential
# (stages=1) loss trajectory.
HET_TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    from repro.launch.train import build

    def run(stages, microbatch=0, schedule="gpipe"):
        cfg, mesh, state, step, data = build(
            "jamba-v0.1-52b", smoke=True, global_batch=4, seq_len=32,
            stages=stages, microbatch=microbatch, schedule=schedule,
            seed=0)
        losses = []
        for i in range(2):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    l1 = run(1)
    lg = run(3, microbatch=2, schedule="gpipe")
    lf = run(3, microbatch=2, schedule="1f1b")
    for name, lp in (("gpipe", lg), ("1f1b", lf)):
        diffs = [abs(a - b) / max(abs(a), 1e-9) for a, b in zip(l1, lp)]
        assert all(d < 2e-2 for d in diffs), (name, l1, lp, diffs)
    print("HET TRAIN OK", l1, lg, lf)
""")


def test_heterogeneous_jamba_train_matches_baseline():
    r = subprocess.run([sys.executable, "-c", HET_TRAIN_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "HET TRAIN OK" in r.stdout


# MoE across a (stage=2, data=2) mesh: exercises the stage×data
# composition (per-shard microbatching, aux averaged over both), and the
# constrain self-suppression under manual axes — MoE's custom_vjp
# backward rules call `constrain` while the transpose of the island is
# being traced, outside any caller-held context.
MOE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    from repro.launch.train import build

    def run(stages, mesh_shape=None, axes=None, microbatch=0):
        kw = dict(mesh_shape=mesh_shape, axes=axes) if mesh_shape else {}
        cfg, mesh, state, step, data = build(
            "qwen3-moe-30b-a3b", smoke=True, global_batch=8, seq_len=32,
            stages=stages, microbatch=microbatch, seed=0, **kw)
        losses = []
        for i in range(2):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    l1 = run(1)
    l2 = run(2, mesh_shape=(2, 2), axes=("stage", "data"), microbatch=2)
    diffs = [abs(a - b) / abs(a) for a, b in zip(l1, l2)]
    assert all(d < 2e-2 for d in diffs), (l1, l2, diffs)
    # stage x model: experts sharded inside the islands (manual EP with a
    # local-expert dispatch and a psum("model") combine)
    l3 = run(2, mesh_shape=(2, 1, 2), axes=("stage", "data", "model"),
             microbatch=2)
    diffs = [abs(a - b) / abs(a) for a, b in zip(l1, l3)]
    assert all(d < 2e-2 for d in diffs), (l1, l3, diffs)
    print("MOE PIPE DP OK")
""")


def test_moe_pipeline_composes_with_data_axis():
    r = subprocess.run([sys.executable, "-c", MOE_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "MOE PIPE DP OK" in r.stdout


# enc-dec (whisper): the encoder output enters the schedule as the
# *static* side input — read locally per in-flight microbatch, never
# ppermuted through the ring — and cross-attention must still match the
# non-pipelined baseline.
ENCDEC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    from repro.launch.train import build

    def run(stages, microbatch=0):
        cfg, mesh, state, step, data = build(
            "whisper-base", smoke=True, global_batch=4, seq_len=32,
            stages=stages, microbatch=microbatch, seed=0)
        losses = []
        for i in range(2):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    l1 = run(1)
    l2 = run(2, microbatch=2)
    diffs = [abs(a - b) / abs(a) for a, b in zip(l1, l2)]
    assert all(d < 2e-2 for d in diffs), (l1, l2, diffs)
    print("ENCDEC PIPE OK")
""")


def test_encdec_pipeline_static_encoder_input():
    r = subprocess.run([sys.executable, "-c", ENCDEC_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "ENCDEC PIPE OK" in r.stdout


# pipeline × tensor parallelism (the PP×TP composition): on a full
# (stage=2, data=2, model=2) mesh the islands run Megatron-sharded blocks
# — in_specs from param_specs ∘ stage_stack_specs, explicit psum("model")
# tp collectives in the block math — and the loss trajectory must match
# the tp-only baseline for BOTH schedules (acceptance criterion).
PPTP_TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.launch.train import build

    def run(stages, mesh_shape, axes, microbatch=0, schedule="gpipe"):
        cfg, mesh, state, step, data = build(
            "granite-3-8b", smoke=True, global_batch=8, seq_len=64,
            stages=stages, microbatch=microbatch, schedule=schedule,
            mesh_shape=mesh_shape, axes=axes, seed=0)
        losses = []
        for i in range(3):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses, state, mesh

    l_tp, _, _ = run(1, (2, 2), ("data", "model"))
    lg, sg, mesh = run(2, (2, 2, 2), ("stage", "data", "model"),
                       microbatch=2)
    lf, _, _ = run(2, (2, 2, 2), ("stage", "data", "model"),
                   microbatch=2, schedule="1f1b")
    for name, lp in (("gpipe", lg), ("1f1b", lf)):
        diffs = [abs(a - b) / max(abs(a), 1e-9) for a, b in zip(l_tp, lp)]
        assert all(d < 2e-2 for d in diffs), (name, l_tp, lp, diffs)
    assert dict(mesh.shape) == {"stage": 2, "data": 2, "model": 2}
    # the layer stack is genuinely sharded over stage AND model devices
    leaf = sg[0]["layers"][0]["mixer"]["wq"]
    assert str(leaf.sharding.spec[0]) == "stage"
    assert "model" in str(leaf.sharding.spec)
    assert len(leaf.sharding.device_set) == 8
    print("PPTP OK", l_tp, lg, lf)
""")


def test_pipeline_composes_with_tensor_parallelism():
    """(stage=2, data=2, model=2): `--stages 2` over Megatron-sharded
    blocks matches the tp-only baseline for gpipe and 1f1b."""
    r = subprocess.run([sys.executable, "-c", PPTP_TRAIN_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "PPTP OK" in r.stdout


# --kernels pallas on the full PP×TP mesh (acceptance criterion): the
# Pallas dispatch runs inside the shard_map islands on tp-local shapes,
# and the 3-step loss trajectory must match the plain-jnp baseline for
# BOTH schedules.  The jnp baseline runs on the same (2,2,2) mesh so the
# only delta is the kernel path, not the pipeline arithmetic.
KERNELS_PPTP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.launch.train import build

    def run(schedule, flags):
        cfg, mesh, state, step, data = build(
            "granite-3-8b", smoke=True, global_batch=8, seq_len=64,
            stages=2, microbatch=2, schedule=schedule,
            mesh_shape=(2, 2, 2), axes=("stage", "data", "model"),
            seed=0, flags=flags)
        losses = []
        for i in range(3):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    base = run("gpipe", ())
    for schedule in ("gpipe", "1f1b"):
        lk = run(schedule, ("kernels_pallas",))
        diffs = [abs(a - b) / max(abs(a), 1e-9)
                 for a, b in zip(base, lk)]
        assert all(d < 2e-2 for d in diffs), (schedule, base, lk, diffs)
    print("KERNELS PPTP OK", base)
""")


def test_kernels_pallas_pipeline_matches_jnp_baseline():
    """`--kernels pallas` under (stage=2, data=2, model=2): kernel-path
    loss trajectories match the jnp baseline for gpipe and 1f1b."""
    r = subprocess.run([sys.executable, "-c", KERNELS_PPTP_SCRIPT],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "KERNELS PPTP OK" in r.stdout


# mamba under PP×TP: d_inner-sharded projections, per-head tensors sliced
# by the sharded specs, tp rmsnorm + row-parallel out_proj in the island
MAMBA_PPTP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.launch.train import build

    def run(stages, mesh_shape, axes, microbatch=0):
        cfg, mesh, state, step, data = build(
            "mamba2-370m", smoke=True, global_batch=8, seq_len=32,
            stages=stages, microbatch=microbatch, seed=0,
            mesh_shape=mesh_shape, axes=axes)
        losses = []
        for i in range(2):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        return losses

    l1 = run(1, (2, 2), ("data", "model"))
    l2 = run(2, (2, 1, 2), ("stage", "data", "model"), microbatch=2)
    diffs = [abs(a - b) / abs(a) for a, b in zip(l1, l2)]
    assert all(d < 2e-2 for d in diffs), (l1, l2, diffs)
    print("MAMBA PPTP OK")
""")


def test_mamba_pipeline_composes_with_model_axis():
    r = subprocess.run([sys.executable, "-c", MAMBA_PPTP_SCRIPT],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "MAMBA PPTP OK" in r.stdout


# dryrun pp×tp cell: the schedule's stage-axis ppermute bytes must be
# unchanged from the dp-only pipeline cell (the rotated activations are
# replicated over model), while model-axis all-reduces appear in the
# per-axis collective attribution (acceptance criterion).
DRYRUN_PPTP_SCRIPT = textwrap.dedent("""
    from repro.launch.dryrun import lower_cell   # sets 512 host devices
    from repro.models.common import ShapeSpec

    small = ShapeSpec("train_smoke", 64, 8, "train")
    kw = dict(smoke=True, shape_override=small, data_par=2, n_micro=2)
    pp = lower_cell("granite-3-8b", "train_4k", stages=2, **kw)
    tp = lower_cell("granite-3-8b", "train_4k", stages=2, model_par=2,
                    **kw)
    assert pp["mesh"] == "pp2" and tp["mesh"] == "pp2xtp2", (pp, tp)
    assert tp["pipeline"]["tp"] == 2
    assert pp["pipeline"]["ppermute_bytes"] > 0
    assert pp["pipeline"]["ppermute_bytes"] == tp["pipeline"][
        "ppermute_bytes"], (pp["pipeline"], tp["pipeline"])
    by_axis = tp["per_device"]["collective_bytes_by_axis"]
    assert by_axis.get("model", {}).get("all-reduce", 0.0) > 0, by_axis
    assert by_axis.get("stage", {}).get("collective-permute", 0.0) > 0
    # per-shard pricing: tp=2 halves the estimated block costs
    assert tp["pipeline"]["stage_time_s"] < pp["pipeline"]["stage_time_s"]
    print("DRYRUN PPTP OK")
""")


def test_dryrun_pptp_cell_stage_ppermute_unchanged():
    r = subprocess.run([sys.executable, "-c", DRYRUN_PPTP_SCRIPT],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-2500:]}"
    assert "DRYRUN PPTP OK" in r.stdout


# ------------------------------------------------- mesh CLI validation
def test_parse_mesh_cli_validates_against_stages():
    from repro.launch.train import parse_mesh_cli

    assert parse_mesh_cli(None, None, 1) == (None, None)
    assert parse_mesh_cli("2,2,2", None, 2) == \
        ((2, 2, 2), ("stage", "data", "model"))
    assert parse_mesh_cli("4,2", "data,model", 1) == \
        ((4, 2), ("data", "model"))
    with pytest.raises(ValueError):        # --axes without --mesh-shape
        parse_mesh_cli(None, "data,model", 1)
    with pytest.raises(ValueError):        # rank mismatch
        parse_mesh_cli("2,2,2", "data,model", 1)
    with pytest.raises(ValueError):        # unknown axis name
        parse_mesh_cli("2,2", "data,expert", 1)
    with pytest.raises(ValueError):        # stage axis size != --stages
        parse_mesh_cli("2,2,2", "stage,data,model", 4)
    with pytest.raises(ValueError):        # stage axis without --stages
        parse_mesh_cli("2,2,2", "stage,data,model", 1)
    with pytest.raises(ValueError):        # not ints
        parse_mesh_cli("2,x", "data,model", 1)
