"""Optional-hypothesis shim: property tests skip when hypothesis is
absent, the rest of the module still collects and runs.

Usage (in a test module):

    from _hypothesis_compat import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    import pytest as _pytest

    class _StrategyStub:
        """Accepts any `st.<strategy>(...)` so decorators still evaluate."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def given(*a, **k):
        return _pytest.mark.skip(
            reason="hypothesis not installed — run `pip install -e .[dev]` "
                   "to enable the property-based tests")

    def settings(*a, **k):
        return lambda fn: fn
