"""Training-substrate tests: data determinism, checkpoint/restart, fault
tolerance, straggler detection, elastic resharding, gradient compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.ckpt import CheckpointManager, latest_step, load_checkpoint, \
    save_checkpoint
from repro.data import DataConfig, SyntheticLM, TokenFileDataset
from repro.data.pipeline import write_token_file
from repro.dist.compression import (compressed_psum, dequantize_int8,
                                    init_errors, quantize_int8)
from repro.runtime import FTConfig, StragglerMonitor, TrainDriver
from repro.runtime.elastic import reshard_tree


# ------------------------------------------------------------------- data
def test_synthetic_deterministic():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=128, seed=7)
    ds = SyntheticLM(cfg)
    b1, b2 = ds.batch_at(5), ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next tokens
    full1 = ds.batch_at(0)
    assert full1["tokens"].shape == (4, 32)


def test_host_sharding_disjoint():
    kw = dict(seq_len=16, global_batch=8, vocab_size=64, seed=1, num_hosts=2)
    d0 = SyntheticLM(DataConfig(host_id=0, **kw))
    d1 = SyntheticLM(DataConfig(host_id=1, **kw))
    b0, b1 = d0.batch_at(3), d1.batch_at(3)
    assert b0["tokens"].shape == (4, 16)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_token_file_dataset(tmp_path):
    toks = np.arange(17 * 40, dtype=np.int32) % 100
    path = tmp_path / "toks.bin"
    write_token_file(path, toks)
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=100,
                     path=str(path))
    ds = TokenFileDataset(cfg)
    b = ds.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][0], toks[:16])
    np.testing.assert_array_equal(b["labels"][0], toks[1:17])
    # wraps around, deterministic
    np.testing.assert_array_equal(ds.batch_at(100)["tokens"],
                                  ds.batch_at(100)["tokens"])


# ------------------------------------------------------------- checkpoint
def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 4)),
            "opt": {"m": jnp.zeros((8, 4)), "count": jnp.asarray(3)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 42, tree)
    assert latest_step(tmp_path) == 42
    restored = load_checkpoint(tmp_path, 42, jax.tree.map(jnp.zeros_like,
                                                          tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (10, 20, 30):
        mgr.save(s, _tree(s))
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [20, 30]
    got = mgr.restore_latest(jax.tree.map(jnp.zeros_like, _tree()))
    assert got is not None and got[0] == 30


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, 1, {"w": jnp.zeros((5,))})


# --------------------------------------------------------- fault tolerance
class _FlakyStep:
    """Fails deterministically at a chosen step, once."""

    def __init__(self, fail_at):
        self.fail_at = fail_at
        self.failed = False

    def __call__(self, state, batch):
        step = int(state["step"])
        if step == self.fail_at and not self.failed:
            self.failed = True
            raise RuntimeError("injected node failure")
        loss = jnp.float32(1.0 / (step + 1))
        return {"step": state["step"] + 1,
                "w": state["w"] + batch["tokens"].sum()}, {"loss": loss}


def test_driver_recovers_from_failure(tmp_path):
    ds = SyntheticLM(DataConfig(seq_len=8, global_batch=2, vocab_size=32))
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=3, max_restores=2)
    state = {"step": jnp.asarray(0), "w": jnp.zeros((), jnp.float32)}
    step_fn = _FlakyStep(fail_at=5)
    driver = TrainDriver(step_fn, ds, cfg, state)
    driver.run(10)
    assert driver.step == 10
    # a deterministic replay without failure gives the same final state
    clean = TrainDriver(_FlakyStep(fail_at=-1), ds,
                        FTConfig(ckpt_dir=str(tmp_path / "clean")), state)
    clean.run(10)
    np.testing.assert_allclose(float(driver.state["w"]),
                               float(clean.state["w"]))


def test_driver_gives_up_after_budget(tmp_path):
    class AlwaysFails:
        def __call__(self, state, batch):
            raise RuntimeError("hard failure")

    ds = SyntheticLM(DataConfig(seq_len=8, global_batch=2, vocab_size=32))
    cfg = FTConfig(ckpt_dir=str(tmp_path), max_restores=2)
    driver = TrainDriver(AlwaysFails(), ds, cfg,
                         {"step": jnp.asarray(0)})
    with pytest.raises(RuntimeError):
        driver.run(5)


def test_resume_from_checkpoint(tmp_path):
    ds = SyntheticLM(DataConfig(seq_len=8, global_batch=2, vocab_size=32))
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=2)
    state = {"step": jnp.asarray(0), "w": jnp.zeros((), jnp.float32)}
    d1 = TrainDriver(_FlakyStep(fail_at=-1), ds, cfg, state)
    d1.run(6)
    d2 = TrainDriver.resume_or_init(_FlakyStep(fail_at=-1), ds, cfg, state)
    assert d2.step == 6
    d2.run(4)
    assert d2.step == 10


def test_driver_retry_restores_with_shardings(tmp_path):
    """The restore-retry path must thread the driver's shardings: after
    a recovery, state leaves carry the driver's NamedShardings, not the
    replicated placement a bare load gives."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    ds = SyntheticLM(DataConfig(seq_len=8, global_batch=2, vocab_size=32))
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=3, max_restores=2)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    state = {"step": jnp.asarray(0), "w": jnp.zeros((), jnp.float32)}
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    state = jax.tree.map(jax.device_put, state, shardings)
    driver = TrainDriver(_FlakyStep(fail_at=5), ds, cfg, state,
                         shardings=shardings)
    driver.run(10)
    assert driver.step == 10
    assert [e["kind"] for e in driver.events] == ["restore"]
    # the post-recovery save round-trips through restore_latest with the
    # driver's shardings — leaves land as NamedShardings on the mesh
    restored = driver.manager.restore_latest(state, shardings)
    assert restored is not None
    for leaf in jax.tree.leaves(restored[1]):
        assert isinstance(leaf.sharding, NamedSharding), leaf.sharding


def test_driver_emergency_save_coexists_with_periodic(tmp_path):
    """A failure at a step that already has a periodic checkpoint must
    not clobber it: the emergency save publishes under its own tag."""
    ds = SyntheticLM(DataConfig(seq_len=8, global_batch=2, vocab_size=32))
    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=3, max_restores=2,
                   keep=5)
    state = {"step": jnp.asarray(0), "w": jnp.zeros((), jnp.float32)}
    driver = TrainDriver(_FlakyStep(fail_at=6), ds, cfg, state)
    driver.run(10)
    names = {p.name for p in tmp_path.glob("step_*")}
    assert "step_00000006" in names            # periodic, after step 5
    assert "step_00000006_emergency" in names  # the failure dump
    from repro.ckpt import read_manifest
    assert read_manifest(tmp_path, 6)["tag"] == "periodic"


def test_straggler_monitor():
    mon = StragglerMonitor(factor=3.0, alpha=0.5)
    assert not mon.observe(0, 1.0)
    assert not mon.observe(1, 1.1)
    assert mon.observe(2, 10.0)           # 10× the EWMA → straggler
    assert len(mon.events) == 1
    # the spike must not poison the baseline
    assert mon.ewma < 2.0


# ------------------------------------------------------------------ elastic
def test_reshard_tree_smaller_mesh():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh_a = make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    specs = {"w": P(None, None)}
    moved = reshard_tree(tree, specs, mesh_a)
    np.testing.assert_array_equal(np.asarray(moved["w"]),
                                  np.asarray(tree["w"]))


# -------------------------------------------------------------- compression
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_quantize_roundtrip_bounded(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)) * rng.uniform(0.1, 10), jnp.float32)
    q, s = quantize_int8(x)
    err = np.asarray(dequantize_int8(q, s) - x)
    assert np.abs(err).max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """With error feedback, the *sum* of dequantized grads tracks the sum
    of true grads (residual stays bounded, doesn't accumulate)."""
    rng = np.random.default_rng(0)
    e = jnp.zeros((32,), jnp.float32)
    total_true = np.zeros(32)
    total_sent = np.zeros(32)
    for step in range(50):
        g = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
        total_true += np.asarray(g)
        q, s = quantize_int8(g + e)
        deq = dequantize_int8(q, s)
        e = (g + e) - deq
        total_sent += np.asarray(deq)
    # cumulative difference equals the final residual only
    np.testing.assert_allclose(total_true - total_sent, np.asarray(e),
                               rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(e)).max() < 1.0


def test_compressed_psum_shard_map():
    """compressed_psum under shard_map on ≥1 devices matches plain mean."""
    from repro.dist.compat import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    n = len(jax.devices())
    mesh = make_mesh((n,), ("dp",))
    rng = np.random.default_rng(1)
    grads = jnp.asarray(rng.normal(size=(n, 64)), jnp.float32)
    errors = jnp.zeros((n, 64), jnp.float32)

    @jax.jit
    def run(g, e):
        def f(g, e):
            m, ne = compressed_psum(g[0], "dp", e[0])
            return m[None], ne[None]
        return shard_map(f, mesh=mesh, in_specs=(P("dp"), P("dp")),
                         out_specs=(P("dp"), P("dp")))(g, e)

    mean, new_e = run(grads, errors)
    true_mean = np.asarray(grads).mean(axis=0)
    got = np.asarray(mean)[0]
    # int8 quantization error is bounded by scale/2 per tensor
    scale = np.abs(np.asarray(grads)).max(axis=1, keepdims=True) / 127
    assert np.abs(got - true_mean).max() <= scale.max() + 1e-5


# ----------------------------------------------------------- end-to-end fit
def test_train_loop_loss_decreases(tmp_path):
    """Real end-to-end: tiny model + driver + checkpointing; loss drops."""
    from repro.launch.train import build
    cfg, mesh, state, step_fn, data = build(
        "granite-3-8b", smoke=True, global_batch=4, seq_len=32, lr=3e-3)
    driver = TrainDriver(step_fn, data,
                         FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100),
                         state)
    driver.run(30)
    losses = [m["loss"] for m in driver.metrics_log]
    assert losses[-1] < losses[0] - 0.1, f"no learning: {losses[:3]}...{losses[-3:]}"
