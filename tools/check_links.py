#!/usr/bin/env python
"""Check that every relative link in the repo's Markdown files resolves.

Walks the tree for ``*.md`` (skipping VCS/cache/output dirs), extracts
inline links and images (``[text](target)``), and verifies each
relative target exists on disk, resolved against the linking file's
directory.  External schemes (http/https/mailto), pure in-page anchors
(``#...``), and absolute paths are ignored; an anchor suffix on a
relative link is stripped before the existence check.

Exit status: 0 when all links resolve, 1 otherwise (each breakage is
printed as ``file:line: broken link -> target``).  No dependencies
beyond the standard library, so CI can run it without installing the
package: ``python tools/check_links.py`` (or ``make docs-check``).
"""
from __future__ import annotations

import argparse
import pathlib
import re
import sys

SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "results", ".ruff_cache",
             "node_modules", ".venv", "venv", "checkpoints"}
# files whose markdown is *quoted* from other repositories, so their
# relative links point into those repos, not this one
SKIP_FILES = {"SNIPPETS.md"}
# inline [text](target) / ![alt](target); target ends at the first
# unescaped ')' — angle-bracketed targets <...> are unwrapped below
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+(?:\s+\"[^\"]*\")?)\)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def md_files(root: pathlib.Path) -> list[pathlib.Path]:
    out = []
    for p in sorted(root.rglob("*.md")):
        if p.name in SKIP_FILES:
            continue
        if not any(part in SKIP_DIRS for part in p.parts):
            out.append(p)
    return out


def check_file(path: pathlib.Path) -> list[str]:
    errors = []
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1).split()[0].strip("<>")
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            if target.startswith("/"):      # absolute: out of repo scope
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (path.parent / rel).exists():
                errors.append(f"{path}:{lineno}: broken link -> {target}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("root", nargs="?", default=".",
                    help="directory to scan (default: cwd)")
    args = ap.parse_args()
    root = pathlib.Path(args.root)

    files = md_files(root)
    errors = []
    for f in files:
        errors.extend(check_file(f))
    for e in errors:
        print(e)
    print(f"checked {len(files)} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
