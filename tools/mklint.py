#!/usr/bin/env python
"""mklint: statically verify launch configurations without compiling.

Runs `repro.analysis.verify_launch` over one config (train.py-style
flags) or the bench-smoke preset, prints each report with its rule IDs
and per-config wall time, and exits 1 if any config has errors.

Examples:

  # one config, dryrun-style pipeline mesh
  python tools/mklint.py --arch jamba-v0.1-52b --smoke --shape train_4k \
      --stages 3 --data-par 2 --microbatch 2 --schedule 1f1b

  # explicit pp x tp mesh, train.py-style
  python tools/mklint.py --arch granite-3-8b --smoke --stages 2 \
      --microbatch 2 --mesh-shape 2,2,2 --axes stage,data,model \
      --global-batch 8 --seq-len 64

  # everything `make bench-smoke` exercises (every schedule incl.
  # interleaved --virtual-stages, heterogeneous --stages 3, the
  # pp x tp cell) in one process
  python tools/mklint.py --preset bench-smoke

  # machine-readable reports (stable schema; CI problem matcher reads
  # the default text format)
  python tools/mklint.py --preset bench-smoke --format json

  # also run the MK-T planner checks: is this config statically
  # dominated on its own mesh?
  python tools/mklint.py --arch jamba-v0.1-52b --smoke --stages 2 \
      --microbatch 2 --mesh-shape 2,2,2 --axes stage,data,model \
      --global-batch 8 --seq-len 64 --plan --mem-budget-gb 16

Device handling: argument parsing and the mesh-size arithmetic run
before any jax import; the needed fake host device count is injected
via XLA_FLAGS, so linting a 16-device mesh works on a laptop CPU.
"""
from __future__ import annotations

import argparse
import os
import sys

# the bench-smoke matrix (mirrors the Makefile's dryrun cells, which use
# SHAPES["train_4k"]: global_batch=256, seq_len=4096, plus the test-dist
# pp x tp train CLI cell), every schedule
_BENCH_SMOKE = [
    dict(arch="granite-3-8b", smoke=True, shape="train_4k",
         stages=2, model_par=2, data_par=4, microbatch=2,
         schedule="gpipe"),
    dict(arch="granite-3-8b", smoke=True, shape="train_4k",
         stages=2, model_par=2, data_par=4, microbatch=2,
         schedule="1f1b"),
    dict(arch="jamba-v0.1-52b", smoke=True, shape="train_4k",
         stages=3, data_par=2, microbatch=2, schedule="gpipe"),
    dict(arch="jamba-v0.1-52b", smoke=True, shape="train_4k",
         stages=3, data_par=2, microbatch=2, schedule="1f1b"),
    dict(arch="granite-3-8b", smoke=True, global_batch=8, seq_len=64,
         stages=2, microbatch=2, mesh_shape="2,2,2",
         axes="stage,data,model", schedule="gpipe"),
    # the --kernels pallas pp x tp cell: islands trace with the Pallas
    # dispatch engaged, so the collective/spec rules see the kernel path
    dict(arch="granite-3-8b", smoke=True, global_batch=8, seq_len=64,
         stages=2, microbatch=2, mesh_shape="2,2,2",
         axes="stage,data,model", schedule="1f1b",
         flags=("kernels_pallas",)),
    # interleaved virtual stages: jamba smoke (n_repeats=4) is the only
    # smoke config deep enough for v*stages = 4 groups
    dict(arch="jamba-v0.1-52b", smoke=True, global_batch=8, seq_len=64,
         stages=2, microbatch=2, mesh_shape="2,2,2",
         axes="stage,data,model", schedule="interleaved",
         virtual_stages=2),
]


def _mesh_product(cfg: dict) -> int:
    """Devices one config's mesh needs — pure arithmetic, no jax."""
    shape = cfg.get("mesh_shape")
    if shape:
        n = 1
        for s in str(shape).split(","):
            if s.strip():
                try:
                    n *= max(int(s), 1)
                except ValueError:
                    return 1          # malformed: the mesh rules report it
        return n
    n = max(cfg.get("stages", 1), 1) * max(cfg.get("model_par", 1), 1)
    return n * max(cfg.get("data_par") or 1, 1)


def _parse_args(argv):
    ap = argparse.ArgumentParser(
        description="static verifier for launch configurations (mklint)")
    ap.add_argument("--preset", choices=["bench-smoke"],
                    help="lint a built-in config matrix instead of one "
                         "--arch config")
    ap.add_argument("--arch")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--shape", default=None,
                    help="take global batch / seq len from a named shape "
                         "cell (e.g. train_4k), like launch.dryrun")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--stages", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--data-par", type=int, default=None)
    ap.add_argument("--mesh-shape", default=None)
    ap.add_argument("--axes", default=None)
    ap.add_argument("--schedule", default="gpipe")
    ap.add_argument("--virtual-stages", type=int, default=1,
                    help="chunks per device for --schedule interleaved")
    ap.add_argument("--grad-int8", action="store_true")
    ap.add_argument("--no-kernels", action="store_true",
                    help="skip the (config-independent) Pallas kernel "
                         "geometry checks")
    ap.add_argument("--plan", action="store_true",
                    help="also run the MK-T planner checks: score the "
                         "config's whole launch space (analytic cost "
                         "models, nothing compiles) and warn if it is "
                         "statically dominated")
    ap.add_argument("--mem-budget-gb", type=float, default=None,
                    help="per-device memory budget for the MK-T002 "
                         "peak-bytes check (with --plan)")
    ap.add_argument("--format", choices=["text", "json"], default="text",
                    help="json emits a stable schema (version, reports "
                         "with rule/severity/loc/msg/hint) for tooling")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print info-severity diagnostics")
    args = ap.parse_args(argv)
    if not args.preset and not args.arch:
        ap.error("pass --arch (one config) or --preset bench-smoke")
    if args.plan and args.preset:
        ap.error("--plan checks one --arch config, not a preset")
    return args


def _plan_report(args, cfg: dict):
    """Run the MK-T planner checks on the flag-specified config.

    Pure arithmetic over the analytic cost models — rebuilds the
    `LaunchCandidate` the flags describe, enumerates its device count's
    launch space, and reports dominated/over-budget/leaving-bubble
    findings as warnings.
    """
    from repro.analysis.planner import LaunchCandidate, check_plan
    from repro.configs import get_config, get_smoke

    model = (get_smoke(cfg["arch"]) if cfg.get("smoke")
             else get_config(cfg["arch"]))
    stages, dp, tp = cfg.get("stages", 1), cfg.get("data_par"), \
        cfg.get("model_par", 1)
    shape, axes = cfg.get("mesh_shape"), cfg.get("axes")
    if shape and axes:                    # explicit mesh wins, per train.py
        sizes = dict(zip([a.strip() for a in str(axes).split(",")],
                         [int(s) for s in str(shape).split(",")]))
        stages = sizes.get("stage", stages)
        dp = sizes.get("data", dp)
        tp = sizes.get("model", tp)
    chosen = LaunchCandidate(
        stages=stages, microbatch=max(cfg.get("microbatch", 1), 1),
        schedule=cfg.get("schedule", "gpipe"),
        virtual_stages=max(cfg.get("virtual_stages", 1), 1),
        tp=max(tp or 1, 1), dp=max(dp or 1, 1),
        kernels="pallas" if "kernels_pallas" in cfg.get("flags", ())
        else "off")
    budget = (args.mem_budget_gb * 2**30
              if args.mem_budget_gb is not None else None)
    return check_plan(model, chosen, global_batch=cfg["global_batch"],
                      seq_len=cfg["seq_len"], mem_budget_bytes=budget)


def main(argv=None) -> int:
    args = _parse_args(argv)
    if args.preset == "bench-smoke":
        configs = [dict(c) for c in _BENCH_SMOKE]
    else:
        configs = [dict(
            arch=args.arch, smoke=args.smoke, shape=args.shape,
            global_batch=args.global_batch, seq_len=args.seq_len,
            stages=args.stages, microbatch=args.microbatch,
            model_par=args.model_par, data_par=args.data_par,
            mesh_shape=args.mesh_shape, axes=args.axes,
            schedule=args.schedule, virtual_stages=args.virtual_stages,
            flags=("grad_int8",) if args.grad_int8 else ())]

    # fake enough host devices for the largest mesh BEFORE jax locks the
    # backend (same trick as launch.dryrun); never shrink a user setting
    need = max(_mesh_product(c) for c in configs)
    if "XLA_FLAGS" not in os.environ and need > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={need}")

    from repro.analysis import verify_launch
    from repro.configs import SHAPES

    failed = 0
    reports = []
    for i, cfg in enumerate(configs):
        shape = cfg.pop("shape", None)
        if shape:
            cfg.setdefault("global_batch", SHAPES[shape].global_batch)
            cfg.setdefault("seq_len", SHAPES[shape].seq_len)
        # kernel geometry is config-independent: check it once per run
        kw = dict(cfg)
        kw.setdefault("check_kernels", not args.no_kernels and i == 0)
        report = verify_launch(**kw)
        reports.append(report)
        if args.plan:
            # MK-T diagnostics are warnings by design: planners advise,
            # launches proceed — --plan never flips the exit code
            reports.append(_plan_report(args, cfg))
        if not report.ok:
            failed += 1
    if args.format == "json":
        import json
        print(json.dumps({"version": 1,
                          "reports": [r.as_dict() for r in reports]},
                         indent=1, sort_keys=True))
    else:
        for report in reports:
            print(report.format(verbose=args.verbose))
        if len(configs) > 1:
            print(f"mklint: {len(configs) - failed}/{len(configs)} "
                  "configs clean")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
