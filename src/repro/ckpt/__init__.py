from .checkpoint import (CheckpointManager, checkpoint_path, latest_step,
                         load_checkpoint, read_manifest, save_checkpoint,
                         save_checkpoint_v1, snapshot_nbytes,
                         snapshot_tree, spec_from_json, write_snapshot)

__all__ = ["CheckpointManager", "checkpoint_path", "latest_step",
           "load_checkpoint", "read_manifest", "save_checkpoint",
           "save_checkpoint_v1", "snapshot_nbytes", "snapshot_tree",
           "spec_from_json", "write_snapshot"]
