"""Sharded, atomic, async checkpointing.

Layout: <dir>/step_<N>/{arrays.npz, manifest.json} written to a temp dir
and atomically renamed, so a crash mid-save never corrupts the latest
checkpoint.  `CheckpointManager` keeps a bounded history, saves on a
background thread (training continues), and `restore()` resharding arrays
onto whatever mesh the restarted job has (elastic restarts).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str | pathlib.Path, step: int, tree: Any,
                    extra: dict | None = None) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}_{time.time_ns()}"
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "time": time.time(),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # atomic publish
    return final


def latest_step(directory: str | pathlib.Path) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.glob("step_*")
             if (p / "manifest.json").exists()]
    return max(steps) if steps else None


def load_checkpoint(directory: str | pathlib.Path, step: int,
                    like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like`; reshard when given shardings."""
    path = pathlib.Path(directory) / f"step_{step:08d}"
    data = np.load(path / "arrays.npz")
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_paths))
    out = []
    for (path_k, leaf), sh in zip(leaves_paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_k)
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint {arr.shape} vs "
                             f"model {tuple(leaf.shape)}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async bounded-history manager with crash-safe publishes."""

    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None,
             blocking: bool = False) -> None:
        self.wait()                        # one in flight at a time
        host_tree = jax.tree.map(np.asarray, tree)   # snapshot now

        def _run():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:     # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> tuple[int, Any] | None:
        step = latest_step(self.directory)
        if step is None:
            return None
        return step, load_checkpoint(self.directory, step, like, shardings)

    def _gc(self) -> None:
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
