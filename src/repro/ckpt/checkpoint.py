"""Sharded, mesh-agnostic, atomic, async checkpointing (format v2).

Layout::

    <dir>/step_<N>[_emergency]/
        manifest.json            # format version, per-leaf metadata
        shards/L<i>_S<j>.npy     # one file per unique array shard

Save never host-gathers a full array: each leaf is snapshotted through
`jax.Array.addressable_shards`, so only per-device shard views are
copied to host (deduplicated by shard index — a leaf replicated over 8
devices writes one file, a stage-sharded leaf writes one file per stage
slice).  The manifest records, per leaf, the *global* shape, dtype,
`PartitionSpec`, and mesh axes/shape it was saved under, which is what
makes restore mesh-agnostic: `load_checkpoint` reassembles the global
array on host from the shard files and `device_put`s it with whatever
shardings the restored job's mesh wants — a different stage count, a
different data degree, or a single device.

Crash safety and history are unchanged from v1: checkpoints are written
to a temp dir and atomically renamed (a crash mid-save never corrupts
the newest checkpoint), `CheckpointManager` saves on a background
thread with a bounded history, and the v1 single-``arrays.npz`` format
is still readable (`load_checkpoint` dispatches on the manifest's
``version``; `save_checkpoint_v1` keeps the host-gathering writer for
migration tests and the save-path A/B in ``benchmarks/ckpt_bench.py``).

Emergency saves (``tag="emergency"``) publish to a distinct
``step_<N>_emergency`` directory so they never clobber a periodic
checkpoint at the same step, and `_gc` never collects the newest
emergency checkpoint.

Restore is linted before any array is touched: `check_restore_manifest`
(`repro.analysis.elastic`, rule ``MK-R001``) compares the manifest
against the target tree and mesh — tree/shape mismatches and corrupt
shard files raise a `DiagnosticError` with a fix hint, spec entries the
new mesh cannot realize are logged as warnings (the restore still
proceeds; those leaves land replicated unless explicit shardings say
otherwise).
"""
from __future__ import annotations

import dataclasses
import json
import logging
import pathlib
import shutil
import threading
import time
import zlib
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

log = logging.getLogger("repro.ckpt")

FORMAT_VERSION = 2

#: checkpoint kinds: periodic saves publish to ``step_<N>``, emergency
#: saves (the driver's last-good-state dump on a failure) to
#: ``step_<N>_emergency`` — distinct names, so an emergency save at a
#: step that also has a periodic checkpoint clobbers nothing
TAGS = ("periodic", "emergency")


def _key(path: tuple) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    """v1 helper: host-gathered flat {key: array} (kept for the legacy
    writer and the v1 read path)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_key(path)] = np.asarray(leaf)
    return flat


# ------------------------------------------------------------- snapshot
def _spec_to_json(spec: PartitionSpec | None) -> list | None:
    """PartitionSpec → JSON: each entry None | "axis" | ["a", "b"]."""
    if spec is None:
        return None
    out: list = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            out.append([str(a) for a in entry])
        else:
            out.append(str(entry))
    return out


def spec_from_json(entries: list | None) -> PartitionSpec | None:
    """Inverse of `_spec_to_json` (tuple entries come back as tuples)."""
    if entries is None:
        return None
    return PartitionSpec(*[tuple(e) if isinstance(e, list) else e
                           for e in entries])


def _norm_index(index: tuple, shape: tuple[int, ...]
                ) -> tuple[tuple[int, int], ...]:
    """A shard's `.index` (tuple of slices) → ((start, stop), ...)."""
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        out.append((start, stop))
    return tuple(out)


@dataclasses.dataclass
class LeafSnapshot:
    """One leaf's host-side shard snapshot + restore metadata."""
    key: str
    shape: tuple[int, ...]
    dtype: str
    spec: list | None                  # serialized PartitionSpec
    mesh: dict | None                  # {"axes": [...], "shape": [...]}
    shards: list[tuple[tuple[tuple[int, int], ...], np.ndarray]]

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for _, a in self.shards)


def snapshot_tree(tree: Any) -> list[LeafSnapshot]:
    """Copy each leaf's *addressable shards* to host, deduplicated by
    shard index — the full array is never materialized in one buffer.

    Called synchronously by `CheckpointManager.save` so the background
    writer works from a stable copy; the per-shard copies are the only
    device→host traffic the save path performs.
    """
    snaps: list[LeafSnapshot] = []
    for i, (path, leaf) in enumerate(
            jax.tree_util.tree_flatten_with_path(tree)[0]):
        key = _key(path)
        spec = mesh = None
        if isinstance(leaf, jax.Array):
            sharding = leaf.sharding
            if isinstance(sharding, NamedSharding):
                spec = _spec_to_json(sharding.spec)
                mesh = {"axes": list(sharding.mesh.axis_names),
                        "shape": [int(s) for s in
                                  sharding.mesh.devices.shape]}
            seen: dict[tuple, np.ndarray] = {}
            for sh in leaf.addressable_shards:
                idx = _norm_index(sh.index, leaf.shape)
                if idx not in seen:
                    seen[idx] = np.asarray(sh.data)
            shards = sorted(seen.items())
            shape, dtype = tuple(leaf.shape), str(leaf.dtype)
        else:
            arr = np.asarray(leaf)
            shards = [(tuple((0, d) for d in arr.shape), arr)]
            shape, dtype = tuple(arr.shape), str(arr.dtype)
        snaps.append(LeafSnapshot(key=key, shape=shape, dtype=dtype,
                                  spec=spec, mesh=mesh, shards=shards))
    return snaps


def snapshot_nbytes(snaps: Sequence[LeafSnapshot]) -> int:
    """Total unique-shard bytes a save of `snaps` writes (the v2 side of
    the ``benchmarks/ckpt_bench.py`` bytes-moved row)."""
    return sum(s.nbytes for s in snaps)


# ------------------------------------------------------------ save path
def _step_dir_name(step: int, tag: str = "periodic") -> str:
    if tag not in TAGS:
        raise ValueError(f"unknown checkpoint tag {tag!r}; want {TAGS}")
    suffix = "" if tag == "periodic" else f"_{tag}"
    return f"step_{step:08d}{suffix}"


def write_snapshot(directory: str | pathlib.Path, step: int,
                   snaps: Sequence[LeafSnapshot],
                   extra: dict | None = None,
                   tag: str = "periodic") -> pathlib.Path:
    """Publish an already-snapshotted tree: shard files + manifest into a
    temp dir, then one atomic rename."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / _step_dir_name(step, tag)
    tmp = directory / f".tmp_{final.name}_{time.time_ns()}"
    (tmp / "shards").mkdir(parents=True)

    leaves = []
    for i, snap in enumerate(snaps):
        recs = []
        for j, (idx, arr) in enumerate(snap.shards):
            fname = f"shards/L{i:04d}_S{j:03d}.npy"
            # custom dtypes (bfloat16 & friends register as kind 'V')
            # don't survive the .npy descr — store the raw bytes; the
            # reader views them back through the manifest's leaf dtype
            out_arr = arr.view(np.uint8) if arr.dtype.kind == "V" else arr
            np.save(tmp / fname, out_arr, allow_pickle=False)
            recs.append({"file": fname,
                         "index": [list(p) for p in idx],
                         "nbytes": int(arr.nbytes),
                         "crc32": zlib.crc32(arr.tobytes())})
        leaves.append({"key": snap.key, "shape": list(snap.shape),
                       "dtype": snap.dtype, "spec": snap.spec,
                       "mesh": snap.mesh, "shards": recs})
    manifest = {
        "version": FORMAT_VERSION,
        "step": step,
        "tag": tag,
        "time": time.time(),
        "extra": extra or {},
        "leaves": leaves,
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                  # atomic publish
    return final


def save_checkpoint(directory: str | pathlib.Path, step: int, tree: Any,
                    extra: dict | None = None,
                    tag: str = "periodic") -> pathlib.Path:
    """Snapshot + publish in one call (format v2, per-shard files)."""
    return write_snapshot(directory, step, snapshot_tree(tree),
                          extra=extra, tag=tag)


def save_checkpoint_v1(directory: str | pathlib.Path, step: int,
                       tree: Any, extra: dict | None = None
                       ) -> pathlib.Path:
    """The legacy host-gathering writer (single ``arrays.npz``).

    Kept for the v1→v2 migration tests and the save-path A/B in
    ``benchmarks/ckpt_bench.py`` — every np.asarray here materializes
    the *full* global array on host, which is exactly what the v2 path
    avoids.  New code should call `save_checkpoint`.
    """
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f".tmp_step_{step:08d}_{time.time_ns()}"
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "time": time.time(),
        "extra": extra or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


# ------------------------------------------------------- step discovery
def _step_of(path: pathlib.Path) -> int:
    return int(path.name.split("_")[1])


def _is_emergency(path: pathlib.Path) -> bool:
    return path.name.endswith("_emergency")


def _step_dirs(directory: pathlib.Path) -> list[pathlib.Path]:
    return [p for p in directory.glob("step_*")
            if (p / "manifest.json").exists()]


def checkpoint_path(directory: str | pathlib.Path,
                    step: int) -> pathlib.Path:
    """Resolve a step to its checkpoint dir — the periodic checkpoint
    when both it and an emergency one exist (they hold the same state;
    the periodic dir is the canonical publish)."""
    directory = pathlib.Path(directory)
    periodic = directory / _step_dir_name(step)
    if (periodic / "manifest.json").exists():
        return periodic
    emergency = directory / _step_dir_name(step, "emergency")
    if (emergency / "manifest.json").exists():
        return emergency
    raise FileNotFoundError(f"no checkpoint for step {step} in "
                            f"{directory}")


def latest_step(directory: str | pathlib.Path) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = [_step_of(p) for p in _step_dirs(directory)]
    return max(steps) if steps else None


def read_manifest(directory: str | pathlib.Path, step: int) -> dict:
    path = checkpoint_path(directory, step) / "manifest.json"
    try:
        return json.loads(path.read_text())
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        from repro.analysis.diagnostics import DiagnosticError
        from repro.analysis.elastic import manifest_error
        raise DiagnosticError(
            [manifest_error(str(path), f"manifest is not valid JSON "
                            f"({e})",
                            hint="the checkpoint directory is corrupt "
                                 "or was truncated mid-copy; restore "
                                 "an older step or re-save")]) from e


# ------------------------------------------------------------ load path
def _mesh_info(shardings: Any) -> dict | None:
    """Target-mesh axes/shape from the first NamedSharding leaf (for the
    MK-R001 restore lint); None when no mesh is discernible."""
    for leaf in jax.tree_util.tree_leaves(shardings):
        if isinstance(leaf, NamedSharding):
            return {"axes": list(leaf.mesh.axis_names),
                    "shape": [int(s) for s in leaf.mesh.devices.shape]}
    return None


def _assemble_leaf(path: pathlib.Path, rec: dict) -> np.ndarray:
    """Reassemble one global array from its shard files, verifying every
    shard's crc32/extent and the leaf's total coverage."""
    from repro.analysis.diagnostics import DiagnosticError
    from repro.analysis.elastic import manifest_error

    shape = tuple(rec["shape"])
    dtype = jax.numpy.dtype(rec["dtype"])
    out = np.empty(shape, dtype)
    covered = 0
    for sh in rec["shards"]:
        fpath = path / sh["file"]
        try:
            arr = np.load(fpath, allow_pickle=False)
        except Exception as e:
            raise DiagnosticError(
                [manifest_error(
                    f"{rec['key']} ({fpath.name})",
                    f"shard file unreadable ({type(e).__name__}: {e})",
                    hint="the shard was corrupted or truncated after "
                         "publish; restore an older checkpoint")]) from e
        want_crc = sh.get("crc32")
        if want_crc is not None and zlib.crc32(arr.tobytes()) != want_crc:
            raise DiagnosticError(
                [manifest_error(
                    f"{rec['key']} ({fpath.name})",
                    "shard crc32 does not match the manifest",
                    hint="bit corruption on disk; restore an older "
                         "checkpoint or re-replicate the shard")])
        if dtype.kind == "V" and arr.dtype != dtype:
            arr = np.ascontiguousarray(arr).view(dtype)
        idx = tuple(slice(a, b) for a, b in sh["index"])
        want = tuple(b - a for a, b in sh["index"])
        if tuple(arr.shape) != want:
            raise DiagnosticError(
                [manifest_error(
                    f"{rec['key']} ({fpath.name})",
                    f"shard shape {tuple(arr.shape)} does not match its "
                    f"manifest index extent {want}",
                    hint="manifest and shard files disagree — the "
                         "checkpoint is corrupt")])
        out[idx] = arr
        covered += arr.size
    if covered < out.size:
        raise DiagnosticError(
            [manifest_error(
                rec["key"],
                f"shards cover {covered} of {out.size} elements",
                hint="missing shard files — the checkpoint is "
                     "truncated; restore an older step")])
    return out


def load_checkpoint(directory: str | pathlib.Path, step: int,
                    like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like`; reshard when given shardings.

    Reads both formats: v2 (per-shard files) reassembles each global
    array from its shards; v1 (single ``arrays.npz``) reads the legacy
    blob.  Either way each leaf is placed with its entry from
    `shardings` (a `NamedSharding` tree — *any* mesh, not just the one
    the checkpoint was saved under) or becomes a replicated
    ``jnp.asarray`` when `shardings` is None.

    The manifest is linted first (MK-R001, `repro.analysis.elastic`):
    tree/shape mismatches and corrupt or missing shards raise
    `DiagnosticError` (a ValueError) naming the leaf and the fix; spec
    entries the target mesh cannot realize only log warnings — the
    reassembled host array restores fine, it just lands replicated
    unless `shardings` says otherwise.
    """
    path = checkpoint_path(directory, step)
    manifest = read_manifest(directory, step)
    version = manifest.get("version", 1)

    leaves_paths = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree_util.tree_structure(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_paths))

    if version == 1:
        data = np.load(path / "arrays.npz")
        get = lambda key: data[key]
        missing = [
            _key(p) for p, _ in leaves_paths if _key(p) not in data.files]
        if missing:
            raise ValueError(f"checkpoint missing keys: {missing}")
    else:
        from repro.analysis.diagnostics import DiagnosticError
        from repro.analysis.elastic import check_restore_manifest
        like_info = {_key(p): tuple(np.shape(leaf))
                     for p, leaf in leaves_paths}
        diags = check_restore_manifest(manifest, like=like_info,
                                       mesh=_mesh_info(shardings),
                                       loc=str(path))
        errors = [d for d in diags if d.is_error]
        if errors:
            raise DiagnosticError(errors, prefix="cannot restore:")
        # every stage-sharded leaf warns identically on a shrunk mesh —
        # show a few, summarize the rest
        for d in diags[:3]:
            log.warning("%s", d.format())
        if len(diags) > 3:
            log.warning("MK-R001: ... and %d more leaves whose saved "
                        "spec the restore mesh cannot realize "
                        "(reassembled fine; resharded per `shardings`, "
                        "else replicated)", len(diags) - 3)
        records = {r["key"]: r for r in manifest["leaves"]}
        get = lambda key: _assemble_leaf(path, records[key])

    out = []
    for (path_k, leaf), sh in zip(leaves_paths, shard_leaves):
        key = _key(path_k)
        arr = get(key)
        if arr.shape != tuple(np.shape(leaf)):
            raise ValueError(f"{key}: checkpoint {arr.shape} vs "
                             f"model {tuple(np.shape(leaf))}")
        arr = arr.astype(np.asarray(leaf).dtype)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async bounded-history manager with crash-safe publishes.

    `save` snapshots the tree's addressable shards synchronously (no
    host-gather, no full-array buffer) and writes files on a background
    thread; errors surface on the next `wait()`/`save()`.  `_gc` keeps
    the newest `keep` periodic checkpoints and *always* keeps the newest
    emergency checkpoint (an emergency save records the last good state
    after a failure — collecting it would discard exactly the state a
    post-mortem restart needs).
    """

    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree: Any, extra: dict | None = None,
             blocking: bool = False, tag: str = "periodic") -> None:
        self.wait()                        # one in flight at a time
        snaps = snapshot_tree(tree)        # per-shard snapshot now

        def _run():
            try:
                write_snapshot(self.directory, step, snaps, extra=extra,
                               tag=tag)
                self._gc()
            except BaseException as e:     # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore_latest(self, like: Any, shardings: Any = None
                       ) -> tuple[int, Any] | None:
        step = latest_step(self.directory)
        if step is None:
            return None
        return step, load_checkpoint(self.directory, step, like, shardings)

    def _gc(self) -> None:
        dirs = _step_dirs(self.directory)
        periodic = sorted((p for p in dirs if not _is_emergency(p)),
                          key=_step_of)
        emergency = sorted((p for p in dirs if _is_emergency(p)),
                           key=_step_of)
        drop = periodic[:-self.keep] if self.keep > 0 else periodic
        # the newest emergency checkpoint is never collected; older
        # emergencies fall under the same bounded-history policy
        drop += emergency[:-max(self.keep, 1)]
        for p in drop:
            shutil.rmtree(p, ignore_errors=True)
