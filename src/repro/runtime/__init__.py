from .elastic import (ElasticBindings, choose_elastic_config,
                      reshard_tree, shrink_mesh)
from .faultinject import (DeviceLossError, FaultInjector, FaultSpec,
                          corrupt_shard, is_device_loss, stage_devices,
                          truncate_manifest)
from .ft import FTConfig, StragglerMonitor, TrainDriver

__all__ = ["DeviceLossError", "ElasticBindings", "FTConfig",
           "FaultInjector", "FaultSpec", "StragglerMonitor",
           "TrainDriver", "choose_elastic_config", "corrupt_shard",
           "is_device_loss", "reshard_tree", "shrink_mesh",
           "stage_devices", "truncate_manifest"]
