from .elastic import reshard_tree
from .ft import FTConfig, StragglerMonitor, TrainDriver

__all__ = ["reshard_tree", "FTConfig", "StragglerMonitor", "TrainDriver"]
