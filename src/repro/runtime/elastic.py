"""Elastic re-meshing: move a sharded pytree onto a different mesh.

On pod loss (or growth) the driver rebuilds the mesh from the surviving
devices and reshards params/optimizer state; the step function re-jits
against the new shardings.  Data parallelism re-splits by the determinism
contract of the data pipeline, so training resumes at the same step with
a smaller/larger global batch per the caller's policy.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding


def reshard_tree(tree: Any, specs: Any, new_mesh: Mesh) -> Any:
    """device_put every leaf onto `new_mesh` with its PartitionSpec.

    Works across device *sets* (survivor subsets), not just permutations:
    leaves are pulled to host then re-placed (production would use
    jax.device_put with compatible shardings for a DMA path; the host
    round-trip is the safe universal fallback).
    """
    def move(leaf, spec):
        sharding = NamedSharding(new_mesh, spec)
        return jax.device_put(np.asarray(leaf), sharding)

    return jax.tree.map(move, tree, specs)


def shrink_mesh(mesh: Mesh, failed_devices: set[int],
                axis: str) -> Mesh | None:
    """Drop the slices of `axis` containing failed devices; returns the
    surviving mesh or None if nothing survives."""
    devs = mesh.devices
    axis_idx = mesh.axis_names.index(axis)
    keep = []
    for i in range(devs.shape[axis_idx]):
        sl = np.take(devs, i, axis=axis_idx)
        if not any(d.id in failed_devices for d in sl.flatten()):
            keep.append(i)
    if not keep:
        return None
    new_devs = np.take(devs, keep, axis=axis_idx)
    return Mesh(new_devs, mesh.axis_names)
