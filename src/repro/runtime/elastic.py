"""Elastic re-meshing: survive device loss by shrinking and re-planning.

On pod loss the driver rebuilds the mesh from the surviving devices
(`shrink_mesh` drops the stage slices containing failed devices),
re-runs the pipeline planner on what remains (`choose_elastic_config`
prices every schedule knob the surviving mesh admits through the
mkplan cost models and picks the frontier's best step-time point),
reshards params/optimizer state from the latest sharded checkpoint
(or `reshard_tree` in memory when none exists), and re-jits the step
function against the new shardings.  Data parallelism re-splits by the
determinism contract of the data pipeline (`batch_at(step)` is a pure
function of seed and step), so training resumes at the restored step
with bit-identical batches.

`ElasticBindings` is the driver's hook into the launch layer: the
model config plus a ``rebuild(mesh, candidate) -> (step_fn,
shardings)`` closure (`repro.launch.train.build_elastic` constructs
one) — `TrainDriver` owns *when* to shrink, the bindings own *how* to
rebuild, and neither imports the other's internals.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

log = logging.getLogger("repro.elastic")


def reshard_tree(tree: Any, specs: Any, new_mesh: Mesh) -> Any:
    """device_put every leaf onto `new_mesh` with its PartitionSpec.

    Works across device *sets* (survivor subsets), not just permutations:
    leaves are pulled to host then re-placed (production would use
    jax.device_put with compatible shardings for a DMA path; the host
    round-trip is the safe universal fallback).
    """
    def move(leaf, spec):
        sharding = NamedSharding(new_mesh, spec)
        return jax.device_put(np.asarray(leaf), sharding)

    return jax.tree.map(move, tree, specs)


def shrink_mesh(mesh: Mesh, failed_devices: set[int],
                axis: str) -> Mesh | None:
    """Drop the slices of `axis` containing failed devices; returns the
    surviving mesh or None if nothing survives."""
    devs = mesh.devices
    axis_idx = mesh.axis_names.index(axis)
    keep = []
    for i in range(devs.shape[axis_idx]):
        sl = np.take(devs, i, axis=axis_idx)
        if not any(d.id in failed_devices for d in sl.flatten()):
            keep.append(i)
    if not keep:
        return None
    new_devs = np.take(devs, keep, axis=axis_idx)
    return Mesh(new_devs, mesh.axis_names)


def choose_elastic_config(cfg, mesh_shape, *, global_batch: int,
                          seq_len: int,
                          schedules: Sequence[str] | None = None,
                          max_virtual_stages: int | None = None):
    """Re-plan the launch config for a *fixed* surviving mesh shape.

    Unlike `plan_frontier` (which walks stage × tp × dp factorizations
    of a device count), elastic re-planning cannot move devices between
    axes — the surviving mesh's (stage, data, model) shape is a fact.
    What is still free are the schedule knobs: microbatch count,
    schedule, virtual stages.  This enumerates those on the fixed shape,
    prices each with the mkplan cost models, and returns the frontier
    candidate with the best step-time model — so the post-shrink config
    is the planner's choice, not "the old knobs on fewer devices".

    Gated by ``MK-R002`` first: a shrink no re-plan can repair (a
    (virtual) stage would hold zero repeats) raises `DiagnosticError`
    naming the surviving options rather than failing inside the
    planner.  Returns a `repro.analysis.planner.LaunchCandidate`.
    """
    from repro.analysis.costmodel import SCHEDULES
    from repro.analysis.diagnostics import DiagnosticError
    from repro.analysis.elastic import check_shrink
    from repro.analysis.planner import LaunchCandidate, frontier, score

    sizes = dict(mesh_shape)
    stages = int(sizes.get("stage", 1))
    dp = int(sizes.get("data", 1))
    tp = int(sizes.get("model", 1))
    loc = f"elastic-shrink stage={stages} data={dp} model={tp}"

    diags = check_shrink(cfg.n_repeats, stages, loc=loc)
    if any(d.is_error for d in diags):
        raise DiagnosticError([d for d in diags if d.is_error],
                              prefix="cannot re-plan onto the "
                                     "surviving mesh:")

    if stages <= 1:
        return LaunchCandidate(stages=max(stages, 1), microbatch=1,
                               schedule="gpipe", tp=tp, dp=dp)

    local_batch = max(global_batch // max(dp, 1), 1)
    micros = [m for m in range(1, local_batch + 1) if local_batch % m == 0]
    if schedules is None:
        schedules = SCHEDULES
    cands: list[LaunchCandidate] = []
    for m in micros:
        for sched in schedules:
            if sched != "interleaved":
                cands.append(LaunchCandidate(
                    stages=stages, microbatch=m, schedule=sched,
                    tp=tp, dp=dp))
                continue
            v_hi = cfg.n_repeats // stages
            if max_virtual_stages is not None:
                v_hi = min(v_hi, max_virtual_stages)
            for v in range(2, v_hi + 1):
                if not check_shrink(cfg.n_repeats, stages,
                                    virtual_stages=v, loc=loc):
                    cands.append(LaunchCandidate(
                        stages=stages, microbatch=m,
                        schedule="interleaved", virtual_stages=v,
                        tp=tp, dp=dp))
    scored = frontier([score(cfg, c, global_batch=global_batch,
                             seq_len=seq_len) for c in cands])
    best = min((s for s in scored if s.on_frontier),
               key=lambda s: s.score.step_time_s)
    log.info("elastic re-plan on mesh %s: chose %s "
             "(step-time model %.3gs, %d candidates, %d on frontier)",
             sizes, best.candidate.label(), best.score.step_time_s,
             len(scored), sum(s.on_frontier for s in scored))
    return best.candidate


@dataclasses.dataclass
class ElasticBindings:
    """What `TrainDriver` needs to rebuild after a shrink.

    `rebuild(mesh, candidate)` must return ``(step_fn, shardings)`` for
    the given mesh: a jitted ``(state, batch) -> (state, metrics)`` and
    a `NamedSharding` tree matching the train state (the restore /
    reshard target).  `replan` picks the candidate; callers can
    override `schedules`/`max_virtual_stages` to constrain it.
    """
    cfg: Any
    global_batch: int
    seq_len: int
    rebuild: Callable[[Mesh, Any], tuple[Callable, Any]]
    stage_axis: str = "stage"
    schedules: Sequence[str] | None = None
    max_virtual_stages: int | None = None

    def replan(self, mesh: Mesh):
        return choose_elastic_config(
            self.cfg, dict(mesh.shape), global_batch=self.global_batch,
            seq_len=self.seq_len, schedules=self.schedules,
            max_virtual_stages=self.max_virtual_stages)


__all__ = ["ElasticBindings", "choose_elastic_config", "reshard_tree",
           "shrink_mesh"]
