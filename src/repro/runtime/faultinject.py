"""Deterministic fault injection for the fault-tolerance stack.

Real failures are non-deterministic; tests need the opposite.  A
`FaultInjector` holds a list of `FaultSpec`s, each pinned to a *data
step* — the `TrainDriver` pokes the injector at the top of every step,
and a spec fires exactly once at its step (retries and
restore-rewinds re-visit the step without re-firing, so one injected
failure means one failure).  Three fault kinds:

- ``"device_loss"``   — raise `DeviceLossError` carrying the device ids
  of stage k's mesh slice, the signal the driver's elastic path
  consumes (`shrink_mesh` drops exactly those slices);
- ``"step_error"``    — raise a plain RuntimeError (a transient step
  failure: exercises the emergency-checkpoint + restore-retry path,
  not the elastic one);
- ``"corrupt_shard"`` — flip bytes in one shard file of the newest
  checkpoint (does not raise; the *next restore* must reject it).

`corrupt_shard` / `truncate_manifest` are also usable directly from
tests that want to damage a checkpoint without a driver in the loop.

On CPU meshes built with ``--xla_force_host_platform_device_count`` the
"killed" devices keep existing — the injector simulates the loss signal
and the driver honors it, which is exactly what the end-to-end elastic
test needs: kill stage k at step N, shrink the stage axis, re-plan,
reshard from the v2 checkpoint, resume, and compare trajectories.
"""
from __future__ import annotations

import dataclasses
import logging
import pathlib
import random
from typing import Sequence

log = logging.getLogger("repro.faultinject")

FAULT_KINDS = ("device_loss", "step_error", "corrupt_shard")


class DeviceLossError(RuntimeError):
    """A (simulated or detected) loss of specific devices."""

    def __init__(self, failed_devices, msg: str | None = None):
        self.failed_devices = set(int(d) for d in failed_devices)
        super().__init__(msg or f"lost devices {sorted(self.failed_devices)}")


def is_device_loss(exc: BaseException) -> bool:
    """Does `exc` look like a device loss?  `DeviceLossError` always;
    runtime errors from the backend match on the phrases real device
    failures produce (a heuristic — injected faults are the reliable
    path, this catches the detected ones)."""
    if isinstance(exc, DeviceLossError):
        return True
    text = str(exc).lower()
    return isinstance(exc, RuntimeError) and any(
        phrase in text for phrase in
        ("device failed", "data_loss", "device unavailable",
         "failed to enqueue"))


def stage_devices(mesh, stage: int, axis: str = "stage") -> set[int]:
    """Device ids of `mesh`'s stage-`stage` slice (the set a
    ``"device_loss"`` fault reports as failed)."""
    import numpy as np
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh {dict(mesh.shape)} has no {axis!r} axis")
    axis_idx = mesh.axis_names.index(axis)
    if not 0 <= stage < mesh.devices.shape[axis_idx]:
        raise ValueError(f"stage {stage} out of range for {axis!r} size "
                         f"{mesh.devices.shape[axis_idx]}")
    sl = np.take(mesh.devices, stage, axis=axis_idx)
    return {d.id for d in sl.flatten()}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: fire `kind` at data step `step`."""
    step: int
    kind: str = "device_loss"
    stage: int = 0                 # device_loss: which stage slice dies
    key: str | None = None         # corrupt_shard: leaf-key substring
    seed: int = 0                  # corrupt_shard: which bytes flip

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"want one of {FAULT_KINDS}")


class FaultInjector:
    """Drives `FaultSpec`s against a `TrainDriver` run.

    The driver calls `poke(step)` before executing each data step;
    faults whose `step` matches fire once (idempotent across the
    retries and rewinds the failure itself causes).  `mesh` is needed
    for ``device_loss`` (to name the dead slice), `ckpt_dir` for
    ``corrupt_shard``.
    """

    def __init__(self, faults: Sequence[FaultSpec], mesh=None,
                 ckpt_dir: str | pathlib.Path | None = None,
                 axis: str = "stage"):
        self.faults = list(faults)
        self.mesh = mesh
        self.ckpt_dir = ckpt_dir
        self.axis = axis
        self._fired: set[int] = set()

    def poke(self, step: int) -> None:
        for i, f in enumerate(self.faults):
            if i in self._fired or f.step != step:
                continue
            self._fired.add(i)
            log.warning("injecting %s at step %d", f.kind, step)
            if f.kind == "device_loss":
                if self.mesh is None:
                    raise ValueError("device_loss fault needs the "
                                     "injector constructed with mesh=")
                raise DeviceLossError(
                    stage_devices(self.mesh, f.stage, self.axis),
                    f"injected loss of stage {f.stage} at step {step}")
            if f.kind == "step_error":
                raise RuntimeError(
                    f"injected transient step failure at step {step}")
            # corrupt_shard: damage the newest checkpoint, don't raise
            if self.ckpt_dir is None:
                raise ValueError("corrupt_shard fault needs the "
                                 "injector constructed with ckpt_dir=")
            corrupt_shard(self.ckpt_dir, key=f.key, seed=f.seed)


# --------------------------------------------------- checkpoint damage
def _latest_dir(ckpt_dir: str | pathlib.Path,
                step: int | None) -> pathlib.Path:
    from repro.ckpt import checkpoint_path, latest_step
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    return checkpoint_path(ckpt_dir, step)


def corrupt_shard(ckpt_dir: str | pathlib.Path, step: int | None = None,
                  key: str | None = None, seed: int = 0) -> pathlib.Path:
    """Flip bytes in one shard file of a (v2) checkpoint — deterministic
    in `seed`.  `key` narrows to shards of a leaf whose manifest key
    contains it; default is the first shard file.  Returns the damaged
    path.  The next restore must reject the checkpoint (MK-R001)."""
    import json
    path = _latest_dir(ckpt_dir, step)
    manifest = json.loads((path / "manifest.json").read_text())
    files = [sh["file"] for rec in manifest.get("leaves", [])
             if key is None or key in rec["key"]
             for sh in rec["shards"]]
    if not files:
        raise FileNotFoundError(
            f"no shard files matching key={key!r} in {path}")
    target = path / files[0]
    raw = bytearray(target.read_bytes())
    rng = random.Random(seed)
    # flip a handful of bytes in the payload (past the .npy header)
    for _ in range(8):
        pos = rng.randrange(min(128, len(raw) - 1), len(raw))
        raw[pos] ^= 0xFF
    target.write_bytes(bytes(raw))
    return target


def truncate_manifest(ckpt_dir: str | pathlib.Path,
                      step: int | None = None,
                      keep_bytes: int = 64) -> pathlib.Path:
    """Truncate a checkpoint's manifest.json to `keep_bytes` — the next
    restore must reject it as unreadable/truncated."""
    path = _latest_dir(ckpt_dir, step) / "manifest.json"
    path.write_bytes(path.read_bytes()[:keep_bytes])
    return path


__all__ = ["DeviceLossError", "FAULT_KINDS", "FaultInjector", "FaultSpec",
           "corrupt_shard", "is_device_loss", "stage_devices",
           "truncate_manifest"]
