"""Fault-tolerant training driver.

Production behaviours, all exercised by tests on CPU:
  - periodic async checkpoints + emergency sync checkpoint on any failure;
  - automatic resume from the latest manifest (bit-reproducible data replay);
  - bounded retry-with-restore on transient step failures;
  - straggler detection from a step-time EWMA (on real pods the hook
    triggers re-compilation without the slow host / re-balancing; here it
    records and reports).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax

from repro.ckpt import CheckpointManager

log = logging.getLogger("repro.ft")


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    keep: int = 3
    max_restores: int = 3
    straggler_factor: float = 3.0     # step > factor × EWMA ⇒ straggler
    ewma_alpha: float = 0.2


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.ewma: float | None = None
        self.events: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.factor * self.ewma
        if is_straggler:
            self.events.append((step, dt, self.ewma))
        # stragglers don't poison the baseline estimate
        if not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class TrainDriver:
    """Runs (state, batch) -> (state, metrics) with checkpoint/restart."""

    def __init__(self, step_fn: Callable, dataset: Any, cfg: FTConfig,
                 state: Any, start_step: int = 0,
                 on_straggler: Callable[[int], None] | None = None):
        self.step_fn = step_fn
        self.dataset = dataset
        self.cfg = cfg
        self.manager = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.monitor = StragglerMonitor(cfg.straggler_factor, cfg.ewma_alpha)
        self.state = state
        self.step = start_step
        self.on_straggler = on_straggler
        self.metrics_log: list[dict] = []

    @classmethod
    def resume_or_init(cls, step_fn, dataset, cfg: FTConfig, init_state,
                       shardings=None, **kw) -> "TrainDriver":
        mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        restored = mgr.restore_latest(init_state, shardings)
        if restored is not None:
            step, state = restored
            log.info("resumed from step %d", step)
            return cls(step_fn, dataset, cfg, state, start_step=step, **kw)
        return cls(step_fn, dataset, cfg, init_state, start_step=0, **kw)

    def run(self, num_steps: int) -> Any:
        restores = 0
        target = self.step + num_steps
        while self.step < target:
            batch = self.dataset.batch_at(self.step)
            t0 = time.perf_counter()
            try:
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics)
            except Exception:
                # emergency checkpoint of the last good state, then either
                # restore-and-retry or re-raise once the budget is spent
                self.manager.save(self.step, self.state,
                                  extra={"emergency": True}, blocking=True)
                restores += 1
                if restores > self.cfg.max_restores:
                    raise
                restored = self.manager.restore_latest(self.state)
                if restored is not None:
                    self.step, self.state = restored
                log.warning("step %d failed; restored (attempt %d)",
                            self.step, restores)
                continue
            dt = time.perf_counter() - t0
            if self.monitor.observe(self.step, dt) and self.on_straggler:
                self.on_straggler(self.step)
            self.metrics_log.append(
                {"step": self.step,
                 **{k: float(v) for k, v in metrics.items()}, "dt": dt})
            self.step += 1
            if self.step % self.cfg.ckpt_every == 0:
                self.manager.save(self.step, self.state,
                                  extra={"emergency": False})
        self.manager.save(self.step, self.state, blocking=True)
        return self.state
