"""Fault-tolerant training driver.

Production behaviours, all exercised by tests on CPU:
  - periodic async checkpoints + emergency sync checkpoint on any failure
    (emergency saves publish under a distinct ``step_<N>_emergency`` tag
    so they never clobber a periodic checkpoint at the same step);
  - automatic resume from the latest manifest (bit-reproducible data
    replay), restoring *with the driver's shardings* so resumed state
    lands sharded, not replicated;
  - bounded retry-with-restore on transient step failures;
  - elastic shrink on device loss: drop the dead stage slices
    (`shrink_mesh`), re-plan the schedule knobs on the surviving mesh
    through the mkplan cost models (`ElasticBindings.replan`, gated by
    MK-R002), rebuild + re-jit the step function, reshard state from the
    latest sharded checkpoint (mesh-agnostic v2 restore) or in memory,
    and resume — the data step replays deterministically;
  - straggler detection from a step-time EWMA (on real pods the hook
    triggers re-compilation without the slow host / re-balancing; here it
    records and reports).

Failures are injectable deterministically (`repro.runtime.faultinject`):
pass a `FaultInjector` and the driver pokes it at the top of every data
step, so tests pin "stage 1 dies at step 7" exactly.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable

import jax

from repro.ckpt import CheckpointManager
from repro.runtime.elastic import ElasticBindings, shrink_mesh
from repro.runtime.faultinject import FaultInjector, is_device_loss

log = logging.getLogger("repro.ft")


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    keep: int = 3
    max_restores: int = 3
    straggler_factor: float = 3.0     # step > factor × EWMA ⇒ straggler
    ewma_alpha: float = 0.2
    elastic: bool = False             # shrink + re-plan on device loss


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self.ewma: float | None = None
        self.events: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = dt > self.factor * self.ewma
        if is_straggler:
            self.events.append((step, dt, self.ewma))
        # stragglers don't poison the baseline estimate
        if not is_straggler:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class TrainDriver:
    """Runs (state, batch) -> (state, metrics) with checkpoint/restart.

    `shardings` (a `NamedSharding` tree matching `state`) makes every
    restore land sharded instead of replicated — the retry path and
    `resume_or_init` both thread it through.  `mesh` + `elastic`
    (an `ElasticBindings`) arm the device-loss path; `fault_injector`
    injects deterministic failures for tests.
    """

    def __init__(self, step_fn: Callable, dataset: Any, cfg: FTConfig,
                 state: Any, start_step: int = 0,
                 on_straggler: Callable[[int], None] | None = None,
                 shardings: Any = None, mesh: Any = None,
                 elastic: ElasticBindings | None = None,
                 fault_injector: FaultInjector | None = None):
        self.step_fn = step_fn
        self.dataset = dataset
        self.cfg = cfg
        self.manager = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        self.monitor = StragglerMonitor(cfg.straggler_factor, cfg.ewma_alpha)
        self.state = state
        self.step = start_step
        self.on_straggler = on_straggler
        self.shardings = shardings
        self.mesh = mesh
        self.elastic = elastic
        self.fault_injector = fault_injector
        self.metrics_log: list[dict] = []
        self.events: list[dict] = []       # shrink / restore history

    @classmethod
    def resume_or_init(cls, step_fn, dataset, cfg: FTConfig, init_state,
                       shardings=None, **kw) -> "TrainDriver":
        mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
        restored = mgr.restore_latest(init_state, shardings)
        if restored is not None:
            step, state = restored
            log.info("resumed from step %d", step)
            return cls(step_fn, dataset, cfg, state, start_step=step,
                       shardings=shardings, **kw)
        return cls(step_fn, dataset, cfg, init_state, start_step=0,
                   shardings=shardings, **kw)

    # ------------------------------------------------------------ failure
    def _rewind(self, step: int, state: Any) -> None:
        """Adopt a restored (step, state); metrics logged at or past the
        restored step are about to be recomputed — drop them so the log
        stays one row per data step."""
        self.state = state
        if step < self.step:
            self.metrics_log = [m for m in self.metrics_log
                                if m["step"] < step]
        self.step = step

    def _handle_device_loss(self, exc: BaseException) -> None:
        """Shrink the stage axis, re-plan, reshard, resume (or re-raise
        when nothing survives / no bindings can rebuild)."""
        if self.elastic is None or self.mesh is None:
            raise exc
        failed = getattr(exc, "failed_devices", set())
        fail_step = self.step
        new_mesh = shrink_mesh(self.mesh, set(failed),
                               self.elastic.stage_axis)
        if new_mesh is None:
            log.error("device loss %s leaves no surviving %r slice",
                      sorted(failed), self.elastic.stage_axis)
            raise exc
        cand = self.elastic.replan(new_mesh)      # MK-R002 gate + mkplan
        step_fn, shardings = self.elastic.rebuild(new_mesh, cand)
        restored = self.manager.restore_latest(self.state, shardings)
        if restored is not None:
            from_step = restored[0]
            self._rewind(*restored)
        else:
            # no checkpoint yet: the survivors' shards still cover the
            # tree (CPU simulation; on real pods this branch is a loss
            # of the un-checkpointed steps) — reshard in memory
            from_step = self.step
            self.state = jax.tree.map(jax.device_put, self.state,
                                      shardings)
        self.mesh, self.step_fn, self.shardings = new_mesh, step_fn, \
            shardings
        self.events.append({
            "kind": "shrink", "at_step": fail_step,
            "resume_step": from_step, "lost": sorted(failed),
            "mesh": dict(new_mesh.shape), "config": cand.label()})
        log.warning("device loss at step %d: shrunk to %s, re-planned "
                    "to %s, resuming at step %d", fail_step,
                    dict(new_mesh.shape), cand.label(), from_step)

    # --------------------------------------------------------------- run
    def run(self, num_steps: int) -> Any:
        restores = 0
        target = self.step + num_steps
        while self.step < target:
            batch = self.dataset.batch_at(self.step)
            t0 = time.perf_counter()
            try:
                if self.fault_injector is not None:
                    self.fault_injector.poke(self.step)
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics)
            except Exception as exc:
                if self.elastic is not None and is_device_loss(exc):
                    # the lost devices' state is gone — restore from the
                    # checkpoint, don't checkpoint the wreckage
                    self._handle_device_loss(exc)
                    continue
                # emergency checkpoint of the last good state, then either
                # restore-and-retry or re-raise once the budget is spent.
                # The emergency tag publishes to step_<N>_emergency, so a
                # periodic checkpoint at the same step survives untouched.
                fail_step = self.step
                self.manager.save(fail_step, self.state,
                                  extra={"emergency": True},
                                  blocking=True, tag="emergency")
                restores += 1
                if restores > self.cfg.max_restores:
                    raise
                restored = self.manager.restore_latest(self.state,
                                                       self.shardings)
                if restored is not None:
                    self._rewind(*restored)
                self.events.append({"kind": "restore",
                                    "at_step": fail_step,
                                    "resume_step": self.step,
                                    "attempt": restores})
                log.warning("step %d failed; restored to step %d "
                            "(attempt %d)", fail_step, self.step,
                            restores)
                continue
            dt = time.perf_counter() - t0
            if self.monitor.observe(self.step, dt) and self.on_straggler:
                self.on_straggler(self.step)
            self.metrics_log.append(
                {"step": self.step,
                 **{k: float(v) for k, v in metrics.items()}, "dt": dt})
            self.step += 1
            if self.step % self.cfg.ckpt_every == 0:
                self.manager.save(self.step, self.state,
                                  extra={"emergency": False})
        self.manager.save(self.step, self.state, blocking=True)
        return self.state
