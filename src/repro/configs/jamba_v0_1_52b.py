"""jamba-v0.1-52b — hybrid Mamba+attention 7:1 interleave, MoE every 2nd
layer (16 experts top-2).  [arXiv:2403.19887]

Pattern of 8 layers repeated 4× = 32 layers; attention sits at pattern
position 4 (the paper's 1:7 ratio), MoE on odd positions.  The Mamba mixer
is the unified Mamba-2 SSD block (Jamba v0.1 used Mamba-1 with d_state=16;
we keep d_state=16 but the SSD formulation — documented in DESIGN.md)."""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

_PATTERN = tuple(
    LayerSpec(
        kind=LayerKind.ATTN if i == 4 else LayerKind.MAMBA,
        moe=(i % 2 == 1),
    )
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    pattern=_PATTERN,
    n_repeats=4,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    act="silu",
    norm="rmsnorm",
    num_experts=16,
    experts_per_tok=2,
    moe_d_ff=14336,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
)

SMOKE = ModelConfig(
    name="jamba-v0.1-52b-smoke",
    family="hybrid",
    pattern=tuple(
        LayerSpec(kind=LayerKind.ATTN if i == 1 else LayerKind.MAMBA,
                  moe=(i % 2 == 1)) for i in range(4)),
    # 4 repeats (matching the full config) keep the hybrid pattern
    # pipeline-able at smoke scale — including the heterogeneous
    # n_repeats % n_stages != 0 split at --stages 3 (repeats are
    # lax.scan'd, so this costs runtime, not compile time)
    n_repeats=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    act="silu",
    norm="rmsnorm",
    num_experts=4,
    experts_per_tok=2,
    moe_d_ff=128,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
)
