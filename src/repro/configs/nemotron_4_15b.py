"""nemotron-4-15b — dense, GQA kv8, squared-ReLU MLP, LayerNorm.
[arXiv:2402.16819]"""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    act="relu2",
    norm="layernorm",
    rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="nemotron-4-15b-smoke",
    family="dense",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    act="relu2",
    norm="layernorm",
)
