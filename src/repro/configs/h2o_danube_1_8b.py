"""h2o-danube-1.8b — dense, GQA kv8, sliding-window attention (mistral mix).
[arXiv:2401.16818]  Runs long_500k: SWA keeps the KV state bounded."""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    pattern=(LayerSpec(kind=LayerKind.SWA),),
    n_repeats=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    window=4096,
    act="silu",
    norm="rmsnorm",
)

SMOKE = ModelConfig(
    name="h2o-danube-1.8b-smoke",
    family="dense",
    pattern=(LayerSpec(kind=LayerKind.SWA),),
    n_repeats=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    window=32,
    act="silu",
    norm="rmsnorm",
)
