"""command-r-plus-104b — dense, GQA kv8, no-bias, parallel attn+FFN block.
[hf:CohereForAI/c4ai-command-r-v01-style]"""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=64,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=33792,
    vocab_size=256000,
    act="silu",
    norm="layernorm",
    parallel_block=True,
)

SMOKE = ModelConfig(
    name="command-r-plus-104b-smoke",
    family="dense",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    act="silu",
    norm="layernorm",
    parallel_block=True,
)
