"""Input-shape cells: the 4 assigned shapes × 10 archs and their specs.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input — weak-type-correct, shardable, no device allocation.  `skip_reason`
implements the documented cell skips (long_500k needs sub-quadratic
attention state; pure full-attention archs skip it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (ALL_SHAPES, LayerKind, ModelConfig,
                                 ShapeSpec)
from repro.models.transformer import init_cache

SHAPES: dict[str, ShapeSpec] = {s.name: s for s in ALL_SHAPES}


def _subquadratic(cfg: ModelConfig) -> bool:
    """True if decode state is bounded (SSM / SWA) or attention layers are
    few enough that a 500k KV cache fits (hybrid: jamba has 4 attn layers)."""
    kinds = {s.kind for s in cfg.pattern}
    if kinds == {LayerKind.MAMBA}:
        return True                                    # pure SSM
    if LayerKind.ATTN not in kinds:
        return True                                    # SWA only
    return cfg.family == "hybrid"                      # few full-attn layers


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    if shape.name == "long_500k" and not _subquadratic(cfg):
        return ("full-attention arch: 500k dense KV cache is the quadratic "
                "regime this shape excludes (DESIGN.md §Arch-applicability)")
    return None


def cells(cfg: ModelConfig) -> list[tuple[ShapeSpec, str | None]]:
    return [(s, skip_reason(cfg, s)) for s in ALL_SHAPES]


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.num_patches:
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.d_model), dt)
        if cfg.is_encdec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.enc_frames, cfg.d_model), dt)
        return specs

    # decode: one new token against a cache of seq_len
    specs = {
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "cache": init_cache(cfg, B, S, abstract=True),
    }
    return specs


def concrete_inputs(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0) -> dict:
    """Materialized small-scale inputs (smoke tests use reduced configs)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        out = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
        if shape.kind == "train":
            out["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        if cfg.num_patches:
            out["patch_embeds"] = jnp.asarray(
                rng.normal(size=(B, cfg.num_patches, cfg.d_model)), dt)
        if cfg.is_encdec:
            out["frames"] = jnp.asarray(
                rng.normal(size=(B, cfg.enc_frames, cfg.d_model)), dt)
        return out
    return {
        "token": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)),
                             jnp.int32),
        "cache": init_cache(cfg, B, S, abstract=False),
    }
