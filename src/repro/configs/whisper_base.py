"""whisper-base — encoder-decoder; the conv frontend is a STUB (input_specs
provide precomputed frame embeddings, 1500 frames).  Decoder self-attention
uses RoPE instead of learned positions (documented simplification).
[arXiv:2212.04356]"""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=6,                  # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,               # MHA
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    act="gelu",
    norm="layernorm",
    enc_layers=6,
    enc_frames=1500,
)

SMOKE = ModelConfig(
    name="whisper-base-smoke",
    family="audio",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    act="gelu",
    norm="layernorm",
    enc_layers=2,
    enc_frames=32,
)
