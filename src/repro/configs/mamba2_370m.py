"""mamba2-370m — pure SSM (SSD, state-space duality), attention-free,
no FFN blocks.  [arXiv:2405.21060]  d_inner = 2·1024 = 2048, head_dim 64
→ 32 SSD heads, d_state=128.  Runs long_500k (constant decode state)."""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    pattern=(LayerSpec(kind=LayerKind.MAMBA, ffn=False),),
    n_repeats=48,
    d_model=1024,
    num_heads=8,               # unused (attention-free); kept for config API
    num_kv_heads=8,
    head_dim=64,
    d_ff=0,
    vocab_size=50280,
    act="silu",
    norm="rmsnorm",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke",
    family="ssm",
    pattern=(LayerSpec(kind=LayerKind.MAMBA, ffn=False),),
    n_repeats=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=0,
    vocab_size=512,
    act="silu",
    norm="rmsnorm",
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    tie_embeddings=True,
)
