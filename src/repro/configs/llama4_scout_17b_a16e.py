"""llama4-scout-17b-a16e — MoE 16 experts top-1 + shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E]  40 q-heads → padded to 48 for TP=16."""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    pattern=(LayerSpec(kind=LayerKind.ATTN, moe=True),),
    n_repeats=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    act="silu",
    norm="rmsnorm",
    num_experts=16,
    experts_per_tok=1,
    moe_d_ff=8192,
    moe_shared_expert=True,
)

SMOKE = ModelConfig(
    name="llama4-scout-17b-a16e-smoke",
    family="moe",
    pattern=(LayerSpec(kind=LayerKind.ATTN, moe=True),),
    n_repeats=2,
    d_model=64,
    num_heads=5,               # deliberately odd: exercises head padding
    num_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    act="silu",
    norm="rmsnorm",
    num_experts=4,
    experts_per_tok=1,
    moe_d_ff=96,
    moe_shared_expert=True,
)
