"""Architecture registry: ``--arch <id>`` resolution."""
from .registry import ARCHS, get_config, get_smoke, list_archs
from .shapes import SHAPES, cells, input_specs, skip_reason

__all__ = ["ARCHS", "get_config", "get_smoke", "list_archs",
           "SHAPES", "cells", "input_specs", "skip_reason"]
