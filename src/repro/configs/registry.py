"""Maps ``--arch <id>`` to its config module."""
from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

ARCHS: dict[str, str] = {
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "whisper-base": "repro.configs.whisper_base",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "mamba2-370m": "repro.configs.mamba2_370m",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[arch])


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def list_archs() -> list[str]:
    return sorted(ARCHS)
