"""granite-3-8b — dense, GQA kv8, tied embeddings.
[hf:ibm-granite/granite-3.0-style]"""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="granite-3-8b-smoke",
    family="dense",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=515,            # deliberately odd: exercises vocab padding
    act="silu",
    norm="rmsnorm",
    tie_embeddings=True,
)
