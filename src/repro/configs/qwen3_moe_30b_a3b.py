"""qwen3-moe-30b-a3b — MoE 128 experts top-8, GQA kv4, qk-norm.
[hf:Qwen/Qwen3-30B-A3B]  head_dim=128 is explicit (d_model/heads ≠ 128)."""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    pattern=(LayerSpec(kind=LayerKind.ATTN, moe=True),),
    n_repeats=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qk_norm=True,
    act="silu",
    norm="rmsnorm",
    num_experts=128,
    experts_per_tok=8,
    moe_d_ff=768,
)

SMOKE = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke",
    family="moe",
    pattern=(LayerSpec(kind=LayerKind.ATTN, moe=True),),
    n_repeats=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=512,
    qk_norm=True,
    act="silu",
    norm="rmsnorm",
    num_experts=8,
    experts_per_tok=2,
    moe_d_ff=96,
)
