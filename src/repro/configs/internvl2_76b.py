"""internvl2-76b — VLM backbone (InternLM2-style LM); the InternViT vision
tower is a STUB: input_specs provide precomputed patch embeddings.
[arXiv:2404.16821]"""
from repro.models.common import LayerKind, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    act="silu",
    norm="rmsnorm",
    num_patches=256,
)

SMOKE = ModelConfig(
    name="internvl2-76b-smoke",
    family="vlm",
    pattern=(LayerSpec(kind=LayerKind.ATTN),),
    n_repeats=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=512,
    act="silu",
    norm="rmsnorm",
    num_patches=4,
)
