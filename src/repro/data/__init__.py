from .pipeline import DataConfig, SyntheticLM, TokenFileDataset, make_dataset

__all__ = ["DataConfig", "SyntheticLM", "TokenFileDataset", "make_dataset"]
