"""Deterministic, restartable data pipeline.

Two sources behind one interface:
  SyntheticLM      — seeded Zipfian token stream (benchmarks, smoke tests)
  TokenFileDataset — memory-mapped token file with per-host sharding

Determinism contract: `batch_at(step)` is a pure function of
(seed, step, host_id) — a restarted/elastically-rescaled job replays the
exact stream, which is what makes checkpoint-resume bit-reproducible and
lets straggler mitigation re-assign host shards safely.
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0
    path: str | None = None           # token file → TokenFileDataset

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticLM:
    """Zipf-distributed tokens with a next-token structure (shifted labels),
    so tiny models can actually fit it and losses go down."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipfian ranks → plausible LM token frequencies
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4096 + cfg.host_id)
        shape = (cfg.host_batch, cfg.seq_len + 1)
        toks = rng.choice(cfg.vocab_size, size=shape, p=self._probs)
        # inject copy structure: token[t+1] == token[t] 30% of the time
        rep = rng.uniform(size=shape) < 0.3
        for t in range(1, shape[1]):
            toks[:, t] = np.where(rep[:, t], toks[:, t - 1], toks[:, t])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenFileDataset:
    """Flat binary int32 token file, mmap'd; deterministic strided reads.

    Host h reads offsets `(step · GB + h·HB + i) · seq` modulo the file —
    disjoint across hosts, contiguous in step."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path is not None
        self.cfg = cfg
        self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self._n_seq = len(self._tokens) // (cfg.seq_len + 1)
        if self._n_seq == 0:
            raise ValueError("token file shorter than one sequence")

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        L = cfg.seq_len + 1
        base = step * cfg.global_batch + cfg.host_id * cfg.host_batch
        rows = [(base + i) % self._n_seq for i in range(cfg.host_batch)]
        toks = np.stack([self._tokens[r * L:(r + 1) * L] for r in rows])
        toks = np.clip(toks, 0, cfg.vocab_size - 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_dataset(cfg: DataConfig):
    return TokenFileDataset(cfg) if cfg.path else SyntheticLM(cfg)


def write_token_file(path: str | pathlib.Path, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(str(path))
