"""Training driver: `python -m repro.launch.train --arch <id> [...]`.

Runs real steps on whatever devices exist (CPU smoke scale through TPU
pods), with the full substrate engaged: sharded params, AdamW, remat,
microbatching, async checkpointing, restart, straggler monitoring.
"""
from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.data import DataConfig, make_dataset
from repro.dist.compression import init_stacked_errors
from repro.dist.context import (KERNEL_MODES, kernel_mode_flags,
                                sharding_context)
from repro.dist.sharding import (batch_spec, data_par_size,
                                 pipelined_param_specs, sanitize_specs,
                                 with_shardings)
from repro.launch.mesh import make_mesh, make_train_mesh
from repro.models.common import tp_align
from repro.models.transformer import init_params
from repro.runtime import FTConfig, TrainDriver
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.pipeline import plan_pipeline
from repro.train.step import make_train_step

log = logging.getLogger("repro.train")


def _plan(cfg, mesh, *, stages: int, microbatch: int, global_batch: int,
          seq_len: int, schedule: str, virtual_stages: int,
          flags: tuple = ()):
    """The pipeline-planning block of `build`, reusable per mesh: the
    elastic rebuild re-runs it on the shrunk mesh with the re-planned
    knobs.  Returns a `PipelinePlan` or None (no pipeline)."""
    if stages <= 1:
        return None
    if "grad_int8" in flags:
        raise ValueError("grad_int8 and pipeline stages are mutually "
                         "exclusive (run one A/B at a time)")
    if "stage" not in mesh.shape or mesh.shape["stage"] != stages:
        raise ValueError(f"mesh {dict(mesh.shape)} lacks a stage axis "
                         f"of size {stages}")
    # pipeline stages compose with both data and model parallelism:
    # the islands run over the full stage × data × model mesh, with
    # tensor-sharded blocks inside (see repro.models.pipeline)
    dp = data_par_size(mesh)
    tp = mesh.shape.get("model", 1)
    n_micro = microbatch or max(global_batch // max(dp, 1), 1)
    plan = plan_pipeline(cfg, stages, n_micro,
                         global_batch=global_batch, seq_len=seq_len,
                         dp=dp, tp=tp, schedule=schedule,
                         virtual_stages=virtual_stages)
    log.info(
            "pipeline plan: schedule=%s stages=%d virtual=%d micro=%d "
            "tp=%d partition=%s stage_times=%s stage_time=%.3gs "
            "padding_overhead=%.1f%% bubble=%.1f%% "
            "peak_act_model=%d×mb=%.3gMB block_costs=%s",
            plan.schedule, plan.n_stages, plan.virtual_stages,
            plan.n_micro, plan.tp, plan.partition,
            ["%.3g" % t for t in plan.stage_times_s],
            plan.stage_time_s, 100 * plan.padding_overhead,
            100 * plan.bubble,
            plan.peak_inflight, plan.peak_activation_bytes / 1e6,
            ["%.3g" % c for c in plan.block_costs_s])
    return plan


def _assemble_step(cfg, mesh, plan, *, lr: float, grad_accum: int,
                   remat: bool, flags: tuple):
    """Build + jit the train step for one concrete mesh.

    Returns the driver-facing ``wrapped(state, batch)`` closure: batch
    leaves are device_put with the mesh's batch specs, the jitted step
    runs under the mesh + sharding context.  `build` calls this once;
    the elastic rebuild calls it again on the shrunk mesh."""
    from jax.sharding import NamedSharding

    opt = AdamWConfig(lr=lr)
    step_fn = make_train_step(cfg, opt, grad_accum=grad_accum,
                              remat=remat, pipeline=plan)
    with mesh, sharding_context(mesh, flags=flags):
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    def wrapped(state, batch):
        params, opt_state = state
        b = dict(batch)
        B = b["tokens"].shape[0]
        if cfg.num_patches:
            b["patch_embeds"] = np.zeros(
                (B, cfg.num_patches, cfg.d_model), np.float32)
        if cfg.is_encdec:
            b["frames"] = np.zeros(
                (B, cfg.enc_frames, cfg.d_model), np.float32)
        with mesh, sharding_context(mesh, flags=flags):
            b = {k: jax.device_put(
                    np.asarray(v),
                    NamedSharding(mesh, batch_spec(mesh, B,
                                                   np.asarray(v).ndim)))
                 for k, v in b.items()}
            if cfg.num_patches:
                b["patch_embeds"] = b["patch_embeds"].astype(cfg.dtype)
            if cfg.is_encdec:
                b["frames"] = b["frames"].astype(cfg.dtype)
            params, opt_state, metrics = jitted(params, opt_state, b)
        return (params, opt_state), metrics

    return wrapped


def state_shardings(state, mesh, pipelined: bool = False):
    """`NamedSharding` tree matching a ``(params, opt_state)`` train
    state on `mesh` — the restore/reshard target the driver threads
    through `resume_or_init`, the retry path, and the elastic rebuild.

    Specs come from `pipelined_param_specs` + `train_state_specs`
    (moments mirror the params, scalars replicate), sanitized against
    the concrete mesh so a non-dividing stage axis degrades to
    replicated instead of failing."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.train.step import train_state_specs

    pspecs = pipelined_param_specs(state[0], pipelined=pipelined)
    specs = sanitize_specs(state, train_state_specs(pspecs, state[1]),
                           mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda l: isinstance(l, P))


def build(arch: str, *, smoke: bool = False, global_batch: int = 8,
          seq_len: int = 128, mesh_shape=None, axes=("data", "model"),
          lr: float = 3e-4, grad_accum: int = 1, remat: bool = True,
          seed: int = 0, stages: int = 1, microbatch: int = 0,
          model_par: int = 1, schedule: str = "gpipe",
          virtual_stages: int = 1, flags: tuple = ()):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    if mesh_shape is not None:
        mesh = make_mesh(tuple(mesh_shape), tuple(axes))
    else:
        mesh = make_train_mesh(n_stages=stages, model_par=model_par)
    tp = mesh.shape.get("model", 1)
    if tp > 1:
        cfg = tp_align(cfg, tp)

    plan = _plan(cfg, mesh, stages=stages, microbatch=microbatch,
                 global_batch=global_batch, seq_len=seq_len,
                 schedule=schedule, virtual_stages=virtual_stages,
                 flags=flags)

    params = init_params(cfg, jax.random.key(seed))
    # stage-partition the layer stack: device s holds its repeats only.
    # When n_repeats doesn't divide n_stages the canonical (R, ...)
    # leading dim can't shard evenly, so sanitization drops the stage
    # entry and storage replicates; the in-step padded (S, K, ...)
    # view still computes stage-local (see models.pipeline.stage_stack)
    pspecs = pipelined_param_specs(params, pipelined=plan is not None)
    params = with_shardings(params, pspecs, mesh)
    opt_state = adamw_init(params)
    if "grad_int8" in flags:
        dp = data_par_size(mesh)
        # build the residuals pre-sharded: out_shardings makes each device
        # materialize only its (1, ...) slice instead of dp full copies
        err_specs = jax.tree.map(
            lambda l: batch_spec(mesh, dp, l.ndim + 1), params)
        from jax.sharding import NamedSharding
        err_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), err_specs)
        opt_state["err"] = jax.jit(
            lambda p: init_stacked_errors(p, dp),
            out_shardings=err_sh)(params)

    wrapped = _assemble_step(cfg, mesh, plan, lr=lr,
                             grad_accum=grad_accum, remat=remat,
                             flags=flags)
    data = make_dataset(DataConfig(
        seq_len=seq_len, global_batch=global_batch,
        vocab_size=cfg.vocab_size, seed=seed))

    return cfg, mesh, (params, opt_state), wrapped, data


def build_elastic(arch: str, *, global_batch: int = 8, seq_len: int = 128,
                  lr: float = 3e-4, grad_accum: int = 1,
                  remat: bool = True, flags: tuple = (), **kw):
    """`build`, plus everything the elastic driver needs to survive a
    stage loss.

    Returns ``(cfg, mesh, state, wrapped, data, bindings, shardings)``:
    the usual 5-tuple, an `ElasticBindings` whose ``rebuild(new_mesh,
    candidate)`` re-plans the pipeline, re-jits the step, and hands back
    the new step_fn + state shardings, and the `NamedSharding` tree for
    the *initial* mesh (so the driver restores sharded from step 0)."""
    from repro.runtime import ElasticBindings

    cfg, mesh, state, wrapped, data = build(
        arch, global_batch=global_batch, seq_len=seq_len, lr=lr,
        grad_accum=grad_accum, remat=remat, flags=flags, **kw)

    def rebuild(new_mesh, cand):
        plan = _plan(cfg, new_mesh, stages=cand.stages,
                     microbatch=cand.microbatch,
                     global_batch=global_batch, seq_len=seq_len,
                     schedule=cand.schedule,
                     virtual_stages=cand.virtual_stages, flags=flags)
        step_fn = _assemble_step(cfg, new_mesh, plan, lr=lr,
                                 grad_accum=grad_accum, remat=remat,
                                 flags=flags)
        return step_fn, state_shardings(state, new_mesh,
                                        pipelined=plan is not None)

    bindings = ElasticBindings(cfg=cfg, global_batch=global_batch,
                               seq_len=seq_len, rebuild=rebuild)
    pipelined = mesh.shape.get("stage", 1) > 1
    return (cfg, mesh, state, wrapped, data, bindings,
            state_shardings(state, mesh, pipelined=pipelined))


# single source of truth for axis names / rank defaults lives with the
# mesh-CLI rules (kept as aliases here for older call sites)
from repro.analysis.meshcli import (DEFAULT_AXES as _DEFAULT_AXES,
                                    KNOWN_AXES as _KNOWN_AXES)  # noqa: E402


def parse_mesh_cli(mesh_shape: str | None, axes: str | None,
                   stages: int, model_par: int = 1
                   ) -> tuple[tuple[int, ...] | None,
                              tuple[str, ...] | None]:
    """Validate `--mesh-shape`/`--axes` against `--stages`.

    Returns ``(shape, axes)`` for `build()` (both None when no explicit
    mesh was requested, letting `make_train_mesh` pick).  Shapes are
    comma-separated ints (``2,2,2``), axes comma-separated names from
    ``stage/pod/data/model``; with `--mesh-shape` but no `--axes` the
    rank picks the conventional names (3 → ``stage,data,model``).

    The checks live in `repro.analysis.meshcli` (rule family ``MK-M``);
    an invalid combination raises `DiagnosticError` — a ValueError whose
    message carries every finding with its rule ID and fix hint, before
    any device is touched.
    """
    from repro.analysis.diagnostics import DiagnosticError
    from repro.analysis.meshcli import resolve_mesh_cli

    shape, names, diags = resolve_mesh_cli(mesh_shape, axes, stages,
                                           model_par)
    errors = [d for d in diags if d.is_error]
    if errors:
        raise DiagnosticError(errors, prefix="invalid mesh CLI:")
    for d in diags:                    # warnings (e.g. ignored --model-par)
        log.warning("%s", d.format())
    return shape, names


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--stages", type=int, default=1,
                    help="pipeline stages over a 'stage' mesh axis — any "
                         "n_stages <= n_repeats (non-divisible counts run "
                         "padded per-stage stacks; needs >= stages "
                         "devices; on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_device_count)")
    ap.add_argument("--microbatch", type=int, default=0,
                    help="pipeline microbatches per step (default: "
                         "per-data-shard batch)")
    ap.add_argument("--model-par", type=int, default=1,
                    help="tensor (model) parallel degree; composes with "
                         "--stages over a (stage, data, model) mesh")
    ap.add_argument("--mesh-shape", default=None,
                    help="explicit mesh, comma-separated sizes (e.g. "
                         "2,2,2); overrides --model-par and the default "
                         "device fill — validated against --stages")
    ap.add_argument("--axes", default=None,
                    help="axis names for --mesh-shape (e.g. "
                         "stage,data,model); defaults by rank")
    ap.add_argument("--schedule", choices=["gpipe", "1f1b", "interleaved"],
                    default="gpipe",
                    help="pipeline backward ordering: gpipe (scan "
                         "transpose), 1f1b (explicit stash/pop step "
                         "program), or interleaved (virtual-stage 1f1b, "
                         "--virtual-stages chunks per device).  Same "
                         "forward numerics; the plan's peak_act_model "
                         "line shows the schedule's analytic stash bound "
                         "(M vs min(M, S) vs min(vM, vS+S-1+v)), which "
                         "loss-in-schedule executors realize — see "
                         "docs/pipeline-schedules.md")
    ap.add_argument("--virtual-stages", type=int, default=1,
                    help="chunks of the layer stack per device for "
                         "--schedule interleaved (v > 1 shrinks the "
                         "bubble toward (S-1)/(vM+S-1); needs "
                         "v*stages <= n_repeats)")
    ap.add_argument("--grad-int8", action="store_true",
                    help="int8 error-feedback gradient all-reduce "
                         "(repro.dist.compression.compressed_psum)")
    ap.add_argument("--kernels", choices=list(KERNEL_MODES), default="off",
                    help="hot-spot kernel execution: off = pure-jnp layer "
                         "math, ref = the kernels' jnp oracles (plumbing "
                         "check), pallas = the Pallas kernels (interpret "
                         "mode on CPU; see docs/kernels.md).  Composes "
                         "with --stages/--model-par: inside pipeline "
                         "islands the kernels run on tp-local shapes")
    ap.add_argument("--verify", action="store_true",
                    help="run the mklint static verifier (collectives, "
                         "step program, sharding specs, kernels) before "
                         "building anything; refuse to launch on errors. "
                         "Also runs the MK-T planner comparison — "
                         "warn-only, a dominated config still launches")
    ap.add_argument("--mem-budget-gb", type=float, default=None,
                    help="per-device memory budget for the --verify "
                         "planner's MK-T002 peak-bytes warning")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--elastic", action="store_true",
                    help="survive stage-device loss: shrink the stage "
                         "axis, re-plan the schedule knobs through the "
                         "mkplan cost models (MK-R002 gated), reshard "
                         "from the latest sharded checkpoint, resume at "
                         "the restored data step — see "
                         "docs/fault-tolerance.md")
    ap.add_argument("--inject-fail-step", type=int, default=None,
                    help="deterministic fault injection: kill one "
                         "stage's devices at this data step "
                         "(repro.runtime.faultinject; needs --elastic "
                         "to survive it)")
    ap.add_argument("--inject-fail-stage", type=int, default=0,
                    help="which stage slice --inject-fail-step kills")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    flags = ("grad_int8",) if args.grad_int8 else ()
    flags += kernel_mode_flags(args.kernels)
    mesh_shape, axes = parse_mesh_cli(args.mesh_shape, args.axes,
                                      args.stages, args.model_par)
    if args.verify:
        from repro.analysis import verify_launch
        report = verify_launch(
            args.arch, smoke=args.smoke, global_batch=args.global_batch,
            seq_len=args.seq_len, stages=args.stages,
            microbatch=args.microbatch, model_par=args.model_par,
            mesh_shape=args.mesh_shape, axes=args.axes,
            schedule=args.schedule, virtual_stages=args.virtual_stages,
            flags=flags)
        print(report.format())
        if not report.ok:
            raise SystemExit(
                f"mklint: refusing to launch: {len(report.errors)} "
                "error(s) — fix the diagnostics above or drop --verify")
        # MK-T planner pass: price this config against its own launch
        # space (analytic cost models, nothing compiles).  Warn-only by
        # design — the models are rankings, not measurements, so a
        # dominated config still launches.
        from repro.analysis.planner import LaunchCandidate, check_plan
        pcfg = (get_smoke(args.arch) if args.smoke
                else get_config(args.arch))
        sizes = dict(zip(axes or (), mesh_shape or ()))
        stages = sizes.get("stage", args.stages)
        tp = sizes.get("model", args.model_par)
        dp = sizes.get("data",
                       max(jax.device_count() // (stages * tp), 1))
        chosen = LaunchCandidate(
            stages=stages, microbatch=max(args.microbatch, 1),
            schedule=args.schedule,
            virtual_stages=max(args.virtual_stages, 1), tp=tp, dp=dp,
            kernels=args.kernels if args.kernels == "pallas" else "off")
        budget = (args.mem_budget_gb * 2**30
                  if args.mem_budget_gb is not None else None)
        plan_report = check_plan(
            pcfg, chosen, global_batch=args.global_batch,
            seq_len=args.seq_len, mem_budget_bytes=budget)
        if plan_report.diagnostics:
            print(plan_report.format())
    kw = {} if mesh_shape is None else {"mesh_shape": mesh_shape,
                                        "axes": axes}
    build_kw = dict(
        smoke=args.smoke, global_batch=args.global_batch,
        seq_len=args.seq_len, lr=args.lr, grad_accum=args.grad_accum,
        stages=args.stages, microbatch=args.microbatch,
        model_par=args.model_par, schedule=args.schedule,
        virtual_stages=args.virtual_stages, flags=flags, **kw)
    bindings = shardings = None
    if args.elastic:
        cfg, mesh, state, step_fn, data, bindings, shardings = \
            build_elastic(args.arch, **build_kw)
    else:
        cfg, mesh, state, step_fn, data = build(args.arch, **build_kw)
    log.info("arch=%s params=%.1fM mesh=%s elastic=%s", cfg.name,
             cfg.n_params() / 1e6, dict(mesh.shape), args.elastic)

    injector = None
    if args.inject_fail_step is not None:
        from repro.runtime import FaultInjector, FaultSpec
        injector = FaultInjector(
            [FaultSpec(step=args.inject_fail_step,
                       stage=args.inject_fail_stage)],
            mesh=mesh, ckpt_dir=args.ckpt_dir)

    driver = TrainDriver.resume_or_init(
        step_fn, data,
        FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                 elastic=args.elastic),
        state, shardings=shardings, mesh=mesh, elastic=bindings,
        fault_injector=injector)
    driver.run(args.steps)
    losses = [m["loss"] for m in driver.metrics_log]
    log.info("first loss %.4f → last loss %.4f over %d steps",
             losses[0], losses[-1], len(losses))
    for ev in driver.events:
        log.info("recovery event: %s", ev)


if __name__ == "__main__":
    main()
