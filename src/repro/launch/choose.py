#!/usr/bin/env python
"""choose: static launch-config selection from the cost-model frontier.

The mkplan CLI: enumerate every feasible ``stages × microbatch ×
schedule × virtual-stages × model-par`` launch for an arch on an
N-device mesh, score each candidate with the unified analytic models
(`repro.analysis.costmodel` — nothing compiles), print the Pareto
frontier over (step-time model, peak-bytes model, collective-bytes),
and recommend the fastest frontier point's `repro.launch.train` argv.

Examples::

  python -m repro.launch.choose --arch jamba-v0.1-52b --smoke \
      --devices 8 --global-batch 8 --seq-len 64
  python -m repro.launch.choose --arch granite-3-8b --smoke --devices 8 \
      --global-batch 8 --seq-len 64 --mem-budget-gb 16 --json

``--measured`` swaps the analytic block costs for the XLA cost-analysis
probe (`costmodel.estimate_block_costs` — compiles one block per
pattern position, still no full-program lowering); the default analytic
path needs no jax at all.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def _parse_args(argv):
    ap = argparse.ArgumentParser(
        description="pick a launch config from the static cost-model "
                    "frontier (mkplan)")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, required=True,
                    help="mesh size to factor into stage x data x model")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--mem-budget-gb", type=float, default=None,
                    help="flag frontier points whose peak-bytes model "
                         "exceeds this per-device budget (MK-T002)")
    ap.add_argument("--schedules", default=None,
                    help="comma list to restrict (default: all)")
    ap.add_argument("--max-microbatch", type=int, default=None)
    ap.add_argument("--kernels", default="off",
                    help="kernels mode the candidates launch with")
    ap.add_argument("--measured", action="store_true",
                    help="price blocks with the XLA cost-analysis probe "
                         "instead of the analytic roofline estimate")
    ap.add_argument("--top", type=int, default=0,
                    help="print only the first N rows (0 = all)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable output (stable schema)")
    return ap.parse_args(argv)


def _row(sc) -> dict:
    return {
        "config": dataclass_dict(sc.candidate),
        "label": sc.candidate.label(),
        "on_frontier": sc.on_frontier,
        "dominated_by": (sc.dominated_by.label()
                         if sc.dominated_by else None),
        "step_time_s": sc.score.step_time_s,
        "peak_bytes": sc.score.peak_bytes,
        "collective_bytes": sc.score.collective_bytes,
        "bubble": sc.bubble,
        "collective_by_axis": sc.collective_by_axis,
    }


def dataclass_dict(cand) -> dict:
    import dataclasses
    return dataclasses.asdict(cand)


def main(argv=None) -> int:
    args = _parse_args(argv)

    from repro.analysis.planner import plan_frontier
    from repro.configs import get_config, get_smoke

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)

    block_costs = None
    if args.measured:
        from repro.analysis.costmodel import estimate_block_costs
        mb = max(args.global_batch, 1)
        block_costs = estimate_block_costs(cfg, mb, args.seq_len, tp=1)

    enum_kwargs: dict = {"kernels_modes": (args.kernels,)}
    if args.schedules:
        enum_kwargs["schedules"] = tuple(
            s.strip() for s in args.schedules.split(",") if s.strip())
    if args.max_microbatch:
        enum_kwargs["max_microbatch"] = args.max_microbatch

    t0 = time.perf_counter()
    scored = plan_frontier(cfg, args.devices,
                           global_batch=args.global_batch,
                           seq_len=args.seq_len, block_costs=block_costs,
                           **enum_kwargs)
    wall = time.perf_counter() - t0

    budget = (args.mem_budget_gb * 2**30
              if args.mem_budget_gb is not None else None)
    front = [s for s in scored if s.on_frontier]
    best = front[0] if front else None    # sorted: frontier first, by time
    over = [s for s in front
            if budget is not None and s.score.peak_bytes > budget]

    if args.json:
        out = {
            "version": 1,
            "arch": args.arch,
            "smoke": args.smoke,
            "devices": args.devices,
            "global_batch": args.global_batch,
            "seq_len": args.seq_len,
            "measured": args.measured,
            "wall_s": round(wall, 4),
            "n_candidates": len(scored),
            "n_frontier": len(front),
            "rows": [_row(s) for s in scored],
            "recommended": None if best is None else {
                "label": best.candidate.label(),
                "argv": best.candidate.argv(
                    args.arch, global_batch=args.global_batch,
                    seq_len=args.seq_len, smoke=args.smoke),
            },
        }
        print(json.dumps(out, indent=1, sort_keys=True))
        return 0 if best is not None else 1

    rows = scored[:args.top] if args.top else scored
    print(f"mkplan: {args.arch} on {args.devices} devices, "
          f"global_batch={args.global_batch} seq_len={args.seq_len} "
          f"({'measured' if args.measured else 'analytic'} block costs, "
          f"{len(scored)} candidates, {wall * 1e3:.0f}ms)")
    print(f"{'':2} {'config':<52} {'time model':>11} {'peak':>9} "
          f"{'coll':>9} {'bubble':>7}")
    for s in rows:
        mark = "*" if s.on_frontier else " "
        print(f"{mark:2} {s.candidate.label():<52} "
              f"{s.score.step_time_s * 1e3:>9.3f}ms "
              f"{s.score.peak_bytes / 2**20:>6.1f}MiB "
              f"{s.score.collective_bytes / 2**20:>6.1f}MiB "
              f"{s.bubble:>7.3f}")
    if args.top and len(scored) > args.top:
        print(f"   ... {len(scored) - args.top} more "
              f"(* = Pareto frontier, {len(front)} points)")
    else:
        print(f"   (* = Pareto frontier, {len(front)} points)")
    for s in over:
        print(f"   MK-T002 warning: {s.candidate.label()} peak "
              f"{s.score.peak_bytes / 2**30:.2f} GiB exceeds the "
              f"{args.mem_budget_gb:.2f} GiB budget")
    if best is not None:
        print("recommended:")
        print("  " + " ".join(best.candidate.argv(
            args.arch, global_batch=args.global_batch,
            seq_len=args.seq_len, smoke=args.smoke)))
        return 0
    print("no feasible candidate (check devices/global-batch "
          "divisibility)")
    return 1


if __name__ == "__main__":
    sys.exit(main())
