"""Production meshes.

Functions, not module constants: importing this module never touches jax
device state.  The dry-run sets XLA_FLAGS before any jax import to get 512
placeholder host devices; real deployments get real TPU topologies.
"""
from __future__ import annotations

import inspect

import jax
from jax.sharding import Mesh

try:                                    # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                     # jax 0.4.x: Auto is the only mode
    AxisType = None

if AxisType is not None and \
        "axis_types" in inspect.signature(jax.make_mesh).parameters:
    def _axis_kwargs(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
else:
    def _axis_kwargs(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """(16, 16) = ("data","model") single pod (256 chips);
    (2, 16, 16) = ("pod","data","model") for 2 pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (dry-run does this automatically)")
    return jax.make_mesh(shape, axes, devices=devices[:n],
                         **_axis_kwargs(len(shape)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh over the first prod(shape) devices (tests, elastic)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n],
                         **_axis_kwargs(len(shape)))


def make_train_mesh(n_stages: int = 1, model_par: int = 1,
                    data_par: int | None = None) -> Mesh:
    """Training mesh with an optional pipeline ``"stage"`` axis.

    Axes, outermost first: ``("stage", "data", "model")``; the stage axis
    appears only when n_stages > 1 (so the default mesh is the familiar
    ``("data", "model")``).  Stage is outermost: stage-to-stage ppermutes
    are the pipeline's only cross-stage traffic, so they get the slowest
    links, while data/model collectives stay within a stage's slice.
    `data_par` defaults to filling the remaining devices.

    n_stages and model_par compose: ``(n_stages, data_par, model_par)``
    is the full PP×TP training mesh — pipeline islands run
    Megatron-sharded blocks on it (`repro.models.pipeline`), with the
    model axis innermost so tp collectives ride the fastest links.
    """
    if n_stages < 1 or model_par < 1:
        raise ValueError("need n_stages >= 1 and model_par >= 1")
    n_dev = len(jax.devices())
    if data_par is None:
        data_par = max(n_dev // (n_stages * model_par), 1)
    need = n_stages * model_par * data_par
    if n_dev < need:
        raise RuntimeError(
            f"need {need} devices for (stage={n_stages}, data={data_par}, "
            f"model={model_par}), have {n_dev} — set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            "before importing jax")
    shape: tuple[int, ...] = (data_par, model_par)
    axes: tuple[str, ...] = ("data", "model")
    if n_stages > 1:
        shape = (n_stages, *shape)
        axes = ("stage", *axes)
    return make_mesh(shape, axes)
