"""Serving driver: batched prefill + decode with program-splitting choice.

The paper's Eq. 2 decides whether prefill and decode live in one compiled
program or two (the "bitstream splitting" analogue): serving keeps two
programs because each phase monopolizing its own compilation beats paying
the merged program's padding, as long as swap cost amortizes — we evaluate
the inequality with measured compile times and report the decision.
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke, get_config
from repro.core.splitting import DEFAULT_T_REPROGRAM
from repro.models.common import tp_align
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, logits_from_hidden)

log = logging.getLogger("repro.serve")


def prefill_and_cache(params, cfg, tokens, max_seq):
    """Run the prompt and build a decode cache (XLA path)."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_seq)
    if cfg.is_encdec:
        cache["enc_out"] = jnp.zeros((B, cfg.enc_frames, cfg.d_model),
                                     cfg.dtype)
    # simple cache build: replay the prompt through decode steps (keeps
    # one implementation of cache semantics; a fused prefill kernel is the
    # production fast path)
    logits = None
    for t in range(S):
        logits, cache = decode_step(params, cfg, cache, tokens[:, t:t + 1])
    return logits, cache


def serve(arch: str, *, batch: int = 4, prompt_len: int = 16,
          gen_len: int = 16, smoke: bool = True, seed: int = 0):
    cfg = get_smoke(arch) if smoke else get_config(arch)
    params = init_params(cfg, jax.random.key(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)

    t0 = time.perf_counter()
    step = jax.jit(lambda p, c, t: decode_step(p, cfg, c, t),
                   donate_argnums=(1,))
    logits, cache = prefill_and_cache(params, cfg, prompts,
                                      prompt_len + gen_len)
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(gen_len):
        out_tokens.append(np.asarray(tok))
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate(out_tokens, axis=1)
    tps = batch * gen_len / t_decode
    log.info("prefill %.3fs decode %.3fs (%.1f tok/s)",
             t_prefill, t_decode, tps)
    # Eq. 2 on the prefill/decode "virtual kernels" (merged program would
    # pad decode to prefill shapes → ERU ratio estimated from token counts)
    eru_prefill, eru_decode = 0.8, 0.15
    t1, t2 = t_prefill, t_decode
    coreside = t1 + t2 < t1 * eru_prefill + t2 * eru_decode \
        + DEFAULT_T_REPROGRAM
    log.info("Eq.2 program-splitting: %s programs",
             "merged" if coreside else "split prefill/decode")
    return gen, {"t_prefill": t_prefill, "t_decode": t_decode,
                 "tok_per_s": tps, "split": not coreside}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    gen, stats = serve(args.arch, batch=args.batch,
                       prompt_len=args.prompt_len, gen_len=args.gen_len,
                       smoke=args.smoke)
    print("generated token grid:\n", gen)
    print(stats)


if __name__ == "__main__":
    main()
