import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (the two lines above MUST run before any jax import — jax locks the device
#  count on first init; everything else, including repro imports, follows)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, per device: HLO FLOPs and bytes
(cost_analysis), memory footprint (memory_analysis), and collective traffic
(optimized-HLO parse incl. loop trip counts) — the three roofline terms.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all            # every cell, subprocess-isolated
"""
import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time

import jax

from repro.configs import (SHAPES, get_config, get_smoke, input_specs,
                           list_archs, skip_reason)
from repro.dist.compression import init_stacked_errors
from repro.dist.context import sharding_context
from repro.dist.sharding import (batch_spec, cache_specs, data_par_size,
                                 param_specs, sanitize_specs,
                                 shard_tree_specs, stage_stack_specs)
from repro.launch.hloanalysis import analyze_hlo, mesh_axis_groups
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models.common import tp_align
from repro.models.transformer import abstract_params
from repro.train.optimizer import adamw_init
from repro.train.pipeline import plan_pipeline
from repro.train.step import (make_prefill_step, make_serve_step,
                              make_train_step, zero1_specs)

# TPU v5e-like constants (per chip) — the assignment's hardware model,
# shared with the rest of the launch stack via the unified cost-model API.
from repro.analysis.costmodel import (HBM_BW, ICI_BW,  # noqa: E402,F401
                                      PEAK_FLOPS, roofline_terms)

RESULTS = pathlib.Path("results/dryrun")


def _named(specs_tree, mesh):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs_tree)


def _dryrun_mesh(mesh_kind: str, stages: int, model_par: int = 1,
                 data_par: int | None = None):
    """The analysis mesh for one cell.

    "pod"/"multipod": the production TP meshes.  "dp": a pure
    data-parallel (256, 1) mesh — the baseline for the grad_int8
    collective-bytes A/B (the int8 reduction island replicates params
    over the mapped axes, so it needs model_par == 1).  stages > 1: a
    (stages, data) ("stage", "data") pipeline mesh — with model_par > 1 a
    3D (stages, data, model_par) ("stage", "data", "model") pp×tp mesh.
    `data_par` defaults to 256/stages either way, so the pp×tp cell keeps
    the pp cell's per-device batch (its stage-axis ppermute bytes are
    directly comparable) and simply uses model_par× more devices.
    """
    if stages > 1:
        data = data_par or max(256 // stages, 1)
        if model_par > 1:
            return make_mesh((stages, data, model_par),
                             ("stage", "data", "model")), model_par
        return make_mesh((stages, data), ("stage", "data")), 1
    if mesh_kind == "dp":
        return make_mesh((256, 1), ("data", "model")), 1
    return make_production_mesh(multi_pod=(mesh_kind == "multipod")), 16


def lower_cell(arch: str, shape_name: str, mesh_kind: str = "pod",
               zero1: bool = False, grad_accum: int = 1,
               remat: bool = True, variants: tuple[str, ...] = (),
               stages: int = 1, n_micro: int = 0,
               schedule: str = "gpipe", virtual_stages: int = 1,
               model_par: int = 1,
               data_par: int | None = None, smoke: bool = False,
               shape_override=None):
    """Lower + compile one cell; returns the stats record.

    variants: optimization flags ("ar_bf16", "seq_shard",
    "decode_bf16_scores", "grad_int8", ...) consumed by the model layers
    and the train step through the sharding context — the §Perf hillclimb
    knobs.  stages > 1 lowers the pipelined train step over a
    ("stage", "data") mesh — with model_par > 1, over a 3D
    ("stage", "data", "model") pp×tp mesh — and reports the stage plan,
    predicted bubble, and per-axis collective bytes alongside the
    roofline terms.  smoke swaps in the reduced config (CI-scale
    compiles); shape_override substitutes a custom ShapeSpec (tests).
    """
    shape = shape_override or SHAPES[shape_name]
    mesh_name = (f"pp{stages}xtp{model_par}"
                 if stages > 1 and model_par > 1
                 else f"pp{stages}" if stages > 1 else mesh_kind)
    if stages > 1 and shape.kind != "train":
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": "pipeline cells are train-only"}
    if model_par > 1 and stages <= 1:
        raise ValueError("model_par applies to pipeline cells (stages > "
                         "1); pod/multipod cells fix their own tp")

    mesh, tp = _dryrun_mesh(mesh_kind, stages, model_par=model_par,
                            data_par=data_par)
    if "grad_int8" in variants and (tp != 1 or stages > 1):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": "the int8 reduction island replicates params "
                           "over its mapped axes, so grad_int8 wants "
                           "model_par == 1 and composes with data "
                           "parallelism only, not with pipeline cells "
                           "(use --mesh dp)"}
    base_cfg = get_smoke(arch) if smoke else get_config(arch)
    cfg = tp_align(base_cfg, tp=tp)
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "skipped": reason}

    n_dev = mesh.devices.size
    dp = data_par_size(mesh)

    plan = None
    if stages > 1:
        micro = n_micro or max(shape.global_batch // max(dp, 1), 1)
        try:
            plan = plan_pipeline(cfg, stages, micro,
                                 global_batch=shape.global_batch,
                                 seq_len=shape.seq_len, dp=dp, tp=tp,
                                 schedule=schedule,
                                 virtual_stages=virtual_stages)
        except ValueError as exc:
            return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "skipped": f"pipeline plan: {exc}"}

    params_abs = abstract_params(cfg)
    pspecs = param_specs(params_abs)
    if plan is not None:
        pspecs = dict(pspecs)
        pspecs["layers"] = [stage_stack_specs(s) for s in pspecs["layers"]]
    # clamp against the concrete mesh so out_shardings stay valid on
    # meshes without a model axis (pipeline / dp cells)
    pspecs = sanitize_specs(params_abs, pspecs, mesh)
    params_sds = shard_tree_specs(params_abs, pspecs, mesh)
    specs = input_specs(cfg, shape)

    t0 = time.perf_counter()
    with mesh, sharding_context(mesh, flags=tuple(variants)):
        if shape.kind == "train":
            opt_abs = jax.eval_shape(adamw_init, params_abs)
            ospecs = {"m": pspecs, "v": pspecs,
                      "count": jax.sharding.PartitionSpec()}
            if zero1:
                ospecs = {"m": zero1_specs(pspecs, params_abs, mesh),
                          "v": zero1_specs(pspecs, params_abs, mesh),
                          "count": jax.sharding.PartitionSpec()}
            if "grad_int8" in variants:
                err_abs = jax.eval_shape(
                    lambda t: init_stacked_errors(t, dp), params_abs)
                opt_abs["err"] = err_abs
                ospecs["err"] = jax.tree.map(
                    lambda l: batch_spec(mesh, dp, l.ndim), err_abs)
            opt_sds = shard_tree_specs(opt_abs, ospecs, mesh)
            bspecs = {
                k: batch_spec(mesh, v.shape[0], v.ndim)
                for k, v in specs.items()
            }
            batch_sds = shard_tree_specs(specs, bspecs, mesh)
            z1 = _named(ospecs["m"], mesh) if zero1 else None
            step = make_train_step(cfg, grad_accum=grad_accum, remat=remat,
                                   zero1_constraints=z1, pipeline=plan)
            lowered = jax.jit(
                step,
                out_shardings=(_named(pspecs, mesh), _named(ospecs, mesh),
                               None),
                donate_argnums=(0, 1),
            ).lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            bspecs = {k: batch_spec(mesh, v.shape[0], v.ndim)
                      for k, v in specs.items()}
            batch_sds = shard_tree_specs(specs, bspecs, mesh)
            step = make_prefill_step(cfg)
            lowered = jax.jit(step).lower(params_sds, batch_sds)
        else:  # decode
            cspecs = cache_specs(specs["cache"], mesh, shape.global_batch)
            cache_sds = shard_tree_specs(specs["cache"], cspecs, mesh)
            tok_sds = shard_tree_specs(
                {"t": specs["token"]},
                {"t": batch_spec(mesh, shape.global_batch, 2)}, mesh)["t"]
            step = make_serve_step(cfg)
            lowered = jax.jit(
                step, out_shardings=(None, _named(cspecs, mesh)),
                donate_argnums=(1,),
            ).lower(params_sds, cache_sds, tok_sds)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # jax<=0.4 returns [dict]
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    hlo = analyze_hlo(compiled.as_text(),
                      axis_groups=mesh_axis_groups(mesh))

    # loop-aware accounting (XLA cost_analysis counts while bodies once)
    flops_dev = hlo.flops
    bytes_dev = hlo.hbm_bytes
    coll_dev = hlo.collective_bytes

    # MODEL_FLOPS (whole-step, all devices): 6·N·D train / 2·N·D inference,
    # active params for MoE.
    n_active = cfg.n_params(active_only=True)
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind in ("train", "prefill")
              else shape.global_batch)
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "variants": sorted(variants) + (["zero1"] if zero1 else [])
        + ([f"ga{grad_accum}"] if grad_accum > 1 else [])
        + ([] if remat else ["noremat"]) + (["smoke"] if smoke else []),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "per_device": {
            "hlo_flops": flops_dev,
            "hlo_bytes": bytes_dev,
            "collective_bytes": coll_dev,
            "collective_breakdown": hlo.coll_bytes_by_op,
            "collective_counts": hlo.coll_count_by_op,
            # which collectives run on which mesh axis (replica-group
            # attribution): the pp×tp cells read stage-axis ppermute and
            # model-axis all-reduce traffic straight off this
            "collective_bytes_by_axis": hlo.coll_bytes_by_axis,
            "xla_cost_analysis_flops": float(ca.get("flops", 0.0)),
            "xla_cost_analysis_bytes": float(ca.get("bytes accessed", 0.0)),
        },
        "memory": None if ma is None else {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        },
        "terms_s": roofline_terms(flops_dev, bytes_dev,
                                  coll_dev).as_dict(),
        "model_flops_total": model_flops,
        "hlo_flops_total": flops_dev * n_dev,
        "useful_flops_ratio": (model_flops / (flops_dev * n_dev)
                               if flops_dev else 0.0),
        "params_total": cfg.n_params(),
        "params_active": n_active,
    }
    terms = rec["terms_s"]
    rec["bottleneck"] = max(terms, key=terms.get)
    if plan is not None:
        from repro.dist.pipeline import pipeline_peak_activation_bytes
        mb_bytes = (plan.peak_activation_bytes / plan.peak_inflight
                    if plan.peak_inflight else 0.0)
        stage_permute = hlo.coll_bytes_by_axis.get("stage", {}).get(
            "collective-permute")
        v_cmp = plan.virtual_stages if plan.virtual_stages > 1 else 2
        rec["pipeline"] = {
            "schedule": plan.schedule,
            "n_stages": plan.n_stages,
            "n_micro": plan.n_micro,
            "virtual_stages": plan.virtual_stages,
            "tp": plan.tp,
            "repeats_per_stage": plan.repeats_per_stage,
            "block_costs_s": list(plan.block_costs_s),
            "stage_time_s": plan.stage_time_s,
            # heterogeneous-partition record: which candidate won
            # (uniform / staggered / block), the per-position per-stage
            # valid repeats, per-stage fused-bottleneck times, and the
            # padded-FLOPs overhead column — the cost-weighted fraction
            # of scanned block work that is padding (masked out,
            # skipped by the stage scan's lax.cond):
            #   1 − R·Σc / (S · Σ_pos K_pos·c_pos)
            "partition": plan.partition,
            "sizes": [list(row) for row in plan.sizes],
            "stage_times_s": list(plan.stage_times_s),
            "padded_repeats": list(plan.padded_repeats),
            "padded_stage_time_s": plan.padded_stage_time_s,
            "padding_overhead": plan.padding_overhead,
            "padded_flops_fraction": (
                1.0 - (cfg.n_repeats * sum(plan.block_costs_s))
                / (plan.n_stages * plan.padded_stage_time_s)
                if plan.padded_stage_time_s > 0 else 0.0),
            "predicted_bubble": plan.bubble,
            "peak_inflight": plan.peak_inflight,
            "peak_activation_bytes": plan.peak_activation_bytes,
            # analytic *schedule model* (loss-in-schedule executors /
            # real hardware); the island train step lowered above keeps
            # the loss outside the schedule and stashes n_micro
            # microbatches per stage under either schedule — see
            # docs/pipeline-schedules.md
            "peak_activation_note": "analytic schedule model; the "
                                    "island train step stashes n_micro "
                                    "per stage under either schedule",
            # the schedules side by side: same plan, different stash
            # (interleaved priced at this cell's v, or v=2 for flat
            # cells, so every record shows the virtual-stage tradeoff)
            "peak_activation_bytes_by_schedule": {
                **{s: pipeline_peak_activation_bytes(
                    plan.n_micro, plan.n_stages, s, mb_bytes)
                   for s in ("gpipe", "1f1b")},
                f"interleaved(v={v_cmp})":
                    pipeline_peak_activation_bytes(
                        plan.n_micro, plan.n_stages, "interleaved",
                        mb_bytes, virtual_stages=v_cmp),
            },
            # the schedule's own traffic: stage-axis ppermute bytes (per
            # axis attribution; total collective-permute as the fallback
            # when replica groups were unclassifiable) — by construction
            # unchanged between a pp cell and its pp×tp sibling, since
            # the rotated activations are replicated over the model axis
            "ppermute_bytes": float(
                stage_permute if stage_permute is not None
                else hlo.coll_bytes_by_op.get("collective-permute", 0.0)),
        }
    return rec


def run_all(meshes: list[str], out_dir: pathlib.Path,
            parallel: int = 2, timeout: int = 3600) -> int:
    """Run every cell in isolated subprocesses; returns #failures."""
    out_dir.mkdir(parents=True, exist_ok=True)
    jobs = []
    for arch in list_archs():
        for shape_name in SHAPES:
            for mesh in meshes:
                tag = f"{arch}__{shape_name}__{mesh}"
                if (out_dir / f"{tag}.json").exists():
                    continue
                jobs.append((arch, shape_name, mesh, tag))
    procs: list[tuple[subprocess.Popen, str, float]] = []
    fails = 0

    def reap(block=False):
        nonlocal fails
        for p, tag, start in list(procs):
            if p.poll() is None and not block:
                continue
            if p.poll() is None and block and time.time() - start < timeout:
                continue
            if p.poll() is None:
                p.kill()
            p.wait()
            if p.returncode != 0:
                fails += 1
                print(f"[dryrun] FAIL {tag} rc={p.returncode}", flush=True)
            else:
                print(f"[dryrun] ok   {tag}", flush=True)
            procs.remove((p, tag, start))

    for arch, shape_name, mesh, tag in jobs:
        while len(procs) >= parallel:
            reap()
            time.sleep(2)
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape_name, "--mesh", mesh,
               "--out", str(out_dir)]
        log = open(out_dir / f"{tag}.log", "w")
        procs.append((subprocess.Popen(
            cmd, stdout=log, stderr=subprocess.STDOUT), tag, time.time()))
    while procs:
        reap(block=True)
        time.sleep(2)
    return fails


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both", "dp"],
                    help="dp = pure data-parallel (256, 1) mesh, the "
                         "baseline for the grad_int8 collective A/B")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--stages", type=int, default=1,
                    help="lower the pipelined train step over a "
                         "(stages, 256/stages) ('stage', 'data') mesh")
    ap.add_argument("--model-par", type=int, default=1,
                    help="tensor-parallel degree inside each pipeline "
                         "stage: with --stages > 1 the mesh becomes "
                         "(stages, 256/stages, model_par) ('stage', "
                         "'data', 'model') — the pp×tp cell, keeping the "
                         "pp cell's per-device batch")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CI-scale compile); record is "
                         "tagged with a 'smoke' variant")
    ap.add_argument("--data-par", type=int, default=None,
                    help="data-parallel degree for --stages > 1 cells "
                         "(default 256/stages); smaller values make "
                         "CI-scale pipeline compiles cheap")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--schedule",
                    choices=["gpipe", "1f1b", "interleaved"],
                    default="gpipe",
                    help="pipeline schedule for --stages > 1 cells; "
                         "reported peak-activation bytes cover all "
                         "schedules side by side")
    ap.add_argument("--virtual-stages", type=int, default=1,
                    help="chunks per device for --schedule interleaved "
                         "(the cell's plan and stash bound price v)")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--verify", action="store_true",
                    help="run the mklint static verifier on this cell "
                         "before lowering; refuse on errors")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--variant", action="append", default=[],
                    help="optimization flags (repeatable): ar_bf16, "
                         "seq_shard, decode_bf16_scores, kernels_ref, "
                         "kernels_pallas")
    ap.add_argument("--kernels", choices=["off", "ref", "pallas"],
                    default="off",
                    help="hot-spot kernel execution for the lowered cell "
                         "(shorthand for --variant kernels_<mode>; the "
                         "record is tagged with the variant)")
    ap.add_argument("--out", default=str(RESULTS))
    ap.add_argument("--parallel", type=int, default=2)
    args = ap.parse_args()

    if args.model_par > 1 and args.stages <= 1:
        ap.error("--model-par applies to pipeline cells: pass --stages "
                 "N > 1 (pod/multipod cells fix their own tp)")

    if args.kernels != "off":
        from repro.dist.context import kernel_mode_flags
        for f in kernel_mode_flags(args.kernels):
            if f not in args.variant:
                args.variant.append(f)

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
        fails = run_all(meshes, out_dir, parallel=args.parallel)
        sys.exit(1 if fails else 0)

    if args.verify and args.shape:
        # lint the cell with the same mesh convention _dryrun_mesh uses
        # ((stages, data[, model])) before any lowering work starts
        from repro.analysis import verify_launch
        shape = SHAPES[args.shape]
        report = verify_launch(
            args.arch, smoke=args.smoke,
            global_batch=shape.global_batch, seq_len=shape.seq_len,
            stages=args.stages, microbatch=args.microbatch,
            model_par=args.model_par,
            data_par=args.data_par or (max(256 // args.stages, 1)
                                       if args.stages > 1 else None),
            schedule=args.schedule,
            virtual_stages=args.virtual_stages,
            flags=tuple(args.variant))
        print(report.format())
        if not report.ok:
            sys.exit(f"mklint: refusing to lower: {len(report.errors)} "
                     "error(s) — fix the diagnostics above or drop "
                     "--verify")

    meshes = (["pod", "multipod"] if args.mesh == "both" else [args.mesh])
    if args.stages > 1:
        meshes = meshes[:1]          # _dryrun_mesh ignores mesh_kind then
    for mesh in meshes:
        rec = lower_cell(args.arch, args.shape, mesh_kind=mesh,
                         zero1=args.zero1, grad_accum=args.grad_accum,
                         remat=not args.no_remat,
                         variants=tuple(args.variant),
                         stages=args.stages, n_micro=args.microbatch,
                         schedule=args.schedule,
                         virtual_stages=args.virtual_stages,
                         model_par=args.model_par,
                         data_par=args.data_par, smoke=args.smoke)
        tag = f"{args.arch}__{args.shape}__{rec['mesh']}"
        suffix = ""
        for v in args.variant:
            suffix += f"__{v}"
        if args.zero1:
            suffix += "__zero1"
        if args.stages > 1 and args.microbatch:
            suffix += f"__m{args.microbatch}"
        if args.stages > 1 and args.schedule != "gpipe":
            suffix += f"__{args.schedule}"
        if args.stages > 1 and args.virtual_stages > 1:
            suffix += f"__v{args.virtual_stages}"
        if args.grad_accum > 1:
            suffix += f"__ga{args.grad_accum}"
        if args.no_remat:
            suffix += "__noremat"
        if args.smoke:
            suffix += "__smoke"
        path = out_dir / f"{tag}{suffix}.json"
        path.write_text(json.dumps(rec, indent=2))
        print(json.dumps(rec, indent=2))


if __name__ == "__main__":
    main()
