"""Loop-aware accounting over compiled (SPMD-partitioned) HLO text.

XLA's `compiled.cost_analysis()` counts every `while` body exactly once, so
for scan-heavy programs (layer scans, CE-chunk scans, attention triangle
scans) its FLOPs/bytes under-count by the trip counts.  This module
re-derives the three roofline inputs directly from the optimized HLO:

  flops             2·M·N·K over every dot/convolution, loop-aware
  hbm_bytes         Σ (operand + result bytes) of top-level instructions,
                    loop-aware — fusion bodies are *not* traversed (their
                    internals live in registers/VMEM), matching what
                    "bytes accessed" means on a real backend
  collective_bytes  Σ collective output bytes × ring multiplier (all-reduce
                    2×, others 1×), loop-aware

Trip counts come from XLA's own loop analysis: the `backend_config=
{"known_trip_count":{"n":K}}` attribute on each while op.  Shapes in
partitioned HLO are per-device, so all numbers are per-device.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[\d,]+\}(?:,\{[\d,]+\})*)\}")
_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(
    r"source_target_pairs=\{(\{\d+,\d+\}(?:,\{\d+,\d+\})*)\}")
# ops that don't touch HBM (metadata / aliasing / control)
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "after-all", "partition-id", "replica-id", "iota",
    "get-dimension-size", "opt-barrier", "domain",
}


def _shapes_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(sig: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(sig):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def mesh_axis_groups(mesh) -> dict[str, frozenset]:
    """Device-id groups per mesh axis (and per combination of axes), for
    attributing compiled collectives to the axis they run over.

    Returns ``{"stage": {{0,4},{1,5},...}, "model": ..., "data+model":
    ...}``: one entry per non-trivial axis (size > 1) and per combination
    of such axes (``"+"``-joined, e.g. a gradient all-reduce over both
    data axes matches ``"pod+data"``).  Groups are frozensets of device
    ids, matching the ``replica_groups`` of a collective partitioned over
    exactly those axes.
    """
    import itertools

    import numpy as np

    ids = np.vectorize(lambda d: d.id)(mesh.devices)
    names = [n for n in mesh.axis_names if mesh.shape[n] > 1]
    out: dict[str, frozenset] = {}
    for r in range(1, len(names) + 1):
        for combo in itertools.combinations(names, r):
            axes = tuple(mesh.axis_names.index(n) for n in combo)
            rest = [i for i in range(ids.ndim) if i not in axes]
            arr = ids.transpose(*rest, *axes).reshape(
                -1, int(np.prod([ids.shape[i] for i in axes])))
            out["+".join(combo)] = frozenset(
                frozenset(int(x) for x in row) for row in arr)
    return out


def _collective_axis(line: str, axis_groups: dict[str, frozenset]) -> str:
    """Name of the mesh axis (or ``"a+b"`` combination) a collective runs
    over, from its replica_groups / source_target_pairs; ``"other"`` when
    the groups match no axis (mixed groups, degenerate singletons)."""
    mp = _PAIRS_RE.search(line)
    if mp:
        pairs = [tuple(int(x) for x in g.split(","))
                 for g in re.findall(r"\{(\d+,\d+)\}", mp.group(1))]
        for name, ref in axis_groups.items():
            if "+" in name:
                continue             # permutes are single-axis rings here
            if all(any(s in g and t in g for g in ref) for s, t in pairs):
                return name
        return "other"
    m = _GROUPS_RE.search(line)
    if m:
        groups = [frozenset(int(x) for x in g.split(","))
                  for g in re.findall(r"\{([\d,]+)\}", m.group(1))]
    else:
        mi = _IOTA_RE.search(line)
        if not mi:
            return "other"
        import numpy as np
        ng, gs = int(mi.group(1)), int(mi.group(2))
        dims = [int(x) for x in mi.group(3).split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if mi.group(4):
            arr = arr.transpose([int(x) for x in mi.group(4).split(",")])
        groups = [frozenset(int(x) for x in row)
                  for row in arr.reshape(ng, gs)]
    gset = frozenset(groups)
    for name, ref in axis_groups.items():
        if gset == ref:
            return name
    return "other"


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_sig: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    by_name: dict[str, Instr]


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")


def _parse_operands(rest: str) -> list[str]:
    # operand list up to the matching close paren at depth 0
    depth = 1
    out, cur = [], []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        if depth >= 1:
            cur.append(ch)
    args = "".join(cur)
    names = re.findall(r"%([\w\.\-]+)", args)
    return names


def parse_computations(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        # computation header: "%name (args) -> type {"  or "ENTRY %name ..."
        if (s.endswith("{") and ") -> " in s
                and not s.startswith(("%param", "ROOT"))
                and "=" not in s.split("(", 1)[0]):
            is_entry = s.startswith("ENTRY")
            name = s.split("(", 1)[0].replace("ENTRY", "").strip()
            name = name.lstrip("%").strip()
            cur = Computation(name, [], {})
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if s == "}" or s.startswith("}"):
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        iname, sig, op, rest = m.groups()
        ins = Instr(iname, op, sig, _parse_operands(rest), s)
        cur.instrs.append(ins)
        cur.by_name[iname] = ins
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation,
               all_comps: dict[str, Computation]) -> float:
    """2 × prod(result dims) × contraction size for dot ops."""
    shapes = _shape_dims(ins.result_sig)
    if not shapes:
        return 0.0
    _, rdims = shapes[0]
    out_elems = 1
    for d in rdims:
        out_elems *= d
    # contraction size from lhs shape and lhs_contracting_dims
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    lhs = comp.by_name.get(ins.operands[0]) if ins.operands else None
    if mc and lhs is not None:
        lshapes = _shape_dims(lhs.result_sig)
        if lshapes:
            _, ldims = lshapes[0]
            k = 1
            for idx in mc.group(1).split(","):
                if idx and int(idx) < len(ldims):
                    k *= ldims[int(idx)]
            return 2.0 * out_elems * k
    return 2.0 * out_elems  # fallback


_PASSTHROUGH_OPS = {"parameter", "convert", "bitcast", "copy", "transpose",
                    "reshape", "tuple", "get-tuple-element"}


def _is_convert_only(sub: Computation) -> bool:
    """A fusion body that only moves/casts data (no arithmetic): on the
    TPU target its consumer reads the source at native width instead —
    these fusions are the CPU backend's FloatSupport promotion artifacts
    (bf16 dot/collective operands upcast to f32)."""
    return all(i.op in _PASSTHROUGH_OPS for i in sub.instrs)


def _source_bytes(comp: Computation, name: str,
                  comps: dict[str, Computation], depth: int = 0) -> int:
    """Bytes of a value at its narrowest dtype along the convert chain."""
    ins = comp.by_name.get(name)
    if ins is None or depth > 20:
        return 0
    b = _shapes_bytes(ins.result_sig)
    if ins.op in ("convert", "copy", "bitcast", "transpose", "reshape") \
            and ins.operands:
        src = _source_bytes(comp, ins.operands[0], comps, depth + 1)
        return min(b, src) if src else b
    if ins.op == "fusion":
        m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
        sub = comps.get(m.group(1)) if m else None
        if sub is not None and _is_convert_only(sub):
            src = sum(_source_bytes(comp, o, comps, depth + 1)
                      for o in ins.operands)
            return min(b, src) if src else b
    return b


def _fusion_bytes(ins: Instr, comp: Computation,
                  comps: dict[str, Computation]) -> float:
    """HBM bytes of a fusion call site, slice-aware.

    - a fused operand consumed *only* by dynamic-slice ops inside the body
      reads only the slice(s), not the whole buffer;
    - a fusion whose root is a dynamic-update-slice writes only the update
      (XLA aliases the buffer in place) and doesn't re-read the aliased
      full operand.
    """
    m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
    sub = comps.get(m.group(1)) if m else None
    if sub is None:
        b = _shapes_bytes(ins.result_sig)
        for oname in ins.operands:
            other = comp.by_name.get(oname)
            if other is not None:
                b += _shapes_bytes(other.result_sig)
        return b

    # map param index -> param instruction name inside the body
    param_names: dict[int, str] = {}
    for i_ins in sub.instrs:
        if i_ins.op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", i_ins.line)
            if pm:
                param_names[int(pm.group(1))] = i_ins.name

    # uses of each param inside the body
    uses: dict[str, list[Instr]] = defaultdict(list)
    for i_ins in sub.instrs:
        for o in i_ins.operands:
            uses[o].append(i_ins)

    root = sub.instrs[-1] if sub.instrs else None
    dus_root = None
    if root is not None:
        r = root
        # unwrap bitcast/copy/convert roots
        while r is not None and r.op in ("bitcast", "copy", "convert") \
                and r.operands:
            r = sub.by_name.get(r.operands[0])
        if r is not None and r.op == "dynamic-update-slice":
            dus_root = r
    if dus_root is None:
        # in-place stash pattern: any DUS on a param-sized buffer matching
        # the fusion result shape (XLA aliases these)
        res_dims = [d for _t, d in _shape_dims(ins.result_sig)]
        for i_ins in sub.instrs:
            if i_ins.op != "dynamic-update-slice":
                continue
            dims = [d for _t, d in _shape_dims(i_ins.result_sig)]
            if dims == res_dims:
                dus_root = i_ins
                break

    def trace_params(start: str) -> set[str]:
        """Params reachable through value-preserving/selecting ops — the
        buffers a DUS aliases in place."""
        out: set[str] = set()
        stack, seen = [start], set()
        while stack:
            nm = stack.pop()
            if nm in seen:
                continue
            seen.add(nm)
            i2 = sub.by_name.get(nm)
            if i2 is None:
                continue
            if i2.op == "parameter":
                out.add(nm)
            elif i2.op in ("convert", "bitcast", "copy", "select",
                           "broadcast", "get-tuple-element"):
                stack.extend(i2.operands)
        return out

    total = 0.0
    # writes
    aliased_params: set[str] = set()
    if dus_root is not None:
        upd = sub.by_name.get(dus_root.operands[1]) \
            if len(dus_root.operands) > 1 else None
        total += _shapes_bytes(upd.result_sig) if upd else 0
        if dus_root.operands:
            aliased_params = trace_params(dus_root.operands[0])
    else:
        total += _shapes_bytes(ins.result_sig)

    # reads
    for idx, oname in enumerate(ins.operands):
        other = comp.by_name.get(oname)
        if other is None:
            continue
        pname = param_names.get(idx)
        if pname is not None and pname in aliased_params:
            continue                        # in-place aliased buffer
        if pname is not None and uses.get(pname):
            if all(u.op == "dynamic-slice" and u.operands
                   and u.operands[0] == pname for u in uses[pname]):
                total += sum(_shapes_bytes(u.result_sig)
                             for u in uses[pname])
                continue
        total += _source_bytes(comp, oname, comps)
    return total


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes_by_op: dict = dataclasses.field(default_factory=dict)
    coll_count_by_op: dict = dataclasses.field(default_factory=dict)
    # mesh axis (or "a+b" combination) → op → bytes; populated only when
    # analyze_hlo is given `axis_groups` (see `mesh_axis_groups`)
    coll_bytes_by_axis: dict = dataclasses.field(default_factory=dict)
    transcendental_free: bool = True   # we only count dots

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes_by_op.values())


def analyze_hlo(text: str, axis_groups: dict | None = None) -> HloStats:
    """Loop-aware roofline stats of partitioned HLO `text`.

    `axis_groups` (from `mesh_axis_groups`) additionally attributes every
    collective's bytes to the mesh axis its replica groups span —
    `HloStats.coll_bytes_by_axis` — so e.g. a pipeline cell can report
    stage-axis ppermute traffic separately from model-axis all-reduces.
    """
    comps, entry = parse_computations(text)
    memo: dict[str, HloStats] = {}

    def called_comp(ins: Instr, attr: str) -> str | None:
        m = re.search(rf"{attr}=%?([\w\.\-]+)", ins.line)
        return m.group(1) if m else None

    def flops_only(cname: str, depth: int = 0) -> float:
        """dot flops including fusion bodies (no HBM side effects)."""
        if depth > 80 or cname not in comps:
            return 0.0
        total = 0.0
        comp = comps[cname]
        for ins in comp.instrs:
            if ins.op in ("dot", "convolution"):
                total += _dot_flops(ins, comp, comps)
            sub = called_comp(ins, "calls")
            if sub:
                total += flops_only(sub, depth + 1)
        return total

    def merge(st: HloStats, sub: HloStats, mult: float) -> None:
        st.flops += sub.flops * mult
        st.hbm_bytes += sub.hbm_bytes * mult
        for k, v in sub.coll_bytes_by_op.items():
            st.coll_bytes_by_op[k] += v * mult
        for k, v in sub.coll_count_by_op.items():
            st.coll_count_by_op[k] += v * mult
        for ax, by_op in sub.coll_bytes_by_axis.items():
            acc = st.coll_bytes_by_axis.setdefault(ax, defaultdict(float))
            for k, v in by_op.items():
                acc[k] += v * mult

    def analyze(cname: str, depth: int = 0) -> HloStats:
        if cname in memo:
            return memo[cname]
        st = HloStats(coll_bytes_by_op=defaultdict(float),
                      coll_count_by_op=defaultdict(int))
        if depth > 80 or cname not in comps:
            return st
        comp = comps[cname]
        for ins in comp.instrs:
            op = ins.op
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                b = _shapes_bytes(ins.result_sig) * _COLL_MULT[base]
                # XLA's AllReducePromotion wraps 16-bit collectives in
                # convert-to-f32 on backends without native bf16 reduction
                # (the CPU host backend here).  The TPU target reduces
                # natively in bf16, so count promoted collectives at their
                # logical (pre-promotion) width.
                if "_promoted" in ins.line:
                    b *= 0.5
                st.coll_bytes_by_op[base] += b
                st.coll_count_by_op[base] += 1
                if axis_groups is not None:
                    ax = _collective_axis(ins.line, axis_groups)
                    st.coll_bytes_by_axis.setdefault(
                        ax, defaultdict(float))[base] += b
                st.hbm_bytes += _shapes_bytes(ins.result_sig)
                continue
            if op == "while":
                trips = 1
                mt = _TRIP_RE.search(ins.line)
                if mt:
                    trips = int(mt.group(1))
                body = called_comp(ins, "body")
                cond = called_comp(ins, "condition")
                for sub_name in (body, cond):
                    if sub_name:
                        merge(st, analyze(sub_name, depth + 1), trips)
                continue
            if op in ("call", "conditional", "async-start"):
                sub_name = (called_comp(ins, "to_apply")
                            or called_comp(ins, "calls"))
                if sub_name:
                    merge(st, analyze(sub_name, depth + 1), 1)
                continue
            if op in ("dot", "convolution"):
                st.flops += _dot_flops(ins, comp, comps)
            elif op == "fusion":
                sub_name = called_comp(ins, "calls")
                if sub_name:
                    st.flops += flops_only(sub_name, depth + 1)
            elif op == "custom-call":
                # CPU backend lowers some dots to custom-calls (oneDNN);
                # approximate from shapes: out × lhs-minor contraction
                pass
            if op in _FREE_OPS:
                continue
            if op == "copy" and ins.operands:
                src = comp.by_name.get(ins.operands[0])
                if src is not None and src.op == "get-tuple-element":
                    # copy-insertion artifact on a while-loop carry: the
                    # TPU scheduler aliases these in place
                    continue
            # HBM traffic: result + operands of this top-level instruction
            if op == "dynamic-update-slice":
                # in-place on real backends: writes only the update slice
                upd = comp.by_name.get(ins.operands[1]) \
                    if len(ins.operands) > 1 else None
                st.hbm_bytes += 2 * _shapes_bytes(
                    upd.result_sig) if upd else 0
                continue
            if op == "dynamic-slice":
                # reads + writes only the slice
                st.hbm_bytes += 2 * _shapes_bytes(ins.result_sig)
                continue
            if op == "scatter":
                # in-place on real backends: writes the updates (operand 2)
                upd = comp.by_name.get(ins.operands[2]) \
                    if len(ins.operands) > 2 else None
                st.hbm_bytes += 2 * _shapes_bytes(
                    upd.result_sig) if upd else _shapes_bytes(ins.result_sig)
                continue
            if op == "fusion":
                m2 = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                sub2 = comps.get(m2.group(1)) if m2 else None
                if sub2 is not None and _is_convert_only(sub2):
                    continue   # promotion artifact; consumers charge source
                st.hbm_bytes += _fusion_bytes(ins, comp, comps)
                continue
            b = _shapes_bytes(ins.result_sig)
            for oname in ins.operands:
                if comp.by_name.get(oname) is not None:
                    b += _source_bytes(comp, oname, comps)
            st.hbm_bytes += b
        st.coll_bytes_by_op = dict(st.coll_bytes_by_op)
        st.coll_count_by_op = dict(st.coll_count_by_op)
        st.coll_bytes_by_axis = {ax: dict(by_op) for ax, by_op
                                 in st.coll_bytes_by_axis.items()}
        memo[cname] = st
        return st

    if not entry:
        # fall back to the largest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps else ""
    return analyze(entry)


# Back-compat shim for the collective-only interface
@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict[str, float]
    count_by_op: dict[str, int]
    unresolved_loops: int = 0

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def analyze_collectives(hlo_text: str) -> CollectiveStats:
    st = analyze_hlo(hlo_text)
    return CollectiveStats(bytes_by_op=st.coll_bytes_by_op,
                           count_by_op=st.coll_count_by_op,
                           unresolved_loops=0)
