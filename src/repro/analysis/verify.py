"""`verify_launch`: the full mklint pass over one launch configuration.

Runs every rule family against the exact objects `launch.train.build`
would construct — same config transforms (`tp_align`), same mesh, same
plan, same step program, same spec composition, same traced collectives
— but *before* compile: nothing lowers, nothing allocates parameters,
and the pipeline plan prices stages with the analytic cost model instead
of compiling XLA probes, so a verdict lands in well under ~2s on the
smoke configs (the Report's ``wall_s`` records the measured cost; tests
pin the budget).

Check order (each layer gates the next — a malformed mesh makes the
plan meaningless, a failed plan makes tracing impossible):

1. mesh CLI rules (``MK-M``, symbolic — no devices touched);
2. launch arithmetic (``MK-L``): dp/microbatch divisibility, schedule
   name, stage count vs repeats, flag conflicts;
3. step-program dataflow (``MK-P``) on the schedule's generated program;
4. sharding-spec lint (``MK-S``) on the stage-stacked abstract params —
   the very spec tree the islands get as in_specs;
5. collective alignment (``MK-C``): trace the (forward) pipelined loss
   with `jax.make_jaxpr` under the mesh, walk every shard_map island,
   abstract-interpret varying sets.  Forward-only keeps jamba-class
   traces inside the budget; the backward is the transpose of the same
   island program, and its schedule-level timing is what ``MK-P``
   already verified;
6. Pallas kernel geometry (``MK-K``, optional — config-independent).
"""
from __future__ import annotations

import time
from typing import Sequence

from .diagnostics import Report, error
from .meshcli import resolve_mesh_cli


def _fmt_csv(value) -> str | None:
    """Accept CLI strings or int/str sequences for mesh_shape/axes."""
    if value is None or isinstance(value, str):
        return value
    return ",".join(str(v) for v in value)


def verify_launch(arch: str, *, smoke: bool = True, global_batch: int = 8,
                  seq_len: int = 128, stages: int = 1, microbatch: int = 0,
                  model_par: int = 1, data_par: int | None = None,
                  mesh_shape=None, axes=None,
                  schedule: str = "gpipe", virtual_stages: int = 1,
                  flags: Sequence[str] = (),
                  check_kernels: bool = True,
                  trace_collectives: bool = True) -> Report:
    """Statically verify a launch configuration; never compiles.

    Mirrors `repro.launch.train.build`'s keyword surface (`mesh_shape`
    and `axes` also accept the CLI's comma-separated strings; `data_par`
    mirrors `launch.dryrun`'s explicit pipeline mesh) and returns a
    `Report`; the launch should proceed iff ``report.ok``.
    """
    t0 = time.perf_counter()
    if mesh_shape is None and data_par is not None:
        # dryrun-style explicit pipeline mesh: (stage, data[, model])
        if stages > 1 and model_par > 1:
            mesh_shape, axes = ((stages, data_par, model_par),
                                ("stage", "data", "model"))
        elif stages > 1:
            mesh_shape, axes = (stages, data_par), ("stage", "data")
        else:
            mesh_shape, axes = (data_par, model_par), ("data", "model")
    target = (f"{arch}{' smoke' if smoke else ''} stages={stages} "
              f"schedule={schedule}")
    report = Report(target=target)

    def done() -> Report:
        report.wall_s = time.perf_counter() - t0
        return report

    # -- 1. mesh rules (symbolic) ------------------------------------
    shape, names, mdiags = resolve_mesh_cli(
        _fmt_csv(mesh_shape), _fmt_csv(axes), stages, model_par)
    report.extend(mdiags)
    if report.errors:
        return done()

    # jax only from here on (keeps `--help`-adjacent paths import-light)
    import jax

    from repro.configs import get_config, get_smoke
    from repro.dist.context import sharding_context
    from repro.dist.sharding import (data_par_size, param_specs,
                                     stage_stack_specs)
    from repro.dist.pipeline import SCHEDULES, make_step_program
    from repro.launch.mesh import make_mesh, make_train_mesh
    from repro.models.common import tp_align
    from repro.models.transformer import init_params
    from repro.train.pipeline import plan_pipeline

    from .collectives import check_shard_map_islands
    from .costmodel import analytic_block_cost as _analytic_block_cost
    from .dataflow import check_step_program
    from .shardspec import check_spec_tree

    cfg = get_smoke(arch) if smoke else get_config(arch)
    if shape is not None:
        mesh = make_mesh(shape, names)
    else:
        mesh = make_train_mesh(n_stages=stages, model_par=model_par)
    mesh_axes = dict(mesh.shape)
    tp = mesh_axes.get("model", 1)
    if tp > 1:
        cfg = tp_align(cfg, tp)
    dp = data_par_size(mesh)
    n_micro = microbatch or max(global_batch // max(dp, 1), 1)
    loc = f"launch {target}"

    # -- 2. launch arithmetic ----------------------------------------
    if schedule not in SCHEDULES:
        report.add(error(
            "MK-L004", loc,
            f"unknown schedule {schedule!r}; the executors implement "
            f"{SCHEDULES}"))
    v = int(virtual_stages)
    if v < 1:
        report.add(error(
            "MK-L007", loc,
            f"virtual_stages must be >= 1, got {virtual_stages}"))
        v = 1
    elif v > 1 and schedule != "interleaved":
        report.add(error(
            "MK-L007", loc,
            f"--virtual-stages {v} requires --schedule interleaved "
            f"(got {schedule!r}) — only the interleaved executor holds "
            "multiple chunks per device",
            "drop --virtual-stages or switch the schedule"))
    elif v > 1 and v * stages > cfg.n_repeats:
        report.add(error(
            "MK-L001", loc,
            f"{cfg.name}: n_repeats={cfg.n_repeats} < "
            f"virtual_stages*n_stages={v * stages} — every virtual "
            "stage needs at least one repeat to hold"))
    if stages > 1 and "grad_int8" in flags:
        report.add(error(
            "MK-L005", loc,
            "grad_int8 and pipeline stages are mutually exclusive",
            "run one A/B at a time"))
    if "kernels_ref" in flags and "kernels_pallas" in flags:
        report.add(error(
            "MK-L006", loc,
            "kernels_ref and kernels_pallas are mutually exclusive — "
            "the layers dispatch on one kernel mode",
            "pass a single --kernels mode (off, ref, or pallas)"))
    if stages > cfg.n_repeats:
        report.add(error(
            "MK-L001", loc,
            f"{cfg.name}: n_repeats={cfg.n_repeats} < n_stages={stages} "
            "— every stage needs at least one repeat to hold"))
    if global_batch % dp:
        report.add(error(
            "MK-L002", loc,
            f"global_batch={global_batch} not divisible by dp={dp} "
            f"(mesh {mesh_axes})",
            "pick a batch the data axes divide, or shrink the mesh"))
    elif (global_batch // dp) % n_micro:
        report.add(error(
            "MK-L003", loc,
            f"per-shard batch {global_batch // dp} not divisible by "
            f"n_micro={n_micro}",
            "adjust --microbatch (default: one per per-shard example)"))
    if report.errors:
        return done()

    # -- 3/4/5: pipeline plan, program, specs, collectives -----------
    plan = None
    if stages > 1:
        mb = max(global_batch // dp // n_micro, 1)
        plan = plan_pipeline(
            cfg, stages, n_micro, global_batch=global_batch,
            seq_len=seq_len, dp=dp, tp=tp, schedule=schedule,
            virtual_stages=v,
            block_costs=[_analytic_block_cost(cfg, p, mb * seq_len)
                         for p in range(len(cfg.pattern))])

        prog = make_step_program(n_micro, stages, schedule,
                                 virtual_stages=v)
        report.extend(check_step_program(prog, n_micro, stages,
                                         schedule=schedule,
                                         virtual_stages=v))

        params_abs = jax.eval_shape(
            lambda: init_params(cfg, jax.random.key(0)))
        from repro.models.pipeline import loss_fn_pipelined, stage_stack
        manual = tuple(a for a in ("stage", "model")
                       if mesh_axes.get(a, 1) > 1)
        for pos in range(len(cfg.pattern)):
            row = tuple(plan.sizes[pos])
            # an interleaved plan's sizes rows are per *group*; each
            # chunk's S-entry slice is one island's stage stack, sliced
            # from the chunk's contiguous repeats (models.pipeline)
            for c in range(v):
                chunk_sizes = row[c * stages:(c + 1) * stages]
                off = sum(row[:c * stages])
                cnt = sum(chunk_sizes)
                st_abs = jax.eval_shape(
                    lambda t, _o=off, _n=cnt, sz=chunk_sizes: stage_stack(
                        jax.tree.map(lambda p: p[_o:_o + _n], t),
                        stages, sz),
                    params_abs["layers"][pos])
                st_specs = stage_stack_specs(param_specs(st_abs))
                report.extend(check_spec_tree(
                    st_abs, st_specs, mesh_axes,
                    loc_prefix=(f"island in_specs (pattern pos {pos}"
                                + (f", chunk {c}" if v > 1 else "") + ")"),
                    manual_axes=manual))
        if report.errors:
            return done()

        if trace_collectives:
            batch_abs = _abstract_batch(cfg, global_batch, seq_len)

            def lf(params, batch):
                return loss_fn_pipelined(
                    params, cfg, batch, stages, n_micro, remat=False,
                    axis=plan.axis, schedule=plan.schedule,
                    sizes=plan.sizes,
                    virtual_stages=plan.virtual_stages)

            with mesh, sharding_context(mesh, flags=tuple(flags)):
                closed = jax.make_jaxpr(lf)(params_abs, batch_abs)
            report.extend(check_shard_map_islands(
                closed, mesh_axes, loc=loc))
    elif trace_collectives:
        from repro.models.transformer import loss_fn
        params_abs = jax.eval_shape(
            lambda: init_params(cfg, jax.random.key(0)))
        batch_abs = _abstract_batch(cfg, global_batch, seq_len)

        def lf(params, batch):
            return loss_fn(params, cfg, batch, remat=False)

        with mesh, sharding_context(mesh, flags=tuple(flags)):
            closed = jax.make_jaxpr(lf)(params_abs, batch_abs)
        report.extend(check_shard_map_islands(closed, mesh_axes, loc=loc))

    # -- 6. kernel geometry (config-independent) ---------------------
    if check_kernels:
        from .kernels import check_repo_kernels
        report.extend(check_repo_kernels())

    return done()


def _abstract_batch(cfg, global_batch: int, seq_len: int):
    """ShapeDtypeStructs mirroring `launch.train`'s ``wrapped`` batch."""
    import jax
    import jax.numpy as jnp

    B = global_batch
    batch = {"tokens": jax.ShapeDtypeStruct((B, seq_len), jnp.int32),
             "labels": jax.ShapeDtypeStruct((B, seq_len), jnp.int32)}
    if cfg.num_patches:
        batch["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.is_encdec:
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


__all__ = ["verify_launch"]
