"""Mesh-configuration rules (family ``MK-M``): pure string/arithmetic
validation of ``--mesh-shape/--axes/--stages/--model-par`` combinations.

No jax import — these rules run before any device allocation, so an
axis typo in a launch command fails with a readable diagnostic instead
of a shard_map traceback after the mesh (and its arrays) exist.
`repro.launch.train.parse_mesh_cli` routes through `check_mesh_cli` and
raises `DiagnosticError` (a ValueError) listing every finding at once.
"""
from __future__ import annotations

from .diagnostics import Diagnostic, error, warning

# the axis names the sharding substrate understands (mirrors
# repro.dist.context: DATA_AXES + MODEL_AXIS + the pipeline stage axis)
KNOWN_AXES = ("stage", "pod", "data", "model")
DEFAULT_AXES = {1: ("data",), 2: ("data", "model"),
                3: ("stage", "data", "model")}


def resolve_mesh_cli(mesh_shape: str | None, axes: str | None,
                     stages: int, model_par: int = 1
                     ) -> tuple[tuple[int, ...] | None,
                                tuple[str, ...] | None,
                                list[Diagnostic]]:
    """Parse + verify the mesh CLI; returns ``(shape, names, diags)``.

    ``shape``/``names`` are None when no explicit mesh was requested (or
    when it was malformed beyond parsing); callers must treat any
    error-severity diagnostic as fatal.
    """
    diags: list[Diagnostic] = []
    loc = f"--mesh-shape {mesh_shape} --axes {axes}"
    if mesh_shape is None:
        if axes is not None:
            diags.append(error(
                "MK-M002", loc, "--axes given without --mesh-shape",
                "pass both, or neither (the default mesh fills the "
                "available devices)"))
        return None, None, diags

    try:
        shape = tuple(int(s) for s in mesh_shape.split(",") if s.strip())
    except ValueError:
        diags.append(error(
            "MK-M001", loc,
            f"--mesh-shape wants comma-separated ints, got "
            f"{mesh_shape!r}", "e.g. --mesh-shape 2,2,2"))
        return None, None, diags
    if not shape or any(s < 1 for s in shape):
        diags.append(error(
            "MK-M001", loc,
            f"--mesh-shape entries must be >= 1: {shape}"))
        return None, None, diags

    if axes is None:
        names = DEFAULT_AXES.get(len(shape))
        if names is None:
            diags.append(error(
                "MK-M002", loc,
                f"no default axis names for a rank-{len(shape)} mesh",
                "pass --axes, e.g. --axes stage,data,model"))
            return None, None, diags
    else:
        names = tuple(a.strip() for a in axes.split(",") if a.strip())
        if len(names) != len(shape):
            diags.append(error(
                "MK-M002", loc,
                f"--mesh-shape {shape} and --axes {names} disagree on "
                "rank"))
            return None, None, diags

    for a in names:
        if a not in KNOWN_AXES:
            close = _closest(a)
            diags.append(error(
                "MK-M003", loc,
                f"unknown mesh axis {a!r}; the sharding substrate knows "
                f"{KNOWN_AXES}",
                f"did you mean {close!r}?" if close else
                "collectives and PartitionSpecs only name these axes"))
    if len(set(names)) != len(names):
        dup = sorted({a for a in names if names.count(a) > 1})
        diags.append(error(
            "MK-M004", loc, f"duplicate mesh axes {dup} in {names}"))

    sizes = dict(zip(names, shape))
    stage_size = sizes.get("stage", 1)
    if stages > 1 and stage_size != stages:
        diags.append(error(
            "MK-M005", loc,
            f"--stages {stages} needs a 'stage' axis of that size in "
            f"the mesh, got {sizes}"))
    if stages <= 1 and stage_size != 1:
        diags.append(error(
            "MK-M005", loc,
            f"mesh carries a 'stage' axis of size {stage_size} but "
            f"--stages is {stages}",
            f"pass --stages {stage_size}"))
    model_size = sizes.get("model", 1)
    if model_par > 1 and model_size != model_par:
        diags.append(warning(
            "MK-M006", loc,
            f"--model-par {model_par} is ignored when --mesh-shape is "
            f"explicit (the mesh's model axis is {model_size})",
            "drop --model-par or make the mesh's model axis match"))
    return shape, names, diags


def _closest(name: str) -> str | None:
    """Cheap typo hint: the known axis sharing the longest prefix."""
    best, best_len = None, 0
    for known in KNOWN_AXES:
        n = 0
        for a, b in zip(name.lower(), known):
            if a != b:
                break
            n += 1
        if n > best_len:
            best, best_len = known, n
    return best if best_len >= 2 else None


def check_mesh_cli(mesh_shape: str | None, axes: str | None, stages: int,
                   model_par: int = 1) -> list[Diagnostic]:
    """Diagnostics-only form of `resolve_mesh_cli`."""
    return resolve_mesh_cli(mesh_shape, axes, stages, model_par)[2]


__all__ = ["DEFAULT_AXES", "KNOWN_AXES", "check_mesh_cli",
           "resolve_mesh_cli"]
