"""Pallas kernel geometry checks (rule family ``MK-K``).

The five kernels under `src/repro/kernels/` each build a `pallas_call`
whose correctness rests on grid arithmetic: block shapes must divide the
(padded) operand dims, index maps must stay inside each operand's block
grid, and the union of output blocks visited over the grid must cover
the whole output — a gap is silently-uninitialized memory, an overrun is
an interpreter error on CPU and garbage on hardware.

Nothing compiles here.  `record_pallas_calls` monkeypatches
``pallas.pallas_call`` with a recorder that captures (grid, specs,
out_shape, operand shapes, scalar-prefetch arrays) and returns zeros, so
running a kernel *builder* eagerly on small concrete inputs yields a
`PallasCallRecord` per call site; `check_pallas_call` then evaluates
every index map over the whole grid with concrete integers (scalar-
prefetch tables are real numpy arrays, so prefetch-driven maps like
flash attention's ``pi[p]`` evaluate exactly).  `check_repo_kernels`
drives the five builders on dividing smoke shapes — the same geometry
class the real configs use, ~1e2 grid points, milliseconds."""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
import math
from typing import Any, Callable, Iterator, Sequence

import numpy as np

from .diagnostics import Diagnostic, error, warning

_MAX_GRID_POINTS = 200_000   # guard: lint evaluates index maps per point

# mirrors `repro.kernels.tune._TARGETS` (parity-tested): the pow2 ladder
# the tuner enumerates around and the dispatch clamps block args toward
_POW2_TARGETS = (16, 32, 64, 128, 256, 512)


def _largest_divisor(n: int, target: int) -> int:
    d = max(min(target, n), 1)
    while n % d:
        d -= 1
    return d


def check_block_clamp(name: str, what: str, dim: int,
                      target: int) -> list[Diagnostic]:
    """MK-K008: the `_divisor` clamp (largest divisor of ``dim`` not
    above ``target``) degrades a ragged dim to a block under half the
    intended target — e.g. a 131-row operand collapses to 1-row blocks,
    the ROADMAP's one-block 130-row shape class.  The kernel stays
    *correct* (hence warning, not error) but the grid loses its
    vector-width economics; padding the dim keeps the intended block."""
    dim, target = int(dim), int(target)
    got = _largest_divisor(dim, target)
    if 2 * got >= min(target, dim):
        return []
    return [warning(
        "MK-K008", f"kernel {name}: {what}",
        f"divisor clamp shrinks the block to {got} for dim {dim} "
        f"(target {target}) — under half the intended block",
        f"pad the dim to a multiple of a pow2 block (e.g. "
        f"{-(-dim // target) * target}) instead of clamping; ragged "
        "dims cost a masked tail block, not a degenerate grid")]


@dataclasses.dataclass
class PallasCallRecord:
    """One captured pallas_call: everything the geometry checks need."""
    name: str
    grid: tuple[int, ...]
    in_specs: list[Any]                  # BlockSpec per non-prefetch operand
    out_specs: list[Any]
    out_shapes: list[tuple[int, ...]]
    operand_shapes: list[tuple[int, ...]]
    prefetch: tuple[Any, ...] = ()       # concrete scalar-prefetch arrays


def _as_list(x) -> list:
    if x is None:
        return []
    return list(x) if isinstance(x, (tuple, list)) else [x]


@contextlib.contextmanager
def record_pallas_calls(records: list[PallasCallRecord],
                        name: str = "pallas_call") -> Iterator[None]:
    """Swap ``pallas.pallas_call`` for a recorder.

    Inside the context, kernel builders run eagerly but nothing lowers:
    each call site appends a `PallasCallRecord` and the returned callable
    hands back numpy zeros of ``out_shape`` (so builder post-processing —
    reshapes, transposes — still runs, off jax's dispatch path)."""
    from jax.experimental import pallas

    real = pallas.pallas_call

    def recorder(kernel, *, grid=None, grid_spec=None, in_specs=None,
                 out_specs=None, out_shape=None, **kw):
        nsp = 0
        if grid_spec is not None:
            grid = getattr(grid_spec, "grid", grid)
            in_specs = getattr(grid_spec, "in_specs", in_specs)
            out_specs = getattr(grid_spec, "out_specs", out_specs)
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0))
        out_shapes = _as_list(out_shape)

        def run(*operands):
            prefetch = tuple(np.asarray(o) for o in operands[:nsp])
            records.append(PallasCallRecord(
                name=name,
                grid=tuple(int(g) for g in _as_list(grid)),
                in_specs=_as_list(in_specs),
                out_specs=_as_list(out_specs),
                out_shapes=[tuple(s.shape) for s in out_shapes],
                operand_shapes=[tuple(o.shape)
                                for o in operands[nsp:]],
                prefetch=prefetch,
            ))
            outs = [np.zeros(s.shape, s.dtype) for s in out_shapes]
            if isinstance(out_shape, (tuple, list)):
                return type(out_shape)(outs)
            return outs[0]

        return run

    pallas.pallas_call = recorder
    try:
        yield
    finally:
        pallas.pallas_call = real


def _block_counts(shape: Sequence[int], block: Sequence[int | None],
                  ) -> list[int]:
    return [math.ceil(dim / (bs or 1)) for dim, bs in zip(shape, block)]


def _check_one_spec(rec: PallasCallRecord, spec, shape: Sequence[int],
                    what: str, coverage: bool,
                    ) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    loc = f"kernel {rec.name}: {what}"
    block = getattr(spec, "block_shape", None)
    index_map = getattr(spec, "index_map", None)
    if block is None:
        return diags

    if len(block) != len(shape):
        diags.append(error(
            "MK-K001", loc,
            f"block shape {tuple(block)} has rank {len(block)} but the "
            f"operand is rank {len(shape)} ({tuple(shape)})"))
        return diags
    for d, (dim, bs) in enumerate(zip(shape, block)):
        if bs is not None and dim % bs:
            diags.append(error(
                "MK-K001", loc,
                f"dim {d}: block size {bs} does not divide the operand "
                f"dim {dim} (shape {tuple(shape)})",
                "pad the operand to a multiple of the block, or clamp "
                "the block (the repo kernels min() their block args)"))
    if diags or index_map is None:
        return diags   # non-dividing blocks poison the bounds math below
    # MK-K008 on the realized geometry: a dividing block that sits
    # exactly where the ladder clamp lands a ragged dim, under half the
    # pow2 target — the recorded call ran the degraded grid (warning
    # only; the bounds/coverage checks below still run)
    for d, (dim, bs) in enumerate(zip(shape, block)):
        if bs is None or bs >= dim:
            continue
        t = max((t for t in _POW2_TARGETS if t <= dim), default=0)
        if t and bs == _largest_divisor(dim, t):
            diags.extend(check_block_clamp(rec.name, f"{what} dim {d}",
                                           dim, t))

    counts = _block_counts(shape, block)
    n_points = 1
    for g in rec.grid:
        n_points *= g
    if n_points > _MAX_GRID_POINTS:
        return diags   # lint stays O(small); real configs never hit this

    visited: set[tuple[int, ...]] = set()
    reported_oob = False
    for ids in itertools.product(*(range(g) for g in rec.grid)):
        try:
            idx = index_map(*ids, *rec.prefetch)
        except Exception as e:   # a crashing map is itself a finding
            diags.append(error(
                "MK-K002", loc,
                f"index map raised {type(e).__name__}: {e} at grid "
                f"point {ids}"))
            return diags
        idx = tuple(int(i) for i in _as_list(idx))
        if len(idx) != len(block):
            diags.append(error(
                "MK-K002", loc,
                f"index map returned {len(idx)} indices for a rank-"
                f"{len(block)} block at grid point {ids}"))
            return diags
        oob = [d for d, i in enumerate(idx)
               if not 0 <= i < counts[d]]
        if oob and not reported_oob:
            reported_oob = True
            diags.append(error(
                "MK-K002", loc,
                f"index map returns block index {idx} at grid point "
                f"{ids}, outside the block grid {tuple(counts)} (operand "
                f"{tuple(shape)}, block {tuple(block)})",
                "block indices count blocks, not elements"))
        if not oob:
            visited.add(idx)

    if coverage and not reported_oob:
        total = 1
        for c in counts:
            total *= c
        if len(visited) < total:
            missing = next(
                idx for idx in itertools.product(
                    *(range(c) for c in counts)) if idx not in visited)
            diags.append(error(
                "MK-K003", loc,
                f"grid x block covers {len(visited)} of {total} output "
                f"blocks (first uncovered: {missing}) — unvisited "
                "blocks are never written",
                "the grid (or the prefetch pair tables driving it) must "
                "reach every output block"))
    return diags


def check_pallas_call(rec: PallasCallRecord) -> list[Diagnostic]:
    """Geometry-check one recorded pallas_call (pure, no jax tracing)."""
    diags: list[Diagnostic] = []
    if len(rec.in_specs) != len(rec.operand_shapes):
        diags.append(error(
            "MK-K001", f"kernel {rec.name}",
            f"{len(rec.in_specs)} in_specs for "
            f"{len(rec.operand_shapes)} operands"))
        return diags
    for i, (spec, shape) in enumerate(zip(rec.in_specs,
                                          rec.operand_shapes)):
        diags.extend(_check_one_spec(rec, spec, shape, f"operand {i}",
                                     coverage=False))
    for i, (spec, shape) in enumerate(zip(rec.out_specs, rec.out_shapes)):
        diags.extend(_check_one_spec(rec, spec, shape, f"output {i}",
                                     coverage=True))
    return diags


def _smoke_builders() -> list[tuple[str, Callable[[], None]]]:
    """The five kernel builders on tiny dividing shapes — geometry-
    equivalent to the real configs, milliseconds to evaluate.  Inputs
    are numpy: the builders only reshape/transpose operands before the
    (recorded) pallas_call, and numpy keeps the lint off jax's dispatch
    path."""
    f32 = np.float32

    def flash():
        from repro.kernels.flash_attention.kernel import (
            flash_attention_kernel)
        q = np.zeros((1, 128, 2, 8), f32)
        k = np.zeros((1, 128, 1, 8), f32)
        flash_attention_kernel(q, k, k, causal=True, q_blk=64, kv_blk=64)

    def mlp():
        from repro.kernels.fused_mlp.kernel import fused_mlp_kernel
        x = np.zeros((128, 16), f32)
        wu = np.zeros((16, 256), f32)
        wd = np.zeros((256, 16), f32)
        fused_mlp_kernel(x, wu, wd, np.zeros((16, 256), f32),
                         bm=64, bff=128)
        fused_mlp_kernel(x, wu, wd, None, act="gelu", bm=64, bff=128)

    def rmsnorm():
        from repro.kernels.fused_rmsnorm.kernel import fused_rmsnorm_kernel
        fused_rmsnorm_kernel(np.zeros((128, 16), f32),
                             np.zeros((16,), f32), bm=64)

    def moe():
        from repro.kernels.moe_gmm.kernel import moe_gmm_kernel
        moe_gmm_kernel(np.zeros((2, 64, 32), f32),
                       np.zeros((2, 32, 64), f32), bc=32, bf=32, bd=16)

    def ssd():
        from repro.kernels.ssd_chunk.kernel import ssd_chunk_kernel
        ssd_chunk_kernel(np.zeros((4, 2, 16, 8), f32),
                         np.zeros((4, 2, 1, 16), f32),
                         np.zeros((2,), f32), np.zeros((4, 16, 4), f32),
                         np.zeros((4, 16, 4), f32))

    return [("flash_attention", flash), ("fused_mlp", mlp),
            ("fused_rmsnorm", rmsnorm), ("moe_gmm", moe),
            ("ssd_chunk", ssd)]


def check_kernel_builder(name: str, build: Callable[[], Any],
                         ) -> list[Diagnostic]:
    """Record one kernel builder and geometry-check every pallas_call it
    makes.  A builder that raises under the recorder (shape asserts,
    bad block arithmetic) is itself an MK-K001 finding — this is what
    lets the autotuner screen candidate block configs without lowering
    anything."""
    records: list[PallasCallRecord] = []
    try:
        with record_pallas_calls(records, name=name):
            build()
    except Exception as e:
        return [error(
            "MK-K001", f"kernel {name}",
            f"builder failed under the recorder: "
            f"{type(e).__name__}: {e}")]
    diags: list[Diagnostic] = []
    for rec in records:
        diags.extend(check_pallas_call(rec))
    return diags


def check_repo_kernels() -> list[Diagnostic]:
    """Record and geometry-check every kernel under `src/repro/kernels/`."""
    diags: list[Diagnostic] = []
    for name, build in _smoke_builders():
        diags.extend(check_kernel_builder(name, build))
    return diags


__all__ = ["PallasCallRecord", "check_block_clamp", "check_kernel_builder",
           "check_pallas_call", "check_repo_kernels",
           "record_pallas_calls"]
