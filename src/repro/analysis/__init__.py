"""mklint: pre-compile static verification of launch configurations.

MKPipe statically analyzes the multi-kernel graph before it enqueues
anything; this package is the mesh-scale analogue.  `verify_launch`
checks a (config, mesh, schedule) combination — collective alignment
inside the shard_map islands, step-program dataflow, sharding-spec
composition, Pallas kernel geometry — and returns structured
diagnostics (stable rule ID, severity, location, fix hint) instead of
asserting, deadlocking, or tracebacking mid-compile.

Surfaces: ``tools/mklint.py`` (CLI), ``--verify`` on the train/dryrun
launchers, and this importable API.  Rule catalog: `RULES` here,
prose in ``docs/static-analysis.md``.

Import layering: `diagnostics`/`meshcli`/`dataflow` are jax-free (the
launchers use them before touching devices); `verify_launch` imports
jax lazily on first call.
"""
from .dataflow import check_step_program
from .diagnostics import (RULES, Diagnostic, DiagnosticError, Report,
                          Severity, error, info, warning)
from .meshcli import check_mesh_cli, resolve_mesh_cli
from .verify import verify_launch

__all__ = [
    "Diagnostic", "DiagnosticError", "RULES", "Report", "Severity",
    "check_mesh_cli", "check_step_program", "error", "info",
    "resolve_mesh_cli", "verify_launch", "warning",
]
