"""mklint: pre-compile static verification of launch configurations.

MKPipe statically analyzes the multi-kernel graph before it enqueues
anything; this package is the mesh-scale analogue.  `verify_launch`
checks a (config, mesh, schedule) combination — collective alignment
inside the shard_map islands, step-program dataflow, sharding-spec
composition, Pallas kernel geometry — and returns structured
diagnostics (stable rule ID, severity, location, fix hint) instead of
asserting, deadlocking, or tracebacking mid-compile.

Beyond correctness, `costmodel` is the unified analytic pricing API
(bubble/peak/roofline/block/collective/kernel-footprint models — the
single home for every formula the launch stack scores with) and
`planner` walks the discrete launch space with those models, marks
statically-dominated configs, and emits the Pareto frontier as MK-T
diagnostics (mkplan).

Surfaces: ``tools/mklint.py`` (CLI, incl. ``--plan``),
``repro.launch.choose`` (frontier CLI), ``--verify`` on the
train/dryrun launchers, and this importable API.  Rule catalog:
`RULES` here, prose in ``docs/static-analysis.md``; formulas in
``docs/cost-models.md``.

Import layering: `diagnostics`/`meshcli`/`dataflow`/`costmodel`/
`planner` are jax-free at import (the launchers use them before
touching devices); `verify_launch` and the planner's scoring import
jax lazily on first call.
"""
from .costmodel import (estimate_block_costs, estimate_collective_bytes,
                        kernel_footprint, pipeline_bubble_fraction,
                        pipeline_peak_activation_bytes,
                        pipeline_peak_inflight, roofline_terms)
from .dataflow import check_step_program
from .diagnostics import (RULES, Diagnostic, DiagnosticError, Report,
                          Severity, error, info, warning)
from .elastic import check_restore_manifest, check_shrink
from .meshcli import check_mesh_cli, resolve_mesh_cli
from .planner import (LaunchCandidate, check_launch, check_plan,
                      enumerate_configs, frontier, plan_frontier)
from .verify import verify_launch

__all__ = [
    "Diagnostic", "DiagnosticError", "LaunchCandidate", "RULES",
    "Report", "Severity", "check_launch", "check_mesh_cli", "check_plan",
    "check_restore_manifest", "check_shrink",
    "check_step_program", "enumerate_configs", "error",
    "estimate_block_costs", "estimate_collective_bytes", "frontier",
    "info", "kernel_footprint", "pipeline_bubble_fraction",
    "pipeline_peak_activation_bytes", "pipeline_peak_inflight",
    "plan_frontier", "resolve_mesh_cli", "roofline_terms",
    "verify_launch", "warning",
]
