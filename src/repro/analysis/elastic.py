"""Restore/elastic lint (rule family ``MK-R``).

The fault-tolerance layer gained two places where a wrong launch used
to fail deep inside jax with an unreadable traceback (or, worse,
silently replicate state):

- **restore**: a v2 checkpoint manifest records each leaf's global
  shape, dtype, `PartitionSpec`, and save-time mesh.  When the restored
  job's tree or mesh disagrees, `check_restore_manifest` says exactly
  which leaf and why (``MK-R001``) *before* any shard file is read —
  tree/shape mismatches are errors (the restore cannot produce the
  requested state), spec entries the new mesh cannot realize are
  warnings (the restore proceeds; those leaves land replicated unless
  explicit shardings resharded them);
- **elastic shrink**: on device loss the driver re-runs `plan_pipeline`
  on the surviving mesh.  `check_shrink` (``MK-R002``) guards the one
  arithmetic fact no re-plan can repair — every (virtual) stage still
  needs at least one repeat of the layer stack to hold — so a doomed
  shrink aborts with the surviving options named instead of a
  ValueError from the middle of the planner.

Like the rest of `repro.analysis`, this module is jax-free at import:
manifests are plain dicts, meshes arrive as ``{"axes": [...],
"shape": [...]}`` records, tree info as ``{key: shape}`` mappings.
"""
from __future__ import annotations

from typing import Any, Mapping

from .diagnostics import Diagnostic, error, warning


def manifest_error(loc: str, msg: str, hint: str = "") -> Diagnostic:
    """An MK-R001 error record (corrupt manifest / shard, tree
    mismatch) — the ckpt layer raises these as `DiagnosticError`."""
    return error("MK-R001", loc, msg, hint)


def _mesh_sizes(mesh: Mapping | None) -> dict[str, int]:
    if not mesh:
        return {}
    if "axes" in mesh:
        return {a: int(s) for a, s in zip(mesh["axes"], mesh["shape"])}
    return {a: int(s) for a, s in mesh.items()}


def check_restore_manifest(manifest: Mapping[str, Any],
                           like: Mapping[str, tuple] | None = None,
                           mesh: Mapping | None = None,
                           loc: str = "restore") -> list[Diagnostic]:
    """Lint a v2 checkpoint manifest against a restore target (MK-R001).

    `like` maps leaf key → expected global shape (the restored tree's
    structure); `mesh` is the restore mesh as ``{"axes", "shape"}`` (or
    ``{axis: size}``).  Errors: malformed/truncated manifest, missing or
    extra leaves, global-shape mismatches.  Warnings: recorded
    PartitionSpec entries the restore mesh cannot realize (axis absent
    or dim not divisible) — legal, but the leaf arrives replicated
    unless the caller passes shardings for the new mesh.
    """
    diags: list[Diagnostic] = []
    leaves = manifest.get("leaves")
    if not isinstance(leaves, list):
        diags.append(error(
            "MK-R001", loc,
            "manifest has no 'leaves' list — truncated or not a v2 "
            "checkpoint manifest",
            hint="v1 checkpoints carry a 'keys' list instead; pass the "
                 "directory through load_checkpoint, which dispatches "
                 "on the manifest version"))
        return diags
    by_key: dict[str, dict] = {}
    for rec in leaves:
        if not isinstance(rec, dict) or "key" not in rec \
                or "shape" not in rec or "shards" not in rec:
            diags.append(error(
                "MK-R001", loc,
                f"malformed leaf record {rec!r:.80}",
                hint="the manifest was corrupted — restore an older "
                     "checkpoint"))
            continue
        by_key[rec["key"]] = rec

    if like is not None:
        missing = sorted(set(like) - set(by_key))
        extra = sorted(set(by_key) - set(like))
        if missing:
            diags.append(error(
                "MK-R001", loc,
                f"checkpoint is missing {len(missing)} leaves the "
                f"restored tree expects (first: {missing[0]!r})",
                hint="the training state's pytree structure changed "
                     "since the save — restore with the saved "
                     "structure, or migrate the checkpoint"))
        if extra:
            diags.append(error(
                "MK-R001", loc,
                f"checkpoint carries {len(extra)} leaves the restored "
                f"tree does not expect (first: {extra[0]!r})",
                hint="restoring a larger state into a smaller tree "
                     "drops data — restore with the saved structure"))
        for key, shape in like.items():
            rec = by_key.get(key)
            if rec is None:
                continue
            if tuple(rec["shape"]) != tuple(shape):
                diags.append(error(
                    "MK-R001", f"{loc}:{key}",
                    f"global shape {tuple(rec['shape'])} in the "
                    f"manifest vs {tuple(shape)} in the restore tree",
                    hint="global shapes are mesh-independent — a "
                         "mismatch means a different model config, not "
                         "a different mesh; check arch/--smoke flags"))

    sizes = _mesh_sizes(mesh)
    if sizes:
        for key, rec in by_key.items():
            spec = rec.get("spec")
            if not spec:
                continue
            shape = tuple(rec.get("shape", ()))
            for d, entry in enumerate(spec):
                axes = ([entry] if isinstance(entry, str)
                        else list(entry or []))
                if not axes:
                    continue
                absent = [a for a in axes if a not in sizes]
                if absent:
                    diags.append(warning(
                        "MK-R001", f"{loc}:{key}",
                        f"saved spec names axis {absent[0]!r} which the "
                        f"restore mesh {sizes} does not have",
                        hint="legal — the leaf reassembles from its "
                             "shards and lands replicated; pass "
                             "shardings built for the new mesh "
                             "(sanitize_specs) to reshard it"))
                    continue
                n = 1
                for a in axes:
                    n *= sizes[a]
                if d < len(shape) and shape[d] % n:
                    diags.append(warning(
                        "MK-R001", f"{loc}:{key}",
                        f"saved spec shards dim {d} (size {shape[d]}) "
                        f"over {axes} = {n} shards, which does not "
                        f"divide on the restore mesh",
                        hint="the leaf restores replicated on this "
                             "mesh; shrink the axis or accept "
                             "replication"))
    return diags


def check_shrink(n_repeats: int, n_stages: int, virtual_stages: int = 1,
                 loc: str = "elastic-shrink") -> list[Diagnostic]:
    """MK-R002: can a shrunk stage axis still be re-planned?

    `plan_pipeline` accepts any ``virtual_stages * n_stages <=
    n_repeats`` (heterogeneous padded stacks relax divisibility), so the
    only unrecoverable shrink is one where a (virtual) stage would hold
    no repeats at all — or nothing survives.
    """
    diags: list[Diagnostic] = []
    S, v, R = int(n_stages), int(virtual_stages), int(n_repeats)
    if S < 1:
        diags.append(error(
            "MK-R002", loc,
            f"no stages survive the shrink (n_stages={S})",
            hint="nothing to re-plan onto — the job must abort and "
                 "restart from the latest checkpoint on new hardware"))
        return diags
    if v * S > R:
        hint = (f"lower --virtual-stages (v={v} needs v*stages <= "
                f"{R})" if v > 1 else
                "every stage needs at least one repeat; shrink cannot "
                "re-plan — restart on a mesh with a stage axis <= "
                f"{R}")
        diags.append(error(
            "MK-R002", loc,
            f"surviving stage axis needs virtual_stages*n_stages = "
            f"{v}*{S} = {v * S} <= n_repeats = {R}",
            hint=hint))
    return diags


__all__ = ["check_restore_manifest", "check_shrink", "manifest_error"]
