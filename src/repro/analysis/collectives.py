"""Collective-alignment verification (rule family ``MK-C``).

The deadlock class this guards against: inside a shard_map island every
member of a mesh axis must issue the *same* sequence of collectives over
that axis, or a psum blocks forever waiting for a peer that branched the
other way.  XLA cannot see this — `lax.cond` lowers both branches and
the mismatch only manifests at run time as a hang.

The checker is a small abstract interpreter over jaxprs.  Each value is
summarized by its *varying set*: the mesh axes along which the value may
differ between members.  ``axis_index(A)`` introduces {A}; reductions
over an axis (psum/pmax/all_gather/...) remove it; ``ppermute`` keeps
it; everything else unions its inputs.  A `lax.cond` whose branches
issue different per-axis collective sequences is then an error *only*
when the predicate's varying set contains that axis — members that agree
on the predicate take the same branch, so e.g. PR 5's masked stage scan
(predicate varies over ``stage`` only, branches disagree on ``model``
collectives never — identity vs body both psum over ``model``… and when
they genuinely differ over ``model`` the stage-uniform predicate keeps
it legal) passes clean while a data-dependent one-sided psum is flagged.

Entry points: `check_closed_jaxpr` for a traced function (axis sizes
from ``axis_env`` tracing or a mesh), `check_shard_map_islands` to walk
an outer jaxpr, find every shard_map island, seed varying sets from its
``in_names``, and verify each island body.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping

from .diagnostics import Diagnostic, error, warning

# collective primitive name → effect on the varying set of its output
# w.r.t. the named axes: "remove" (reduction / gather makes the value
# identical across the axis), "keep" (members still hold different
# values afterwards)
COLLECTIVE_PRIMS: dict[str, str] = {
    "psum": "remove",
    "pmax": "remove",
    "pmin": "remove",
    "all_gather": "remove",
    "ppermute": "keep",
    "pbroadcast": "keep",
    "all_to_all": "keep",
    "reduce_scatter": "keep",
    "psum_scatter": "keep",
}

_SUBJAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def _axis_names(eqn) -> tuple[str, ...]:
    """The named mesh axes a collective eqn operates over (positional
    integer axes from vmap-style code are ignored — they are not mesh
    axes and cannot deadlock)."""
    raw = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(raw, (tuple, list)):
        raw = (raw,)
    return tuple(a for a in raw if isinstance(a, str))


def _as_open(jaxpr):
    """Sub-jaxpr params hold either open Jaxprs or ClosedJaxprs."""
    return jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr


@dataclasses.dataclass
class _Ctx:
    mesh_axes: Mapping[str, int]     # axis name → size
    loc: str
    diags: list[Diagnostic]
    emit: bool = True                # False during fixpoint warm-up

    def add(self, d: Diagnostic) -> None:
        if self.emit:
            self.diags.append(d)


class _Env:
    """Var → varying set; literals never vary."""

    def __init__(self) -> None:
        self._m: dict[Any, frozenset[str]] = {}

    def read(self, atom) -> frozenset[str]:
        if hasattr(atom, "val"):       # Literal
            return frozenset()
        return self._m.get(atom, frozenset())

    def write(self, var, v: frozenset[str]) -> None:
        if not hasattr(var, "val"):    # skip DropVar-safe? DropVar is a Var
            self._m[var] = v


def _check_perm(eqn, axes: tuple[str, ...], ctx: _Ctx) -> None:
    perm = tuple((int(s), int(d)) for s, d in eqn.params.get("perm", ()))
    for axis in axes:
        size = ctx.mesh_axes.get(axis)
        if size is None:
            continue                   # MK-C001 already reported
        loc = f"{ctx.loc}: ppermute over {axis!r}"
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        bad = False
        if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
            ctx.add(error(
                "MK-C003", loc,
                f"perm {perm} repeats a source or destination — each "
                "member may send and receive at most once"))
            bad = True
        out_of_range = [i for i in srcs + dsts if not 0 <= i < size]
        if out_of_range:
            ctx.add(error(
                "MK-C003", loc,
                f"perm {perm} references indices {sorted(set(out_of_range))} "
                f"outside the axis (size {size})"))
            bad = True
        if not bad and (set(srcs) != set(range(size))
                        or set(dsts) != set(range(size))):
            missing = sorted(set(range(size)) - set(srcs)
                             | set(range(size)) - set(dsts))
            ctx.add(error(
                "MK-C003", loc,
                f"perm {perm} is not a complete permutation of the axis "
                f"(size {size}): members {missing} are dropped and would "
                "receive zeros / send into nothing",
                "pipeline rings must rotate every member: "
                "perm=[(i, (i+1) % size) for i in range(size)]"))
            bad = True
        if not bad and axis == "stage":
            shifts = {(d - s) % size for s, d in perm}
            if len(shifts) != 1:
                ctx.add(warning(
                    "MK-C004", loc,
                    f"stage-axis perm {perm} is a permutation but not a "
                    "uniform ring shift — the pipeline executors assume "
                    "neighbor transfers",
                    "expected a rotation like "
                    "[(i, (i+1) % size) for i in range(size)]"))


def _interp(jaxpr, in_varying: Iterable[frozenset[str]], ctx: _Ctx,
            ) -> tuple[list[frozenset[str]], list[tuple[str, str]]]:
    """Abstract-interpret an *open* jaxpr.

    Returns (per-output varying sets, collective event sequence) where
    each event is ``(axis, primitive_name)`` in program order — the
    per-axis subsequences are what cond branches must agree on.
    """
    env = _Env()
    for var in jaxpr.constvars:
        env.write(var, frozenset())
    for var, v in zip(jaxpr.invars, in_varying):
        env.write(var, v)
    events: list[tuple[str, str]] = []

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        in_v = [env.read(a) for a in eqn.invars]
        joined = frozenset().union(*in_v) if in_v else frozenset()

        if name == "axis_index":
            axis = eqn.params.get("axis_name")
            axes = axis if isinstance(axis, (tuple, list)) else (axis,)
            axes = tuple(a for a in axes if isinstance(a, str))
            for a in axes:
                if a not in ctx.mesh_axes:
                    ctx.add(error(
                        "MK-C001", ctx.loc,
                        f"axis_index({a!r}) but the mesh axes are "
                        f"{tuple(ctx.mesh_axes)}"))
            env.write(eqn.outvars[0], joined | frozenset(
                a for a in axes if a in ctx.mesh_axes))

        elif name in COLLECTIVE_PRIMS:
            axes = _axis_names(eqn)
            for a in axes:
                if a not in ctx.mesh_axes:
                    ctx.add(error(
                        "MK-C001", ctx.loc,
                        f"{name} over axis {a!r} but the mesh axes are "
                        f"{tuple(ctx.mesh_axes)}",
                        "collectives over a nonexistent axis fail at "
                        "lowering or, under axis_env tracing, at run "
                        "time"))
            if name == "ppermute":
                _check_perm(eqn, axes, ctx)
            events.extend((a, name) for a in axes)
            out_v = joined
            if COLLECTIVE_PRIMS[name] == "remove":
                out_v = joined - frozenset(axes)
            for var in eqn.outvars:
                env.write(var, out_v)

        elif name == "cond":
            pred_v = in_v[0]
            branches = [_as_open(b) for b in eqn.params["branches"]]
            branch_out: list[list[frozenset[str]]] = []
            branch_seq: list[list[tuple[str, str]]] = []
            for b in branches:
                o, s = _interp(b, in_v[1:], ctx)
                branch_out.append(o)
                branch_seq.append(s)
            axes_seen = {a for s in branch_seq for a, _ in s}
            for axis in sorted(axes_seen):
                per = [tuple(p for ax, p in s if ax == axis)
                       for s in branch_seq]
                if len(set(per)) > 1 and axis in pred_v:
                    shapes = ", ".join(
                        f"branch {i}: [{' '.join(p) or 'none'}]"
                        for i, p in enumerate(per))
                    ctx.add(error(
                        "MK-C002", ctx.loc,
                        f"cond predicate may vary over axis {axis!r} but "
                        f"its branches issue different collective "
                        f"sequences over it ({shapes}) — members taking "
                        "different branches would deadlock",
                        "hoist the collective out of the cond, or make "
                        "every branch issue the same collectives (the "
                        "masked-stage pattern: identity branch still "
                        "psums a zero)"))
            for s in branch_seq:
                events.extend(s)
            for i, var in enumerate(eqn.outvars):
                v = frozenset().union(*(o[i] for o in branch_out))
                env.write(var, v | pred_v)

        elif name == "scan":
            body = _as_open(eqn.params["jaxpr"])
            nc = eqn.params["num_consts"]
            ncar = eqn.params["num_carry"]
            const_v, carry_v = in_v[:nc], list(in_v[nc:nc + ncar])
            xs_v = in_v[nc + ncar:]
            # fixpoint on the carry varying sets, then one emitting pass
            sub = dataclasses.replace(ctx, emit=False)
            for _ in range(len(ctx.mesh_axes) + 2):
                out_v, _ = _interp(body, const_v + carry_v + xs_v, sub)
                new_carry = [carry_v[i] | out_v[i] for i in range(ncar)]
                if new_carry == carry_v:
                    break
                carry_v = new_carry
            out_v, seq = _interp(body, const_v + carry_v + xs_v, ctx)
            events.extend(seq)
            for i, var in enumerate(eqn.outvars):
                env.write(var, out_v[i] if i < len(out_v) else joined)

        elif name == "while":
            cond_j = _as_open(eqn.params["cond_jaxpr"])
            body_j = _as_open(eqn.params["body_jaxpr"])
            cn = eqn.params["cond_nconsts"]
            bn = eqn.params["body_nconsts"]
            cconst_v = in_v[:cn]
            bconst_v = in_v[cn:cn + bn]
            carry_v = list(in_v[cn + bn:])
            sub = dataclasses.replace(ctx, emit=False)
            for _ in range(len(ctx.mesh_axes) + 2):
                out_v, _ = _interp(body_j, bconst_v + carry_v, sub)
                new_carry = [carry_v[i] | out_v[i]
                             for i in range(len(carry_v))]
                if new_carry == carry_v:
                    break
                carry_v = new_carry
            pred_v, _ = _interp(cond_j, cconst_v + carry_v, sub)
            trip_v = pred_v[0] if pred_v else frozenset()
            out_v, seq = _interp(body_j, bconst_v + carry_v, ctx)
            events.extend(seq)
            flagged: set[str] = set()
            for axis, prim in seq:
                if axis in trip_v and axis not in flagged:
                    flagged.add(axis)
                    ctx.add(error(
                        "MK-C005", ctx.loc,
                        f"{prim} over axis {axis!r} inside a while loop "
                        "whose trip count may vary over that axis — "
                        "members running extra iterations issue extra "
                        "collectives and deadlock",
                        "make the trip count axis-uniform (pmax the "
                        "bound) or run a fixed count with a mask"))
            for i, var in enumerate(eqn.outvars):
                v = out_v[i] if i < len(out_v) else joined
                env.write(var, v | trip_v)

        elif name == "shard_map":
            inner = _as_open(eqn.params["jaxpr"])
            in_names = eqn.params.get("in_names", ())
            inner_v = []
            for i, v in enumerate(in_v):
                names = in_names[i] if i < len(in_names) else {}
                axes = frozenset(
                    a for dim_axes in names.values() for a in dim_axes)
                inner_v.append(v | axes)
            out_v, seq = _interp(inner, inner_v, ctx)
            events.extend(seq)
            for i, var in enumerate(eqn.outvars):
                env.write(var, out_v[i] if i < len(out_v) else joined)

        else:
            sub = None
            for key in _SUBJAXPR_KEYS:
                if key in eqn.params:
                    sub = _as_open(eqn.params[key])
                    break
            if sub is not None:
                n = len(sub.invars)
                if len(in_v) >= n:
                    sub_in = in_v[len(in_v) - n:]
                else:
                    sub_in = [joined] * n
                out_v, seq = _interp(sub, sub_in, ctx)
                events.extend(seq)
                for i, var in enumerate(eqn.outvars):
                    env.write(var,
                              out_v[i] if i < len(out_v) else joined)
            else:
                for var in eqn.outvars:
                    env.write(var, joined)

    return [env.read(v) for v in jaxpr.outvars], events


def check_closed_jaxpr(closed, mesh_axes: Mapping[str, int],
                       in_varying: Iterable[frozenset[str]] | None = None,
                       loc: str = "jaxpr") -> list[Diagnostic]:
    """Verify collective alignment of a traced function.

    `closed` is a ClosedJaxpr (e.g. from ``jax.make_jaxpr(f,
    axis_env=[...])``); `mesh_axes` maps axis name → size.  `in_varying`
    seeds the inputs' varying sets (default: nothing varies — inputs are
    replicated, so only ``axis_index`` introduces variance, which is the
    right model for shard_map islands over replicated-in operands)."""
    jaxpr = _as_open(closed)
    if in_varying is None:
        in_varying = [frozenset()] * len(jaxpr.invars)
    ctx = _Ctx(mesh_axes=dict(mesh_axes), loc=loc, diags=[])
    _interp(jaxpr, list(in_varying), ctx)
    return ctx.diags


def iter_shard_map_eqns(jaxpr):
    """Yield every shard_map eqn reachable from an open jaxpr."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "shard_map":
            # the island check interprets its interior inline (including
            # nested islands) — descending here would double-report
            yield eqn
            continue
        for key in ("branches",):
            for b in eqn.params.get(key, ()):
                yield from iter_shard_map_eqns(_as_open(b))
        for key in (*_SUBJAXPR_KEYS, "cond_jaxpr", "body_jaxpr"):
            if key in eqn.params:
                yield from iter_shard_map_eqns(_as_open(eqn.params[key]))


def check_shard_map_islands(closed, mesh_axes: Mapping[str, int],
                            loc: str = "launch") -> list[Diagnostic]:
    """Find every shard_map island under a traced computation and verify
    each body, seeding input varying sets from the island's ``in_names``
    (an operand sharded over an axis varies over it inside the island)."""
    diags: list[Diagnostic] = []
    jaxpr = _as_open(closed)
    for n, eqn in enumerate(iter_shard_map_eqns(jaxpr)):
        inner = _as_open(eqn.params["jaxpr"])
        in_names = eqn.params.get("in_names", ())
        in_varying = []
        for i in range(len(inner.invars)):
            names = in_names[i] if i < len(in_names) else {}
            in_varying.append(frozenset(
                a for dim_axes in names.values() for a in dim_axes))
        ctx = _Ctx(mesh_axes=dict(mesh_axes),
                   loc=f"{loc}: shard_map island #{n}", diags=diags)
        _interp(inner, in_varying, ctx)
    return diags


__all__ = ["COLLECTIVE_PRIMS", "check_closed_jaxpr",
           "check_shard_map_islands", "iter_shard_map_eqns"]
