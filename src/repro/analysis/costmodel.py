"""The unified analytic cost-model API (mkplan's pricing layer).

Every static model the launch stack uses to price a configuration lives
here, behind one typed surface — the MKPipe move of scoring the whole
tradeoff space from static estimates before anything compiles:

- **roofline**: the hardware constants (`PEAK_FLOPS`, `HBM_BW`,
  `ICI_BW`) and `roofline_terms` — FLOPs/bytes/collective-bytes folded
  into per-term seconds (`RooflineTerms`).  Mirrors
  `repro.core.resources.ChipSpec`; a parity test pins them equal.
- **schedule models**: `SCHEDULES`, the `PIPE_*` op codes,
  `pipeline_bubble_fraction` (gpipe/1f1b/interleaved-v, uniform and
  heterogeneous), `pipeline_peak_inflight` /
  `pipeline_peak_activation_bytes`, and the step-program stash
  simulator `program_peak_inflight`.  (Moved from
  `repro.dist.pipeline`, which re-exports them — this module is the
  canonical home so `repro.analysis` stays jax-free at import.)
- **block pricing**: `analytic_block_cost` (6·N·tokens at roofline
  peak) and `estimate_block_costs` (XLA cost-analysis probe, tp-aware)
  — what `plan_pipeline` feeds `balance_stages`.
- **collectives**: `estimate_collective_bytes` (analytic per-axis
  bytes: stage ppermute, model psum, data grad all-reduce) and
  `measured_collective_bytes` (the `launch.hloanalysis` per-axis
  attribution of compiled HLO, wrapped).
- **kernel footprints**: `kernel_footprint` — block geometry →
  bytes-touched / VMEM estimate for one Pallas kernel call, recorded
  through `analysis.kernels.record_pallas_calls` without lowering;
  forward and backward priced separately through the tuner's
  phase-keyed cache.

Import layering: this module imports nothing from the rest of the repo
at module level (numpy + stdlib only) — jax, the model configs, the
kernels and the HLO parser are imported lazily inside the functions
that need them.  Formula derivations: docs/cost-models.md.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Sequence

import numpy as np

log = logging.getLogger("repro.costmodel")

# TPU v5e-like roofline constants (per chip) — the single source for the
# launch stack (train/dist/launch import these); they mirror
# `repro.core.resources.ChipSpec` (the paper-side resource model) and a
# parity test pins the two equal.
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link
VMEM_BYTES = 128 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """The three roofline terms of one step, in seconds."""
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bottleneck(self) -> str:
        terms = self.as_dict()
        return max(terms, key=terms.get)

    def as_dict(self) -> dict[str, float]:
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}


def roofline_terms(flops: float, hbm_bytes: float,
                   collective_bytes: float) -> RooflineTerms:
    """Fold per-device FLOPs / HBM bytes / collective bytes through the
    roofline constants into per-term seconds."""
    return RooflineTerms(compute_s=flops / PEAK_FLOPS,
                         memory_s=hbm_bytes / HBM_BW,
                         collective_s=collective_bytes / ICI_BW)


# ------------------------------------------------------ schedule models
# One pipeline tick = one stage executing one micro-step; the op codes
# are the step programs' vocabulary (see repro.dist.pipeline, which
# builds and executes the programs — this module only prices them).
SCHEDULES = ("gpipe", "1f1b", "interleaved")
PIPE_IDLE, PIPE_FWD, PIPE_BWD = 0, 1, 2


def _check_virtual_stages(schedule: str, virtual_stages: int) -> int:
    v = int(virtual_stages)
    if v < 1:
        raise ValueError(f"need virtual_stages >= 1, got {virtual_stages}")
    if v != 1 and schedule != "interleaved":
        raise ValueError(
            f"virtual_stages={v} requires schedule='interleaved', got "
            f"{schedule!r}")
    return v


def pipeline_bubble_fraction(n_micro: int, n_stages: int,
                             stage_times: Sequence[float] | None = None,
                             virtual_stages: int = 1) -> float:
    """Analytic fill/drain bubble fraction of device-time idle.

    Uniform stages (``stage_times=None``): (S-1) / (M + S-1) — with M
    microbatches over S equal stages, either step program spans
    2·(M + S - 1) ticks of which 2·M per stage are useful.  The formula
    holds for *both* flat schedules (GPipe and 1F1B): they differ in
    *peak activation memory* (`pipeline_peak_inflight`), not in bubble.

    ``virtual_stages=v > 1`` models the interleaved-1F1B schedule: each
    device holds v non-contiguous chunks of the layer stack (virtual
    stage q = c·S + s lives on device s), so one "microbatch unit" of
    per-device work shrinks to 1/v of a flat stage pass while the fill
    ramp still crosses only S devices — the uniform bubble drops to
    **(S-1) / (v·M + S-1)**.

    Heterogeneous stages (``stage_times=[t_0, .., t_{S-1}]``, or one
    entry per *virtual* stage — v·S of them — when ``virtual_stages=v``):
    the pipeline period is set by the bottleneck device, whose
    per-microbatch time is ``D_s = Σ_c t_{c·S+s}`` summed over its
    chunks.  The span is ``(vM−1)·max_s D_s/v + Σ_s D_s/v`` (fill
    through every device once at chunk granularity, then vM−1 bottleneck
    chunk periods) and the useful device-time is ``M·Σ_s D_s``:

        bubble = 1 − vM·Σ D_s / (S·((vM−1)·max D + Σ D))

    which collapses to the uniform interleaved closed form when all
    chunks cost the same, and to the flat heterogeneous form
    ``1 − M·Σ t_s / (S·((M−1)·max t + Σ t))`` at v=1.  Heterogeneous
    plans must price their bubble at least this way — the uniform
    formula is optimistic whenever one device is slower than the rest.
    Note the span models *asynchronous* stage starts (a stage forwards
    as soon as its input arrives); `pipeline_apply_microbatched`
    advances stages in lockstep through a per-tick ring ppermute, so its
    realized span is the still-larger ``(M+S−1)·max_s t_s`` — this
    overload is the schedule-independent lower-bound model, the lockstep
    penalty on top of it is the same fill/drain geometry the uniform
    measured-vs-analytic comparison already carries.
    """
    if n_micro < 1 or n_stages < 1:
        raise ValueError("need n_micro >= 1 and n_stages >= 1")
    v = int(virtual_stages)
    if v < 1:
        raise ValueError(f"need virtual_stages >= 1, got {virtual_stages}")
    if stage_times is None:
        return (n_stages - 1) / (v * n_micro + n_stages - 1)
    ts = [float(t) for t in stage_times]
    if len(ts) != v * n_stages:
        raise ValueError(
            f"got {len(ts)} stage_times for n_stages={n_stages} × "
            f"virtual_stages={v} (want one per virtual stage)")
    if any(t < 0.0 for t in ts) or max(ts, default=0.0) <= 0.0:
        raise ValueError(f"stage_times must be >= 0 with a positive "
                         f"bottleneck, got {ts}")
    # per-device time across its chunks: virtual stage q = c·S + s
    dev = [sum(ts[c * n_stages + s] for c in range(v))
           for s in range(n_stages)]
    total = sum(dev)
    span = (v * n_micro - 1) * max(dev) + total
    return 1.0 - (v * n_micro * total) / (n_stages * span)


def pipeline_peak_inflight(n_micro: int, n_stages: int,
                           schedule: str = "gpipe",
                           virtual_stages: int = 1) -> int:
    """Peak in-flight micro-step activations a device must stash.

    A device holds one stashed activation per (chunk, microbatch) whose
    forward it has run (or received) but whose backward it has not yet
    retired:

    - ``"gpipe"``: every forward completes before any backward starts, so
      the stash peaks at **M** on every stage;
    - ``"1f1b"``: stage s starts draining after min(M, S-s) warmup
      forwards and then strictly alternates forward/backward, bounding its
      stash at min(M, S-s) — **min(M, S)** in the worst case (stage 0),
      independent of the microbatch count;
    - ``"interleaved"`` with v chunks per device: the steady state holds
      up to v chunk activations of up to S microbatches plus the S-1
      transfers in flight across the chunk boundary, and the microbatch
      next in line to retire may keep up to v more chunks stashed while
      its backward diagonal waits for a free slot — bounding the stash
      at **min(v·M, v·S + S - 1 + v)**.  v=1 degenerates to the exact
      1f1b bound min(M, S).

    Returns the worst-case device's count; multiply by the
    per-micro-step activation bytes for a peak-memory estimate
    (`pipeline_peak_activation_bytes`).
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; want {SCHEDULES}")
    if n_micro < 1 or n_stages < 1:
        raise ValueError("need n_micro >= 1 and n_stages >= 1")
    v = _check_virtual_stages(schedule, virtual_stages)
    if schedule == "gpipe":
        return n_micro
    if schedule == "interleaved" and v > 1:
        return min(v * n_micro, v * n_stages + n_stages - 1 + v)
    return min(n_micro, n_stages)


def pipeline_peak_activation_bytes(n_micro: int, n_stages: int,
                                   schedule: str,
                                   microbatch_bytes: float,
                                   virtual_stages: int = 1) -> float:
    """Analytic peak activation-stash bytes per stage device:
    `pipeline_peak_inflight` × the per-microbatch activation size (the
    bytes of one microbatch's stage-boundary activations, e.g.
    mb · seq · d_model · itemsize for the residual stream)."""
    return pipeline_peak_inflight(n_micro, n_stages, schedule,
                                  virtual_stages=virtual_stages) \
        * float(microbatch_bytes)


def _program_books(prog, n_stages: int):
    """(f_tick, b_tick) keyed by (virtual stage q, microbatch): q = s for
    flat (op, m) entries, q = c·n_stages + s for chunked (op, m, c)."""
    f_tick: dict = {}
    b_tick: dict = {}
    for t, row in enumerate(prog):
        for s, entry in enumerate(row):
            op, m = entry[0], entry[1]
            q = (entry[2] * n_stages + s) if len(entry) > 2 else s
            if op == PIPE_FWD:
                f_tick[(q, m)] = t
            elif op == PIPE_BWD:
                b_tick[(q, m)] = t
    return f_tick, b_tick


def program_peak_inflight(prog, n_stages: int) -> int:
    """Peak live stash occupancy over all devices of a step program.

    An entry (q, m) becomes live on device q mod S when its stash slot
    is written — at F(q, m) for the injecting virtual stage 0, at
    F(q-1, m) + 1 otherwise (ppermute arrival) — and is retired by
    B(q, m).

    Flat (op, m) programs report the peak slot *span*
    max(live) - min(live) + 1: their executors key slots by ``m % K``,
    and collisions are impossible iff K ≥ that span (for the programs
    built here it equals `pipeline_peak_inflight`).  Chunked (op, m, c)
    interleaved programs report the peak live *count*: their executor
    allocates slots from a per-device free list replayed off the
    program, so the count is exactly the slots it needs.
    """
    chunked = any(len(entry) > 2
                  for row in prog for entry in row
                  if entry[0] != PIPE_IDLE)
    f_tick, b_tick = _program_books(prog, n_stages)
    peak = 0
    for s in range(n_stages):
        events = []       # (tick, +1 push (q, m) / -1 pop (q, m))
        for (q, m), t in f_tick.items():
            if (q + 1) % n_stages == s and ((q + 1, m) in f_tick
                                            or (q + 1, m) in b_tick):
                events.append((t + 1, 1, (q + 1, m)))
            if q == 0 and s == 0:
                events.append((t, 1, (q, m)))
        for (q, m), t in b_tick.items():
            if q % n_stages == s:
                events.append((t, -1, (q, m)))
        live: set = set()
        # pushes (arrivals) land before the tick's pop (the executors
        # apply ppermute arrivals first, then run the event)
        for t, kind, qm in sorted(events, key=lambda e: (e[0], -e[1])):
            if kind == 1:
                live.add(qm)
                if live:
                    if chunked:
                        peak = max(peak, len(live))
                    else:
                        ms = [m for _, m in live]
                        peak = max(peak, max(ms) - min(ms) + 1)
            else:
                live.discard(qm)
    return peak


# --------------------------------------------------------- block pricing
def analytic_block_cost(cfg, pos: int, tokens: int) -> float:
    """Fallback cost: 6·N_block·tokens FLOPs at roofline peak."""
    from repro.models.common import LayerKind

    spec = cfg.pattern[pos]
    d = cfg.d_model
    n = 0.0
    if spec.kind in (LayerKind.ATTN, LayerKind.SWA):
        n += d * (cfg.num_heads * cfg.head_dim) * 2
        n += d * (cfg.num_kv_heads * cfg.head_dim) * 2
    else:
        di = cfg.d_inner
        n += d * (2 * di + 2 * cfg.ssm_heads * cfg.ssm_state
                  + cfg.ssm_heads) + di * d
    if spec.ffn:
        if spec.moe:
            n += 3 * d * cfg.moe_d_ff * max(cfg.experts_per_tok, 1)
        else:
            n += (3 if cfg.act == "silu" else 2) * d * cfg.d_ff
    return 6.0 * n * tokens / PEAK_FLOPS


def estimate_block_costs(cfg, batch: int, seq: int,
                         tp: int = 1) -> list[float]:
    """Per-pattern-position cost (seconds) of one block's forward at
    (batch, seq): XLA cost analysis of the lowered block (the stage
    profiler's FLOP/byte estimates) folded through the roofline,
    falling back to the analytic 6·N·D estimate when compilation of the
    probe is unavailable.

    `tp` prices *per-model-shard* work: the probe lowers the full block
    and the roofline time divides by `tp`, since every sharded tensor
    (heads, d_ff, d_inner, experts) splits its FLOPs and bytes evenly
    over the model axis — so `balance_stages` partitions stages by the
    work one device actually runs, not the unsharded block.  (The
    replicated residue — norms, routers — is negligible at roofline
    granularity; a uniform divisor also leaves the *relative* costs, and
    hence the partition, of homogeneous stacks unchanged.)"""
    import functools

    import jax
    import jax.numpy as jnp

    from repro.models.transformer import _apply_block, _init_block

    if tp < 1:
        raise ValueError(f"need tp >= 1, got {tp}")
    costs = []
    x_sds = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    for pos, spec in enumerate(cfg.pattern):
        try:
            p_abs = jax.eval_shape(
                functools.partial(_init_block, cfg=cfg, spec=spec), key_sds)
            fn = lambda p, x, _s=spec: _apply_block(p, _s, cfg, x)[0]
            compiled = jax.jit(fn).lower(p_abs, x_sds).compile()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # jax<=0.4 returns [dict]
                ca = ca[0] if ca else {}
            flops = float(ca.get("flops", 0.0))
            bts = float(ca.get("bytes accessed", 0.0))
            cost = max(flops / PEAK_FLOPS, bts / HBM_BW)
            if cost <= 0.0:
                raise ValueError("empty cost analysis")
        except Exception as exc:               # pragma: no cover - fallback
            log.debug("block cost probe failed at pos %d (%s); "
                      "using analytic estimate", pos, exc)
            cost = analytic_block_cost(cfg, pos, batch * seq)
        costs.append(cost / tp)
    return costs


def microbatch_bytes(cfg, n_micro: int, *, global_batch: int,
                     seq_len: int, dp: int = 1) -> float:
    """One microbatch's stage-boundary activation bytes (the residual
    stream): (global_batch/dp/n_micro) · seq · d_model · itemsize."""
    mb = max(global_batch // max(dp, 1) // max(n_micro, 1), 1)
    return float(mb * seq_len * cfg.d_model
                 * np.dtype(cfg.dtype).itemsize)


def model_state_bytes(cfg, n_stages: int = 1, tp: int = 1) -> float:
    """Per-device model-state bytes: params + grads + two Adam moments
    (4× the parameter bytes), split over the stage and model axes.  A
    coarse residency model — embeddings are counted as split although
    some executors replicate them — used for relative peak-memory
    pricing, not allocator-exact accounting."""
    itemsize = np.dtype(cfg.dtype).itemsize
    return 4.0 * cfg.n_params() * itemsize / (max(n_stages, 1)
                                              * max(tp, 1))


# ------------------------------------------------------------ collectives
def estimate_collective_bytes(cfg, *, n_stages: int, n_micro: int,
                              virtual_stages: int = 1, tp: int = 1,
                              dp: int = 1, global_batch: int,
                              seq_len: int) -> dict[str, float]:
    """Analytic per-device collective bytes by mesh axis, per step.

    A ranking model, deliberately coarse (docs/cost-models.md):

    - ``"stage"``: the schedule's ring ppermute — each device sends one
      microbatch activation per pipeline tick, forward and backward,
      across each of its v chunks: ``2 · v · M · mb_bytes``;
    - ``"model"``: the row-parallel psums inside the blocks — per
      microbatch, each local block psums its mixer output and (when
      present) its FFN output over the model axis, forward + backward
      (cotangents transpose to the same psums), at the ring all-reduce
      cost 2·(tp−1)/tp per psum'd activation;
    - ``"data"``: the gradient all-reduce, once per step:
      2·(dp−1)/dp × the per-device parameter bytes.
    """
    mb = microbatch_bytes(cfg, n_micro, global_batch=global_batch,
                          seq_len=seq_len, dp=dp)
    v = max(int(virtual_stages), 1)
    out = {"stage": 0.0, "model": 0.0, "data": 0.0}
    if n_stages > 1:
        out["stage"] = 2.0 * v * n_micro * mb
    if tp > 1:
        psums_per_block = [1 + (1 if spec.ffn else 0)
                           for spec in cfg.pattern]
        local_psums = (cfg.n_repeats * sum(psums_per_block)
                       / max(n_stages, 1))
        out["model"] = (2.0 * (tp - 1) / tp * mb
                        * 2.0 * n_micro * local_psums)
    if dp > 1:
        param_bytes = (cfg.n_params()
                       * np.dtype(cfg.dtype).itemsize
                       / (max(n_stages, 1) * max(tp, 1)))
        out["data"] = 2.0 * (dp - 1) / dp * param_bytes
    return out


@dataclasses.dataclass(frozen=True)
class CollectiveBytes:
    """Measured per-device collective traffic of one compiled program."""
    total: float
    by_axis: dict[str, dict[str, float]]
    by_op: dict[str, float]


def measured_collective_bytes(hlo_text: str, mesh=None,
                              axis_groups=None) -> CollectiveBytes:
    """Per-axis collective-bytes attribution of compiled (SPMD) HLO —
    the `launch.hloanalysis` loop-aware parse, behind the typed API.
    Pass either a concrete `mesh` (axis groups are derived) or
    precomputed ``axis_groups``."""
    from repro.launch.hloanalysis import analyze_hlo, mesh_axis_groups

    if axis_groups is None and mesh is not None:
        axis_groups = mesh_axis_groups(mesh)
    hlo = analyze_hlo(hlo_text, axis_groups=axis_groups)
    return CollectiveBytes(total=hlo.collective_bytes,
                           by_axis=hlo.coll_bytes_by_axis,
                           by_op=hlo.coll_bytes_by_op)


# -------------------------------------------------------- kernel footprint
@dataclasses.dataclass(frozen=True)
class KernelFootprint:
    """Static block-geometry footprint of one Pallas kernel call.

    ``bytes_touched`` counts the HBM bytes moved across all grid steps
    (every grid point reads its input blocks and writes its output
    blocks — re-reads of the same block on different grid points count
    each time, which is exactly the streamed traffic a non-revisiting
    kernel pays); ``vmem_bytes`` is the per-grid-step resident block
    bytes (one block per operand and output), the VMEM working set the
    block config commits to.  ``approximate`` marks phases priced
    without a recorded pallas_call (the chunked flash backward, the
    unfused ref VJPs).
    """
    kernel: str
    phase: str
    config: tuple[tuple[str, int], ...]
    grid: tuple[int, ...]
    bytes_touched: float
    vmem_bytes: float
    n_calls: int
    approximate: bool = False


def resolve_block_config(kernel: str, shape: Sequence[int],
                         dtype: str = "float32", *, phase: str = "fwd",
                         tp: int = 1,
                         cache_path: str | None = None) -> dict[str, int]:
    """The block config `kernels.dispatch` would run this call with:
    tuned-cache entry (phase-keyed) → kernel defaults (backward falls
    back to the forward blocks when no backward entry was tuned), then
    clamped to the largest divisor of each blocked dim."""
    from repro.kernels.dispatch import _DEFAULTS
    from repro.kernels.tune import PARAM_DIMS, _divisor, cached_config

    shape = tuple(int(s) for s in shape)
    cfg = dict(_DEFAULTS.get(kernel, {}))
    cfg.update(cached_config(kernel, shape, dtype, tp=tp,
                             path=cache_path))
    if phase == "bwd":
        cfg.update(cached_config(kernel, shape, dtype, tp=tp,
                                 phase="bwd", path=cache_path))
    for param, axis in PARAM_DIMS.get(kernel, {}).items():
        if param in cfg:
            cfg[param] = _divisor(shape[axis], cfg[param])
    return cfg


def _spec_block_bytes(spec, shape: Sequence[int], itemsize: int) -> float:
    block = getattr(spec, "block_shape", None) if spec is not None else None
    if block is None:       # unblocked operand: the whole array per step
        n = 1
        for d in shape:
            n *= int(d)
        return float(n * itemsize)
    n = 1
    for bs in block:
        n *= int(bs) if bs else 1
    return float(n * itemsize)


def kernel_footprint(kernel: str, shape: Sequence[int],
                     dtype: str = "float32", *, phase: str = "fwd",
                     config: dict[str, int] | None = None, tp: int = 1,
                     cache_path: str | None = None) -> KernelFootprint:
    """Record one kernel builder under `record_pallas_calls` and derive
    its static footprint — nothing lowers, nothing allocates on device.

    ``phase="bwd"`` prices the backward with the backward-phase block
    config (the tuner caches it separately; see `repro.kernels.tune`):
    flash attention's backward is the memory-linear chunked recompute —
    modeled as 2× the forward's streamed traffic (recompute reads plus
    dq/dk/dv writes) at the backward chunk geometry — and the other
    kernels' backwards are the unfused ref VJPs, priced as whole-operand
    reads and gradient writes with no VMEM blocking.
    """
    from repro.analysis.kernels import PallasCallRecord, record_pallas_calls
    from repro.kernels.tune import PARAM_DIMS, _builder

    shape = tuple(int(s) for s in shape)
    if kernel not in PARAM_DIMS:
        raise ValueError(f"unknown tunable kernel {kernel!r}; "
                         f"tunable: {tuple(PARAM_DIMS)}")
    if config is None:
        config = resolve_block_config(kernel, shape, dtype, phase=phase,
                                      tp=tp, cache_path=cache_path)
    itemsize = int(np.dtype(dtype).itemsize)

    records: list[PallasCallRecord] = []
    with record_pallas_calls(records, name=kernel):
        _builder(kernel, shape, config)()
    grid: tuple[int, ...] = ()
    touched = 0.0
    vmem = 0.0
    for rec in records:
        pts = 1
        for g in rec.grid:
            pts *= int(g)
        grid = rec.grid
        for spec, shp in list(zip(rec.in_specs, rec.operand_shapes)) \
                + list(zip(rec.out_specs, rec.out_shapes)):
            bb = _spec_block_bytes(spec, shp, itemsize)
            touched += pts * bb
            vmem += bb

    if phase == "fwd":
        return KernelFootprint(
            kernel=kernel, phase=phase,
            config=tuple(sorted(config.items())), grid=grid,
            bytes_touched=touched, vmem_bytes=vmem, n_calls=len(records))
    if kernel == "flash_attention":
        # chunked recompute backward: same streamed geometry as the
        # forward (at the backward chunk sizes already in `config`),
        # twice — recompute reads + dq/dk/dv writes
        return KernelFootprint(
            kernel=kernel, phase=phase,
            config=tuple(sorted(config.items())), grid=grid,
            bytes_touched=2.0 * touched, vmem_bytes=vmem,
            n_calls=len(records), approximate=True)
    # ref-VJP backward: unfused whole-array traffic, no blocking
    whole = 0.0
    for rec in records:
        for shp in list(rec.operand_shapes) + list(rec.out_shapes):
            n = 1
            for d in shp:
                n *= int(d)
            whole += n * itemsize
    return KernelFootprint(
        kernel=kernel, phase=phase,
        config=tuple(sorted(config.items())), grid=(),
        bytes_touched=2.0 * whole, vmem_bytes=0.0,
        n_calls=len(records), approximate=True)


__all__ = [
    "CollectiveBytes", "HBM_BW", "ICI_BW", "KernelFootprint",
    "PEAK_FLOPS", "PIPE_BWD", "PIPE_FWD", "PIPE_IDLE", "RooflineTerms",
    "SCHEDULES", "VMEM_BYTES", "analytic_block_cost",
    "estimate_block_costs", "estimate_collective_bytes",
    "kernel_footprint", "measured_collective_bytes", "microbatch_bytes",
    "model_state_bytes", "pipeline_bubble_fraction",
    "pipeline_peak_activation_bytes", "pipeline_peak_inflight",
    "program_peak_inflight", "resolve_block_config", "roofline_terms",
]
