"""Step-program dataflow verification (rule family ``MK-P``).

`repro.dist.pipeline.make_step_program` builds the statically unrolled
per-tick (op, microbatch[, chunk]) schedule the pipeline executors scan
over.  Its invariants used to live in `_check_program` as bare asserts —
tuples like ``AssertionError((3, 1))`` that vanish under ``python -O``.
This module is the reporting form: `check_step_program` validates *any*
program — flat (op, m) entries and interleaved (op, m, c) chunk entries
alike — and returns diagnostics that name the schedule, tick, stage,
chunk and microbatch, so new schedules land on a checker instead of
growing new asserts.

Invariants (see `make_step_program`'s docstring for the derivation;
virtual stage q = c·S + s runs on device s = q mod S, and a flat
program is the v=1 case with q = s):

- every tick row covers every stage (MK-P001), entries are well-formed
  (MK-P006) with chunk indices consistent with ``virtual_stages``
  (MK-P008), and each (virtual stage, microbatch) forward/backward is
  scheduled exactly once (MK-P002 / MK-P003);
- F(q, m) runs >= 1 tick after F(q-1, m): activations travel the ring
  ppermute with one tick of latency — within a chunk (MK-P004) and
  across the S-1 → 0 chunk-wrap boundary, which rides the *same*
  uniform ring (MK-P009);
- B(q, m) runs exactly 1 tick after B(q+1, m) — cotangents are consumed
  the tick they arrive, the executors keep no cotangent buffer — and
  the last virtual stage's B(q, m) runs >= 1 tick after its F(q, m)
  (MK-P005);
- the measured stash occupancy (`program_peak_inflight`) stays within
  the schedule's analytic bound `pipeline_peak_inflight` (MK-P007), so
  the flat executors' ``m % K`` stash slots — and the interleaved
  executor's free-list slots — cannot collide.
"""
from __future__ import annotations

from typing import Sequence

from .costmodel import (PIPE_BWD, PIPE_FWD, PIPE_IDLE, SCHEDULES,
                        pipeline_peak_inflight, program_peak_inflight)
from .diagnostics import Diagnostic, error, info

_OPS = (PIPE_IDLE, PIPE_FWD, PIPE_BWD)
_OP_NAMES = {PIPE_IDLE: "idle", PIPE_FWD: "F", PIPE_BWD: "B"}


def _loc(schedule: str | None, t: int | None = None,
         s: int | None = None, m: int | None = None,
         c: int | None = None) -> str:
    parts = [f"schedule={schedule or '?'}"]
    if t is not None:
        parts.append(f"tick={t}")
    if s is not None:
        parts.append(f"stage={s}")
    if c is not None:
        parts.append(f"chunk={c}")
    if m is not None:
        parts.append(f"microbatch={m}")
    return " ".join(parts)


def check_step_program(prog: Sequence[Sequence[tuple]],
                       n_micro: int, n_stages: int,
                       schedule: str | None = None,
                       virtual_stages: int = 1) -> list[Diagnostic]:
    """Verify a step program's dataflow; returns diagnostics (possibly
    empty).  `schedule` is only used for messages and for picking the
    analytic peak-inflight bound (no bound is checked when it is None or
    unknown).  `virtual_stages` declares how many chunks each device
    holds: v > 1 expects (op, m, c) entries with c in [0, v) and checks
    the invariants on virtual stages q = c·S + s."""
    M, S = int(n_micro), int(n_stages)
    v = int(virtual_stages)
    if v < 1:
        raise ValueError(f"need virtual_stages >= 1, got {virtual_stages}")
    V = v * S
    diags: list[Diagnostic] = []
    f_tick: dict[tuple[int, int], int] = {}
    b_tick: dict[tuple[int, int], int] = {}
    structural_ok = True
    arities: set[int] = set()

    def vname(q: int) -> str:
        if v == 1:
            return f"stage={q}"
        return f"stage={q % S}, chunk={q // S}"

    for t, row in enumerate(prog):
        if len(row) != S:
            diags.append(error(
                "MK-P001", _loc(schedule, t=t),
                f"tick row has {len(row)} stage slots, the pipeline has "
                f"{S} stages",
                "every tick must state what each stage does (PIPE_IDLE "
                "for nothing)"))
            structural_ok = False
            continue
        for s, entry in enumerate(row):
            try:
                if len(entry) == 2:
                    (op, m), c = entry, 0
                elif len(entry) == 3:
                    op, m, c = entry
                else:
                    raise ValueError(entry)
            except (TypeError, ValueError):
                diags.append(error(
                    "MK-P006", _loc(schedule, t=t, s=s),
                    f"entry {entry!r} is not an (op, microbatch[, chunk]) "
                    "tuple"))
                structural_ok = False
                continue
            if op not in _OPS:
                diags.append(error(
                    "MK-P006", _loc(schedule, t=t, s=s),
                    f"unknown op code {op!r}",
                    "use PIPE_IDLE / PIPE_FWD / PIPE_BWD"))
                structural_ok = False
                continue
            if op == PIPE_IDLE:
                continue
            arities.add(len(entry))
            if v > 1 and len(entry) == 2:
                diags.append(error(
                    "MK-P008", _loc(schedule, t=t, s=s, m=m),
                    f"chunkless entry {entry!r} in a program declared "
                    f"with virtual_stages={v}",
                    "interleaved entries are (op, microbatch, chunk)"))
                structural_ok = False
                continue
            if not 0 <= c < v:
                diags.append(error(
                    "MK-P008", _loc(schedule, t=t, s=s, m=m),
                    f"chunk index {c} outside [0, {v}) — each device "
                    f"holds virtual_stages={v} chunks"))
                structural_ok = False
                continue
            if not 0 <= m < M:
                diags.append(error(
                    "MK-P006", _loc(schedule, t=t, s=s),
                    f"microbatch index {m} outside [0, {M})"))
                structural_ok = False
                continue
            q = c * S + s
            book = f_tick if op == PIPE_FWD else b_tick
            if (q, m) in book:
                diags.append(error(
                    "MK-P002", _loc(schedule, t=t, s=s, m=m,
                                    c=c if v > 1 else None),
                    f"{_OP_NAMES[op]}({vname(q)}, microbatch={m}) "
                    f"already ran at tick {book[(q, m)]} — a stage "
                    "slot can hold one micro-step per (op, "
                    "microbatch)"))
                structural_ok = False
            else:
                book[(q, m)] = t

    if len(arities) > 1:
        diags.append(error(
            "MK-P008", _loc(schedule),
            "program mixes flat (op, m) and chunked (op, m, c) entries",
            "pick one entry arity for the whole program"))
        structural_ok = False

    missing = [(which, q, m)
               for which, book in (("F", f_tick), ("B", b_tick))
               for q in range(V) for m in range(M) if (q, m) not in book]
    for which, q, m in missing:
        diags.append(error(
            "MK-P003", _loc(schedule, s=q % S, m=m,
                            c=q // S if v > 1 else None),
            f"{which}({vname(q)}, microbatch={m}) never scheduled — the "
            "program must run every forward and backward exactly once"))
    if missing:
        structural_ok = False

    if not structural_ok:
        return diags

    for q in range(V):
        for m in range(M):
            if q > 0 and f_tick[(q, m)] < f_tick[(q - 1, m)] + 1:
                wrap = q % S == 0      # chunk boundary rides the S-1 → 0
                #                        leg of the same uniform ring
                diags.append(error(
                    "MK-P009" if wrap else "MK-P004",
                    _loc(schedule, t=f_tick[(q, m)], s=q % S, m=m,
                         c=q // S if v > 1 else None),
                    f"F({vname(q)}, microbatch={m}) at tick "
                    f"{f_tick[(q, m)]} but its producer "
                    f"({vname(q - 1)}) only forwards it at tick "
                    f"{f_tick[(q - 1, m)]} — the ring "
                    "ppermute delivers activations one tick later"
                    + (" (chunk wraps included)" if wrap else ""),
                    "delay the forward to tick "
                    f">= {f_tick[(q - 1, m)] + 1}"))
            if q < V - 1 and b_tick[(q, m)] != b_tick[(q + 1, m)] + 1:
                diags.append(error(
                    "MK-P005", _loc(schedule, t=b_tick[(q, m)], s=q % S,
                                    m=m, c=q // S if v > 1 else None),
                    f"B({vname(q)}, microbatch={m}) at tick "
                    f"{b_tick[(q, m)]} but {vname(q + 1)} retires it at "
                    f"tick {b_tick[(q + 1, m)]} — cotangents are "
                    "consumed the tick after they are emitted (the "
                    "executors keep no cotangent buffer)",
                    f"schedule it at tick {b_tick[(q + 1, m)] + 1} "
                    "exactly"))
            if q == V - 1 and b_tick[(q, m)] < f_tick[(q, m)] + 1:
                diags.append(error(
                    "MK-P005", _loc(schedule, t=b_tick[(q, m)], s=q % S,
                                    m=m, c=q // S if v > 1 else None),
                    f"last-virtual-stage B(microbatch={m}) at tick "
                    f"{b_tick[(q, m)]} precedes its own forward at tick "
                    f"{f_tick[(q, m)]}"))

    if any(d.is_error for d in diags):
        return diags

    measured = program_peak_inflight(prog, S)
    if schedule in SCHEDULES and (v == 1 or schedule == "interleaved"):
        bound = pipeline_peak_inflight(M, S, schedule, virtual_stages=v)
        if measured > bound:
            diags.append(error(
                "MK-P007", _loc(schedule),
                f"measured peak stash occupancy {measured} exceeds the "
                f"{schedule} analytic bound "
                f"pipeline_peak_inflight={bound} — the executors' "
                "stash slots would collide",
                "reorder backwards to retire stashed microbatches "
                "sooner, or size the stash to the measured peak"))
    else:
        diags.append(info(
            "MK-P007", _loc(schedule),
            f"measured peak stash occupancy: {measured} (no analytic "
            "bound checked for an unnamed schedule)"))
    return diags


__all__ = ["check_step_program"]
