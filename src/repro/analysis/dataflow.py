"""Step-program dataflow verification (rule family ``MK-P``).

`repro.dist.pipeline.make_step_program` builds the statically unrolled
per-tick (op, microbatch) schedule both pipeline executors scan over.
Its invariants used to live in `_check_program` as bare asserts — tuples
like ``AssertionError((3, 1))`` that vanish under ``python -O``.  This
module is the reporting form: `check_step_program` validates *any*
program (hand-built interleaved-1F1B experiments included) and returns
diagnostics that name the schedule, tick, stage and microbatch, so new
schedules land on a checker instead of growing new asserts.

Invariants (see `make_step_program`'s docstring for the derivation):

- every tick row covers every stage (MK-P001), entries are well-formed
  (MK-P006), and each (stage, microbatch) forward/backward is scheduled
  exactly once (MK-P002 / MK-P003);
- F(s, m) runs >= 1 tick after F(s-1, m): activations travel the ring
  ppermute with one tick of latency (MK-P004);
- B(s, m) runs exactly 1 tick after B(s+1, m) — cotangents are consumed
  the tick they arrive, the executors keep no cotangent buffer — and the
  last stage's B(s, m) runs >= 1 tick after its F(s, m) (MK-P005);
- the measured stash occupancy (`program_peak_inflight`) stays within
  the schedule's analytic bound `pipeline_peak_inflight` (MK-P007), so
  the executors' ``m % K`` stash slots cannot collide.
"""
from __future__ import annotations

from typing import Sequence

from repro.dist.pipeline import (PIPE_BWD, PIPE_FWD, PIPE_IDLE, SCHEDULES,
                                 pipeline_peak_inflight,
                                 program_peak_inflight)

from .diagnostics import Diagnostic, error, info

_OPS = (PIPE_IDLE, PIPE_FWD, PIPE_BWD)
_OP_NAMES = {PIPE_IDLE: "idle", PIPE_FWD: "F", PIPE_BWD: "B"}


def _loc(schedule: str | None, t: int | None = None,
         s: int | None = None, m: int | None = None) -> str:
    parts = [f"schedule={schedule or '?'}"]
    if t is not None:
        parts.append(f"tick={t}")
    if s is not None:
        parts.append(f"stage={s}")
    if m is not None:
        parts.append(f"microbatch={m}")
    return " ".join(parts)


def check_step_program(prog: Sequence[Sequence[tuple[int, int]]],
                       n_micro: int, n_stages: int,
                       schedule: str | None = None) -> list[Diagnostic]:
    """Verify a step program's dataflow; returns diagnostics (possibly
    empty).  `schedule` is only used for messages and for picking the
    analytic peak-inflight bound (no bound is checked when it is None or
    unknown)."""
    M, S = int(n_micro), int(n_stages)
    diags: list[Diagnostic] = []
    f_tick: dict[tuple[int, int], int] = {}
    b_tick: dict[tuple[int, int], int] = {}
    structural_ok = True

    for t, row in enumerate(prog):
        if len(row) != S:
            diags.append(error(
                "MK-P001", _loc(schedule, t=t),
                f"tick row has {len(row)} stage slots, the pipeline has "
                f"{S} stages",
                "every tick must state what each stage does (PIPE_IDLE "
                "for nothing)"))
            structural_ok = False
            continue
        for s, entry in enumerate(row):
            try:
                op, m = entry
            except (TypeError, ValueError):
                diags.append(error(
                    "MK-P006", _loc(schedule, t=t, s=s),
                    f"entry {entry!r} is not an (op, microbatch) pair"))
                structural_ok = False
                continue
            if op not in _OPS:
                diags.append(error(
                    "MK-P006", _loc(schedule, t=t, s=s),
                    f"unknown op code {op!r}",
                    "use PIPE_IDLE / PIPE_FWD / PIPE_BWD"))
                structural_ok = False
                continue
            if op != PIPE_IDLE and not 0 <= m < M:
                diags.append(error(
                    "MK-P006", _loc(schedule, t=t, s=s),
                    f"microbatch index {m} outside [0, {M})"))
                structural_ok = False
                continue
            book = f_tick if op == PIPE_FWD else b_tick
            if op != PIPE_IDLE:
                if (s, m) in book:
                    diags.append(error(
                        "MK-P002", _loc(schedule, t=t, s=s, m=m),
                        f"{_OP_NAMES[op]}(stage={s}, microbatch={m}) "
                        f"already ran at tick {book[(s, m)]} — a stage "
                        "slot can hold one micro-step per (op, "
                        "microbatch)"))
                    structural_ok = False
                else:
                    book[(s, m)] = t

    missing = [(which, s, m)
               for which, book in (("F", f_tick), ("B", b_tick))
               for s in range(S) for m in range(M) if (s, m) not in book]
    for which, s, m in missing:
        diags.append(error(
            "MK-P003", _loc(schedule, s=s, m=m),
            f"{which}(stage={s}, microbatch={m}) never scheduled — the "
            "program must run every forward and backward exactly once"))
    if missing:
        structural_ok = False

    if not structural_ok:
        return diags

    for s in range(S):
        for m in range(M):
            if s > 0 and f_tick[(s, m)] < f_tick[(s - 1, m)] + 1:
                diags.append(error(
                    "MK-P004", _loc(schedule, t=f_tick[(s, m)], s=s, m=m),
                    f"F(stage={s}, microbatch={m}) at tick "
                    f"{f_tick[(s, m)]} but stage {s - 1} only forwards "
                    f"it at tick {f_tick[(s - 1, m)]} — the ring "
                    "ppermute delivers activations one tick later",
                    "delay the forward to tick "
                    f">= {f_tick[(s - 1, m)] + 1}"))
            if s < S - 1 and b_tick[(s, m)] != b_tick[(s + 1, m)] + 1:
                diags.append(error(
                    "MK-P005", _loc(schedule, t=b_tick[(s, m)], s=s, m=m),
                    f"B(stage={s}, microbatch={m}) at tick "
                    f"{b_tick[(s, m)]} but stage {s + 1} retires it at "
                    f"tick {b_tick[(s + 1, m)]} — cotangents are "
                    "consumed the tick after they are emitted (the "
                    "executors keep no cotangent buffer)",
                    f"schedule it at tick {b_tick[(s + 1, m)] + 1} "
                    "exactly"))
            if s == S - 1 and b_tick[(s, m)] < f_tick[(s, m)] + 1:
                diags.append(error(
                    "MK-P005", _loc(schedule, t=b_tick[(s, m)], s=s, m=m),
                    f"last-stage B(microbatch={m}) at tick "
                    f"{b_tick[(s, m)]} precedes its own forward at tick "
                    f"{f_tick[(s, m)]}"))

    if any(d.is_error for d in diags):
        return diags

    measured = program_peak_inflight(prog, S)
    if schedule in SCHEDULES:
        bound = pipeline_peak_inflight(M, S, schedule)
        if measured > bound:
            diags.append(error(
                "MK-P007", _loc(schedule),
                f"measured peak stash occupancy {measured} exceeds the "
                f"{schedule} analytic bound "
                f"pipeline_peak_inflight={bound} — the executors' "
                "m % K stash slots would collide",
                "reorder backwards to retire stashed microbatches "
                "sooner, or size the stash to the measured peak"))
    else:
        diags.append(info(
            "MK-P007", _loc(schedule),
            f"measured peak stash occupancy: {measured} (no analytic "
            "bound checked for an unnamed schedule)"))
    return diags


__all__ = ["check_step_program"]
