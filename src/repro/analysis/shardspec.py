"""Sharding-spec lint (rule family ``MK-S``).

`repro.dist.sharding` builds mesh-independent PartitionSpec trees and
clamps them against the concrete mesh only at application time
(`_sanitize`): an axis the mesh lacks, or a shard count that doesn't
divide the dim, silently drops to replicated.  That permissiveness is
what lets one spec tree serve every mesh — but it also swallows typos
("modle" replicates everything with no sign) and, inside a *manual*
shard_map island, silent replication of a model-sharded leaf is an
outright correctness bug: the layer code reduces row-parallel partial
products with explicit ``psum("model")``, which double-counts a leaf
that secretly arrived replicated (the hard error `pipeline_stage_specs`
already raises ad hoc).  These checks generalize that: lint any
spec/leaf tree against a symbolic ``{axis: size}`` mesh description and
report what sanitization *would* do before it quietly does it.
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
from jax.sharding import PartitionSpec as P

from .diagnostics import Diagnostic, error, warning
from .meshcli import KNOWN_AXES

Tree = Any


def _entries(spec: P) -> list[tuple[str, ...]]:
    """Normalize a spec to per-dim axis tuples (None → empty tuple)."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(())
        elif isinstance(entry, tuple):
            out.append(tuple(entry))
        else:
            out.append((entry,))
    return out


def check_spec(spec: P, shape: Sequence[int] | None,
               mesh_axes: Mapping[str, int], loc: str,
               manual_axes: Sequence[str] = (),
               constraint: bool = False,
               known_axes: Sequence[str] = KNOWN_AXES) -> list[Diagnostic]:
    """Lint one PartitionSpec against a symbolic mesh.

    Spec trees here are *mesh-independent* by design (`param_specs`
    names the logical ``model`` axis even when the concrete mesh lacks
    it, and `_sanitize` drops the entry at application time) — so an
    axis that is in `known_axes` but absent from this mesh is the
    documented sanitize-to-replicated path, not a finding.  MK-S001
    fires only for axes the substrate does not know at all: those are
    typos, and sanitization would silently replicate them everywhere.

    `shape` is the leaf shape the spec will be applied to (None skips
    rank/divisibility checks); `manual_axes` are the axes that are
    manual inside the surrounding shard_map island.  Two roles:

    - island *in_specs* (``constraint=False``): naming manual axes is
      how shard_map works, but a ``model`` entry that would sanitize
      away there is an error (MK-S003) — the block math psums partials
      it believes are sharded;
    - *constraint* specs issued inside the island (``constraint=True``):
      naming a manual axis at all is an error (MK-S006), because inside
      the island that axis no longer exists for the partitioner.
    """
    diags: list[Diagnostic] = []
    entries = _entries(spec)

    if shape is not None and len(entries) > len(shape):
        diags.append(error(
            "MK-S005", loc,
            f"spec {spec} has {len(entries)} entries for a rank-"
            f"{len(shape)} leaf of shape {tuple(shape)}"))
        # rank mismatch poisons the per-dim checks below
        entries = entries[:len(shape)]

    seen: dict[str, int] = {}
    for d, axes in enumerate(entries):
        for a in axes:
            if a in seen:
                diags.append(error(
                    "MK-S004", loc,
                    f"axis {a!r} appears in dims {seen[a]} and {d} of "
                    f"{spec} — one mesh axis can shard one dim"))
            seen.setdefault(a, d)
            if a not in mesh_axes and a not in known_axes:
                diags.append(error(
                    "MK-S001", loc,
                    f"spec {spec} names axis {a!r}, which neither this "
                    f"mesh ({tuple(mesh_axes)}) nor the sharding "
                    f"substrate ({tuple(known_axes)}) knows",
                    "sanitization would silently replicate this dim — "
                    "fix the axis name or the mesh"))
            elif constraint and a in manual_axes:
                diags.append(error(
                    "MK-S006", loc,
                    f"constraint spec {spec} names {a!r}, which is "
                    "manual inside this island — the partitioner no "
                    "longer sees that axis",
                    "constraints inside shard_map may only name the "
                    "island's auto axes"))

    if shape is None:
        return diags

    for d, axes in enumerate(entries):
        known = [a for a in axes if a in mesh_axes]
        if not known:
            continue
        size = 1
        for a in known:
            size *= mesh_axes[a]
        if size > 1 and shape[d] % size:
            rule, make = ("MK-S002", warning)
            if (not constraint and "model" in known
                    and "model" in manual_axes):
                # inside a manual island a dropped model entry is not a
                # perf wart but a double-count (explicit psum reduces a
                # leaf that arrived replicated)
                rule, make = ("MK-S003", error)
            diags.append(make(
                rule, loc,
                f"dim {d} of shape {tuple(shape)} is not divisible by "
                f"{'x'.join(known)}={size}; the entry drops to "
                "replicated at application time",
                "pad the dim (e.g. tp_align) or lower the axis size"))
    return diags


def check_spec_tree(tree_abs: Tree, specs: Tree,
                    mesh_axes: Mapping[str, int], loc_prefix: str = "",
                    manual_axes: Sequence[str] = (),
                    constraint: bool = False,
                    known_axes: Sequence[str] = KNOWN_AXES,
                    ) -> list[Diagnostic]:
    """Lint a whole spec tree against its (abstract) leaf tree."""
    diags: list[Diagnostic] = []

    def visit(path, leaf, spec):
        loc = f"{loc_prefix}{jax.tree_util.keystr(path)}"
        shape = getattr(leaf, "shape", None)
        diags.extend(check_spec(spec, shape, mesh_axes, loc,
                                manual_axes=manual_axes,
                                constraint=constraint,
                                known_axes=known_axes))
        return spec

    jax.tree_util.tree_map_with_path(
        visit, tree_abs, specs,
        is_leaf=lambda l: isinstance(l, P))
    return diags


__all__ = ["check_spec", "check_spec_tree"]
