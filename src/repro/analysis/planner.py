"""mkplan: static launch-configuration planning (rule family ``MK-T``).

MKPipe's compiler does not pick one optimization — it walks the whole
throughput/resource tradeoff space from static estimates before
anything is built (paper Sec. 5–6).  This module is that move for the
launch space: `enumerate_configs` walks the discrete knobs a human
currently hand-picks (``--stages/--microbatch/--schedule/
--virtual-stages/--model-par/--kernels``), `score` prices every
candidate with the unified cost models in `repro.analysis.costmodel`
*without compiling anything*, and `frontier` marks the
statically-dominated points, leaving the Pareto frontier over

- ``step_time_s``   — the schedule model: M pipeline periods of the
  padded bottleneck stage, inflated by the fill/drain bubble,
- ``peak_bytes``    — model state (params + grads + Adam moments, split
  over stage × model) plus the schedule's peak activation stash,
- ``collective_bytes`` — the analytic per-axis traffic model (stage
  ppermute + model psum + data grad all-reduce).

`check_launch` turns the comparison into structured diagnostics so
`launch.train --verify`, `tools/mklint.py --plan` and `launch.choose`
can *warn* (never refuse) when the chosen config is dominated:

- MK-T001 — chosen config dominated by a same-mesh alternative;
- MK-T002 — the peak-memory model exceeds ``--mem-budget``;
- MK-T003 — interleaved v>1 strictly lowers the bubble at this (M, S);
- MK-T004 — the tensor-parallel degree prices worse than spending the
  same devices on pipeline stages.

Like everything under `repro.analysis`, this module imports no jax at
module level; scoring lazily imports `repro.train.pipeline` (which
does) only when a candidate is actually priced.  Formulas and symbols:
docs/cost-models.md.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence

from .costmodel import (SCHEDULES, analytic_block_cost,
                        estimate_collective_bytes, model_state_bytes,
                        pipeline_bubble_fraction)
from .diagnostics import Diagnostic, Report, warning


def _divisors(n: int) -> list[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


@dataclasses.dataclass(frozen=True)
class LaunchCandidate:
    """One point of the discrete launch space (one train.py argv)."""
    stages: int
    microbatch: int
    schedule: str
    virtual_stages: int = 1
    tp: int = 1
    dp: int = 1
    kernels: str = "off"

    @property
    def n_devices(self) -> int:
        return self.stages * self.tp * self.dp

    @property
    def mesh_shape(self) -> tuple[int, int, int]:
        """(stage, data, model) — the train.py 3D mesh."""
        return (self.stages, self.dp, self.tp)

    def label(self) -> str:
        parts = [f"stages={self.stages}", f"micro={self.microbatch}",
                 f"schedule={self.schedule}"]
        if self.virtual_stages > 1:
            parts.append(f"v={self.virtual_stages}")
        parts += [f"tp={self.tp}", f"dp={self.dp}"]
        if self.kernels != "off":
            parts.append(f"kernels={self.kernels}")
        return " ".join(parts)

    def argv(self, arch: str, *, global_batch: int, seq_len: int,
             smoke: bool = False) -> list[str]:
        """The `repro.launch.train` argv realizing this candidate."""
        out = ["python", "-m", "repro.launch.train", "--arch", arch]
        if smoke:
            out.append("--smoke")
        out += ["--global-batch", str(global_batch),
                "--seq-len", str(seq_len)]
        if self.stages > 1 or self.tp > 1:
            out += ["--stages", str(self.stages),
                    "--microbatch", str(self.microbatch),
                    "--mesh-shape", ",".join(map(str, self.mesh_shape)),
                    "--axes", "stage,data,model",
                    "--schedule", self.schedule]
            if self.virtual_stages > 1:
                out += ["--virtual-stages", str(self.virtual_stages)]
        if self.kernels != "off":
            out += ["--kernels", self.kernels]
        return out


@dataclasses.dataclass(frozen=True)
class Score:
    """The three frontier coordinates of one candidate (lower is
    better on every axis)."""
    step_time_s: float
    peak_bytes: float
    collective_bytes: float

    def as_tuple(self) -> tuple[float, float, float]:
        return (self.step_time_s, self.peak_bytes, self.collective_bytes)

    def dominates(self, other: "Score") -> bool:
        """Weakly better on every coordinate, strictly on at least one
        (equal score vectors do not dominate each other)."""
        a, b = self.as_tuple(), other.as_tuple()
        return all(x <= y for x, y in zip(a, b)) and a != b


@dataclasses.dataclass(frozen=True)
class ScoredCandidate:
    candidate: LaunchCandidate
    score: Score
    bubble: float
    peak_activation_bytes: float
    collective_by_axis: dict[str, float]
    dominated_by: LaunchCandidate | None = None

    @property
    def on_frontier(self) -> bool:
        return self.dominated_by is None


def enumerate_configs(cfg, n_devices: int, *, global_batch: int,
                      schedules: Sequence[str] = SCHEDULES,
                      max_microbatch: int | None = None,
                      max_virtual_stages: int | None = None,
                      kernels_modes: Sequence[str] = ("off",),
                      ) -> list[LaunchCandidate]:
    """Walk the discrete launch space for `cfg` on an `n_devices` mesh.

    Factorizations ``stages × tp × dp = n_devices`` with every knob
    feasible by the launch arithmetic the MK-L rules enforce: stages (and
    stages × virtual_stages) within ``cfg.n_repeats``, tp dividing the
    attention heads and FFN width (the Megatron shard constraint), dp
    dividing the global batch, the microbatch count dividing the
    per-shard batch.  Flat schedules enumerate at v=1; ``"interleaved"``
    enumerates v ≥ 2 (v=1 interleaved is 1f1b).  Single-stage
    factorizations collapse to one (gpipe, M=1) candidate — there is no
    pipeline to schedule.
    """
    heads = getattr(cfg, "num_kv_heads", 1) or 1
    d_ff = getattr(cfg, "d_ff", 1) or 1
    out: list[LaunchCandidate] = []
    for stages in _divisors(n_devices):
        if stages > cfg.n_repeats:
            continue
        for tp in _divisors(n_devices // stages):
            if heads % tp or d_ff % tp:
                continue
            dp = n_devices // (stages * tp)
            if global_batch % dp:
                continue
            local_batch = global_batch // dp
            micros = [m for m in _divisors(local_batch)
                      if max_microbatch is None or m <= max_microbatch]
            for kernels in kernels_modes:
                if stages == 1:
                    out.append(LaunchCandidate(
                        stages=1, microbatch=1, schedule="gpipe",
                        tp=tp, dp=dp, kernels=kernels))
                    continue
                for micro in micros:
                    for schedule in schedules:
                        if schedule != "interleaved":
                            out.append(LaunchCandidate(
                                stages=stages, microbatch=micro,
                                schedule=schedule, tp=tp, dp=dp,
                                kernels=kernels))
                            continue
                        v_hi = cfg.n_repeats // stages
                        if max_virtual_stages is not None:
                            v_hi = min(v_hi, max_virtual_stages)
                        for v in range(2, v_hi + 1):
                            out.append(LaunchCandidate(
                                stages=stages, microbatch=micro,
                                schedule="interleaved", virtual_stages=v,
                                tp=tp, dp=dp, kernels=kernels))
    return out


def score(cfg, cand: LaunchCandidate, *, global_batch: int, seq_len: int,
          block_costs: Sequence[float] | None = None) -> ScoredCandidate:
    """Price one candidate with the unified cost models — no compiling.

    ``block_costs`` (per pattern position, one repeat, *unsharded*)
    defaults to the analytic roofline estimate so scoring stays
    jax-free; pass `costmodel.estimate_block_costs(cfg, mb, seq, tp=1)`
    measured costs for XLA-cost-analysis pricing.  Costs are divided by
    the candidate's tp (the Megatron shards split FLOPs and bytes
    evenly), then `plan_pipeline` partitions stages on them:

    - ``step_time_s = M · v·padded_stage_time / (1 − bubble)`` — M
      pipeline periods of the (padded, per-device) bottleneck stage,
      inflated by the schedule's fill/drain bubble; for S=1 this is just
      the whole stack's time;
    - ``peak_bytes = model_state + peak_activation_stash``;
    - ``collective_bytes = Σ_axis estimate_collective_bytes``.
    """
    from repro.train.pipeline import plan_pipeline

    mb = max(global_batch // cand.dp // cand.microbatch, 1)
    if block_costs is None:
        block_costs = [analytic_block_cost(cfg, pos, mb * seq_len)
                       for pos in range(len(cfg.pattern))]
    costs = [c / cand.tp for c in block_costs]
    plan = plan_pipeline(
        cfg, cand.stages, cand.microbatch, global_batch=global_batch,
        seq_len=seq_len, dp=cand.dp, tp=cand.tp, schedule=cand.schedule,
        virtual_stages=cand.virtual_stages, block_costs=costs)
    denom = max(1.0 - plan.bubble, 1e-9)
    step_time = cand.microbatch * plan.padded_stage_time_s / denom
    coll = estimate_collective_bytes(
        cfg, n_stages=cand.stages, n_micro=cand.microbatch,
        virtual_stages=cand.virtual_stages, tp=cand.tp, dp=cand.dp,
        global_batch=global_batch, seq_len=seq_len)
    peak = (plan.peak_activation_bytes
            + model_state_bytes(cfg, cand.stages, cand.tp))
    return ScoredCandidate(
        candidate=cand,
        score=Score(step_time_s=step_time, peak_bytes=peak,
                    collective_bytes=sum(coll.values())),
        bubble=plan.bubble,
        peak_activation_bytes=plan.peak_activation_bytes,
        collective_by_axis=coll)


def frontier(scored: Iterable[ScoredCandidate]) -> list[ScoredCandidate]:
    """Mark statically-dominated points: each dominated candidate gets
    ``dominated_by`` set to one dominating candidate (a frontier point
    when possible); the Pareto frontier is the rest.  Returns the full
    list sorted by the time model, frontier first."""
    pts = list(scored)
    out: list[ScoredCandidate] = []
    for sc in pts:
        doms = [o for o in pts if o.score.dominates(sc.score)]
        if doms:
            # prefer a dominator that is itself undominated, so the
            # pointer always names a frontier point when one exists
            top = [o for o in doms
                   if not any(p.score.dominates(o.score) for p in pts)]
            best = min(top or doms,
                       key=lambda o: o.score.as_tuple())
            sc = dataclasses.replace(sc, dominated_by=best.candidate)
        out.append(sc)
    return sorted(out, key=lambda s: (not s.on_frontier,
                                      s.score.as_tuple()))


def plan_frontier(cfg, n_devices: int, *, global_batch: int,
                  seq_len: int,
                  block_costs: Sequence[float] | None = None,
                  **enum_kwargs) -> list[ScoredCandidate]:
    """enumerate → score → frontier, one call (the CLI entry path)."""
    cands = enumerate_configs(cfg, n_devices, global_batch=global_batch,
                              **enum_kwargs)
    return frontier([score(cfg, c, global_batch=global_batch,
                           seq_len=seq_len, block_costs=block_costs)
                     for c in cands])


def _find(scored: Sequence[ScoredCandidate],
          pred: Callable[[LaunchCandidate], bool]
          ) -> list[ScoredCandidate]:
    return [s for s in scored if pred(s.candidate)]


def check_launch(cfg, chosen: LaunchCandidate, *, global_batch: int,
                 seq_len: int, mem_budget_bytes: float | None = None,
                 block_costs: Sequence[float] | None = None,
                 scored: Sequence[ScoredCandidate] | None = None,
                 ) -> list[Diagnostic]:
    """Compare a chosen launch config against the scored space (MK-T).

    Every MK-T diagnostic is a *warning* — the models are rankings, not
    measurements, so planners advise and launches proceed.  Pass
    ``scored`` to reuse an already-scored space (must include `chosen`);
    otherwise the chosen config's device count is enumerated here.
    """
    if scored is None:
        scored = plan_frontier(cfg, chosen.n_devices,
                               global_batch=global_batch,
                               seq_len=seq_len, block_costs=block_costs,
                               kernels_modes=(chosen.kernels,))
    mine = _find(scored, lambda c: c == chosen)
    if not mine:
        mine = [score(cfg, chosen, global_batch=global_batch,
                      seq_len=seq_len, block_costs=block_costs)]
        scored = frontier([*scored, mine[0]])
        mine = _find(scored, lambda c: c == chosen)
    sc = mine[0]
    cand = sc.candidate
    loc = cand.label()
    diags: list[Diagnostic] = []

    # MK-T001: a same-mesh alternative (identical stages × data × model
    # factorization — only the schedule knobs differ) dominates the
    # chosen point on all three models
    same_mesh = _find(scored,
                      lambda c: c.mesh_shape == cand.mesh_shape
                      and c.kernels == cand.kernels and c != cand)
    doms = [o for o in same_mesh if o.score.dominates(sc.score)]
    if doms:
        best = min(doms, key=lambda o: o.score.as_tuple())
        diags.append(warning(
            "MK-T001", loc,
            f"statically dominated by {best.candidate.label()} on the "
            f"same mesh: step-time model "
            f"{best.score.step_time_s:.3g}s <= {sc.score.step_time_s:.3g}s, "
            f"peak-bytes {best.score.peak_bytes:.3g} <= "
            f"{sc.score.peak_bytes:.3g}, collective-bytes "
            f"{best.score.collective_bytes:.3g} <= "
            f"{sc.score.collective_bytes:.3g}",
            hint="same devices, same mesh — switch the schedule knobs: "
                 + " ".join(best.candidate.argv(
                     cfg.name, global_batch=global_batch,
                     seq_len=seq_len))))

    # MK-T002: the peak-memory model exceeds the budget
    if mem_budget_bytes is not None and sc.score.peak_bytes \
            > mem_budget_bytes:
        diags.append(warning(
            "MK-T002", loc,
            f"peak-memory model {sc.score.peak_bytes / 2**30:.2f} GiB "
            f"(model state + activation stash) exceeds the budget "
            f"{mem_budget_bytes / 2**30:.2f} GiB",
            hint="raise --microbatch (shrinks each stashed microbatch), "
                 "switch gpipe → 1f1b/interleaved (caps the stash), or "
                 "spread state over more stages/model shards"))

    # MK-T003: a flat schedule was chosen but interleaving the same
    # (M, S) strictly lowers the analytic bubble and the depth allows it
    if cand.stages > 1 and cand.virtual_stages == 1 \
            and 2 * cand.stages <= cfg.n_repeats:
        flat = pipeline_bubble_fraction(cand.microbatch, cand.stages)
        best_v, best_bubble = 0, flat
        for v in range(2, cfg.n_repeats // cand.stages + 1):
            b = pipeline_bubble_fraction(cand.microbatch, cand.stages,
                                         virtual_stages=v)
            if b < best_bubble:
                best_v, best_bubble = v, b
        if best_v:
            diags.append(warning(
                "MK-T003", loc,
                f"interleaved virtual_stages={best_v} lowers the bubble "
                f"model to {best_bubble:.3f} (from {flat:.3f}) at "
                f"M={cand.microbatch}, S={cand.stages}",
                hint=f"--schedule interleaved --virtual-stages {best_v} "
                     f"(peak stash rises to the interleaved bound — "
                     f"check MK-T002 against your budget)"))

    # MK-T004: the chosen tp degree prices worse than spending those
    # devices on pipeline stages (same device count, same kernels)
    if cand.tp > 1:
        alts = _find(scored,
                     lambda c: c.tp < cand.tp and c.stages > cand.stages
                     and c.n_devices == cand.n_devices
                     and c.kernels == cand.kernels)
        better = [o for o in alts
                  if o.score.step_time_s < sc.score.step_time_s]
        if better:
            best = min(better, key=lambda o: o.score.step_time_s)
            diags.append(warning(
                "MK-T004", loc,
                f"tp={cand.tp} prices {sc.score.step_time_s:.3g}s on the "
                f"block-cost model; {best.candidate.label()} prices "
                f"{best.score.step_time_s:.3g}s with the same "
                f"{cand.n_devices} devices",
                hint="the model axis pays psums every block while the "
                     "stage axis pays one ppermute per tick — prefer "
                     "deeper pipeline: " + " ".join(best.candidate.argv(
                         cfg.name, global_batch=global_batch,
                         seq_len=seq_len))))
    return diags


def check_plan(cfg, chosen: LaunchCandidate, *, global_batch: int,
               seq_len: int, mem_budget_bytes: float | None = None,
               block_costs: Sequence[float] | None = None) -> Report:
    """`check_launch` wrapped in a `Report` (mklint-style target line)."""
    import time
    t0 = time.perf_counter()
    report = Report(target=f"plan {cfg.name} {chosen.label()}")
    report.extend(check_launch(cfg, chosen, global_batch=global_batch,
                               seq_len=seq_len,
                               mem_budget_bytes=mem_budget_bytes,
                               block_costs=block_costs))
    report.wall_s = time.perf_counter() - t0
    return report


__all__ = ["LaunchCandidate", "Score", "ScoredCandidate", "check_launch",
           "check_plan", "enumerate_configs", "frontier",
           "plan_frontier", "score"]
