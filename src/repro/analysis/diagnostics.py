"""Structured diagnostics for the mklint static verifier.

Every check in `repro.analysis` reports `Diagnostic` records instead of
asserting: a stable rule ID (the contract tests and CI pin against), a
severity, a human-readable location (which schedule/tick, which spec
leaf, which jaxpr equation), a message stating the violated invariant,
and a fix hint.  `Report` aggregates them per verification run and knows
how to format itself for the CLI; `DiagnosticError` is the exception the
runtime layers (`make_step_program`, `parse_mesh_cli`) raise when a
check that used to be a bare `assert` fails — it subclasses ValueError
so existing callers' error handling keeps working, carries the
structured records, and (unlike an assert) still fires under
``python -O``.

Rule families (catalog in `RULES`, prose in docs/static-analysis.md):

- ``MK-C...`` collective alignment (jaxpr traversal)
- ``MK-P...`` step-program dataflow
- ``MK-S...`` sharding-spec lint
- ``MK-K...`` Pallas kernel geometry
- ``MK-M...`` mesh CLI / axis validation
- ``MK-L...`` launch-configuration arithmetic
- ``MK-R...`` checkpoint restore / elastic shrink
- ``MK-T...`` tradeoff-space planning (cost-model frontier)
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterable, Iterator


class Severity(enum.Enum):
    ERROR = "error"       # would deadlock, miscompute, or fail to compile
    WARNING = "warning"   # legal but suspicious (silent replication, ...)
    INFO = "info"         # measurement / note, never gates a launch

    def __str__(self) -> str:          # "error", not "Severity.ERROR"
        return self.value


# stable rule catalog: ID → one-line description.  IDs are a public
# contract (tests pin them, CI greps them); add, never renumber.
RULES: dict[str, str] = {
    # collective alignment
    "MK-C001": "collective names an axis the mesh does not have",
    "MK-C002": "cond/switch branches issue different collective "
               "sequences over an axis the predicate may vary on",
    "MK-C003": "ppermute permutation is not a complete, duplicate-free "
               "permutation of the axis",
    "MK-C004": "stage-axis ppermute is not a uniform ring shift",
    "MK-C005": "collective inside a while loop whose trip count may "
               "vary over the collective's axis",
    # step-program dataflow
    "MK-P001": "step-program tick row does not cover every stage",
    "MK-P002": "micro-step scheduled more than once (occupancy clash)",
    "MK-P003": "micro-step never scheduled",
    "MK-P004": "forward runs before its input can arrive on the ring",
    "MK-P005": "backward breaks cotangent timing",
    "MK-P006": "malformed step-program entry (op code / microbatch)",
    "MK-P007": "measured stash occupancy exceeds the schedule's "
               "analytic peak-inflight bound",
    "MK-P008": "malformed chunk entry in a virtual-stage program",
    "MK-P009": "chunk-wrap dependency violates the ring transfer "
               "latency",
    # sharding specs
    "MK-S001": "PartitionSpec names an axis the mesh does not have",
    "MK-S002": "sharded dim not divisible by its axes (drops to "
               "replicated at application time)",
    "MK-S003": "model-axis entry would drop inside a manual island "
               "(explicit psum would double-count)",
    "MK-S004": "PartitionSpec names one mesh axis in two dims",
    "MK-S005": "PartitionSpec rank exceeds the leaf rank",
    "MK-S006": "constraint spec names an axis that is already manual "
               "inside the island",
    # Pallas kernels
    "MK-K001": "block shape does not divide the operand dim",
    "MK-K002": "index map leaves the operand's block grid",
    "MK-K003": "grid × block does not cover every output block",
    "MK-K008": "divisor clamp shrinks a block below half its target",
    # mesh CLI
    "MK-M001": "malformed --mesh-shape literal",
    "MK-M002": "--axes and --mesh-shape disagree (or --axes alone)",
    "MK-M003": "unknown mesh axis name",
    "MK-M004": "duplicate mesh axis name",
    "MK-M005": "'stage' axis size disagrees with --stages",
    "MK-M006": "--model-par disagrees with the explicit mesh",
    # launch arithmetic
    "MK-L001": "n_stages exceeds n_repeats",
    "MK-L002": "global batch not divisible by the data-parallel degree",
    "MK-L003": "per-shard batch not divisible by the microbatch count",
    "MK-L004": "unknown pipeline schedule",
    "MK-L005": "mutually exclusive launch flags",
    "MK-L006": "conflicting kernel modes",
    "MK-L007": "virtual-stage count inconsistent with the schedule",
    # restore / elastic fault tolerance (repro.analysis.elastic)
    "MK-R001": "checkpoint manifest does not match the restore target "
               "(tree/shape/spec/mesh)",
    "MK-R002": "elastic shrink would violate n_stages <= n_repeats",
    # tradeoff-space planning (repro.analysis.planner)
    "MK-T001": "chosen config statically dominated by a same-mesh "
               "alternative",
    "MK-T002": "peak-memory model exceeds the device memory budget",
    "MK-T003": "interleaved virtual stages would strictly lower the "
               "bubble at this (M, S)",
    "MK-T004": "tensor-parallel degree prices worse than more pipeline "
               "stages on the block-cost model",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: rule ID + severity + location + message + fix hint."""
    rule: str
    severity: Severity
    loc: str
    msg: str
    hint: str = ""

    def __post_init__(self) -> None:
        if self.rule not in RULES:
            raise ValueError(f"unknown rule id {self.rule!r}")

    @property
    def is_error(self) -> bool:
        return self.severity is Severity.ERROR

    def format(self) -> str:
        head = f"{self.rule} {self.severity}: [{self.loc}] {self.msg}"
        return head + (f"\n    hint: {self.hint}" if self.hint else "")

    def as_dict(self) -> dict:
        """Stable JSON schema (mklint --format json, CI annotations):
        rule / severity / loc / msg / hint, all strings."""
        return {"rule": self.rule, "severity": str(self.severity),
                "loc": self.loc, "msg": self.msg, "hint": self.hint}


def error(rule: str, loc: str, msg: str, hint: str = "") -> Diagnostic:
    return Diagnostic(rule, Severity.ERROR, loc, msg, hint)


def warning(rule: str, loc: str, msg: str, hint: str = "") -> Diagnostic:
    return Diagnostic(rule, Severity.WARNING, loc, msg, hint)


def info(rule: str, loc: str, msg: str, hint: str = "") -> Diagnostic:
    return Diagnostic(rule, Severity.INFO, loc, msg, hint)


@dataclasses.dataclass
class Report:
    """The result of one verification run.

    `wall_s` is the verifier's own cost for this config — the number the
    CLI prints so `--verify` can be judged cheap enough to default on.
    """
    target: str = ""
    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)
    wall_s: float = 0.0

    def add(self, diag: Diagnostic) -> None:
        self.diagnostics.append(diag)

    def extend(self, diags: Iterable[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self.diagnostics)

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.is_error]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics
                if d.severity is Severity.WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def rules_fired(self) -> set[str]:
        return {d.rule for d in self.diagnostics}

    def as_dict(self) -> dict:
        """Stable JSON schema for one report (see `Diagnostic.as_dict`)."""
        return {"target": self.target, "ok": self.ok,
                "wall_s": round(self.wall_s, 4),
                "diagnostics": [d.as_dict() for d in self.diagnostics]}

    def format(self, verbose: bool = False) -> str:
        shown = [d for d in self.diagnostics
                 if verbose or d.severity is not Severity.INFO]
        lines = [d.format() for d in shown]
        verdict = "clean" if self.ok else (
            f"{len(self.errors)} error(s), {len(self.warnings)} "
            "warning(s)")
        lines.append(f"mklint: {self.target or 'target'}: {verdict} "
                     f"({self.wall_s:.2f}s)")
        return "\n".join(lines)


class DiagnosticError(ValueError):
    """Raised by runtime entry points when a verifier check fails.

    Subclasses ValueError so call sites that caught the old asserts'
    sibling errors keep working; str() is the formatted diagnostics, so
    failures name the schedule, tick and microbatch in readable text
    (and, being a real raise, survive ``python -O``).
    """

    def __init__(self, diagnostics: Iterable[Diagnostic],
                 prefix: str = "") -> None:
        self.diagnostics = list(diagnostics)
        body = "\n".join(d.format() for d in self.diagnostics)
        super().__init__(f"{prefix}\n{body}" if prefix else body)


__all__ = ["Diagnostic", "DiagnosticError", "Report", "RULES", "Severity",
           "error", "info", "warning"]
