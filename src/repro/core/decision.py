"""The Fig. 5 decision tree: choose how to enable multi-kernel pipelining.

Order of checks (paper §5.4):
  1. dominant kernel (>95% of total time)   → no CKE; resource balancing only
  2. per producer→consumer edge, classify dependency:
       many-to-many / many-to-few          → global synchronization (KBK cut)
       few-to-many                         → CKE through global memory
                                             (+ id remapping variants)
       few-to-few, long execution time     → kernel fusion
       few-to-few, short execution time    → CKE with channels
  3. fusion feasibility (paper §5.4.1): NDRange stages fuse only when their
     grids match; otherwise fall back to channels.
Host-carried dependencies (§5.2) are excluded from CKE before any of this.

The output groups stages into *concurrency groups* (pipelines) separated by
global syncs, each annotated with its CKE mechanism — the executor lowers
groups to jitted callables and the balancer tunes factors per group.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from .depanalysis import (DepInfo, analyze_graph, merge_deps,
                          merge_edge_infos)
from .graph import StageGraph
from .idremap import RemapPlan, build_id_queue, is_identity

DOMINANT_FRACTION = 0.95
# Fusion-vs-channel threshold (paper Fig. 8: channels win on *short* runs by
# reducing launch overhead; fusion wins on long runs via deeper loop
# optimization).  FPGA launch overhead ~ms; XLA dispatch ~10s of µs — the
# constant is re-measured for TPU but the rule is the paper's.
CHANNEL_TIME_THRESHOLD_S = 5e-3


@dataclasses.dataclass(frozen=True)
class EdgePlan:
    producer: str
    consumer: str
    category: str
    mechanism: str                  # fuse | channel | globalmem | sync
    remap: RemapPlan | None = None  # for globalmem edges
    remap_level: str = "none"       # none | workgroup | workitem


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    graph: StageGraph
    edges: tuple[EdgePlan, ...]
    groups: tuple[tuple[str, ...], ...]     # concurrency groups, topo order
    dominant: str | None
    balancing: str                          # "throughput" | "resource" | "mixed"

    def mechanism(self, producer: str, consumer: str) -> str:
        for e in self.edges:
            if e.producer == producer and e.consumer == consumer:
                return e.mechanism
        return "sync"

    def edge(self, producer: str, consumer: str) -> EdgePlan | None:
        for e in self.edges:
            if e.producer == producer and e.consumer == consumer:
                return e
        return None


def _grids_match(graph: StageGraph, a: str, b: str) -> bool:
    sa, sb = graph.stage(a), graph.stage(b)
    if sa.mode == "single" and sb.mode == "single":
        return True      # single-workitem kernels merge by loop fusion
    return sa.grid == sb.grid and sa.mode == sb.mode


def plan_cke(graph: StageGraph,
             dep_infos: Mapping[tuple[str, str, str], DepInfo] | None = None,
             channel_threshold_s: float = CHANNEL_TIME_THRESHOLD_S,
             ) -> ExecutionPlan:
    dep_infos = dep_infos if dep_infos is not None else analyze_graph(graph)
    times = {s.name: (s.profile.time_s if s.profile else 1.0)
             for s in graph.stages}
    total = sum(times.values())

    # Step 1: dominant-kernel check.
    dominant = None
    for name, t in times.items():
        if total > 0 and t / total >= DOMINANT_FRACTION:
            dominant = name

    # collapse per-buffer infos per stage pair
    pair_infos: dict[tuple[str, str], list[DepInfo]] = {}
    for (p, c, _b), info in dep_infos.items():
        pair_infos.setdefault((p, c), []).append(info)

    host_dep = set(graph.host_dependencies)

    def crosses_loop_boundary(p: str, c: str) -> bool:
        """Paper §7.3.2 (BP): a host loop imposes global synchronization at
        its boundary — kernels inside a loop cannot pipeline with kernels
        outside it (the loop re-invokes its members every iteration)."""
        if graph.in_same_loop(p, c) is not None:
            return False
        in_loop = {m for _l, (ms, _t) in graph.loops.items() for m in ms}
        return (p in in_loop) != (c in in_loop) or (
            p in in_loop and c in in_loop)

    edge_plans: list[EdgePlan] = []
    for (p, c), infos in sorted(pair_infos.items()):
        category = merge_edge_infos(infos)
        if (dominant is not None or (p, c) in host_dep
                or crosses_loop_boundary(p, c)):
            edge_plans.append(EdgePlan(p, c, category, "sync"))
            continue
        if category in ("many-to-many", "many-to-few"):
            mech = "sync"
            remap, level = None, "none"
        elif category == "few-to-many":
            mech = "globalmem"
            # id queue from the union of dependency sets over all shared
            # buffers (a consumer waits for every buffer it reads)
            remap = build_id_queue(merge_deps(infos))
            level = "none" if is_identity(remap) else "workgroup"
        else:  # few-to-few
            exec_time = times[p] + times[c]
            if _grids_match(graph, p, c) and exec_time >= channel_threshold_s:
                mech = "fuse"
            else:
                mech = "channel"     # incl. grid-mismatch fallback (§5.4.1)
            remap, level = None, "none"
        edge_plans.append(EdgePlan(p, c, category, mech, remap, level))

    # An edge cannot pipeline if its endpoints are already serialized by a
    # global sync on another path (BP: K1→K4 crosses the K2/K3 loop's sync).
    sync_pairs = {(e.producer, e.consumer) for e in edge_plans
                  if e.mechanism == "sync"}

    def serialized_via_sync(src: str, dst: str) -> bool:
        # DFS over graph edges; true if every... any path src→dst passes a
        # sync edge that is not the direct (src,dst) edge itself.
        stack = [(src, False)]
        seen = set()
        while stack:
            node, via_sync = stack.pop()
            for p, c, _b in graph.edges():
                if p != node:
                    continue
                vs = via_sync or ((p, c) in sync_pairs)
                if c == dst and vs and (p, c) != (src, dst):
                    return True
                if (c, vs) not in seen and c != dst:
                    seen.add((c, vs))
                    stack.append((c, vs))
        return False

    edge_plans = [
        dataclasses.replace(e, mechanism="sync")
        if e.mechanism != "sync"
        and serialized_via_sync(e.producer, e.consumer) else e
        for e in edge_plans
    ]

    # Build concurrency groups: union stages joined by non-sync edges,
    # then order groups topologically.
    parent: dict[str, str] = {s.name: s.name for s in graph.stages}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a: str, b: str) -> None:
        parent[find(a)] = find(b)

    for e in edge_plans:
        if e.mechanism != "sync":
            union(e.producer, e.consumer)

    topo = graph.topo_order()
    group_of: dict[str, list[str]] = {}
    for name in topo:
        group_of.setdefault(find(name), []).append(name)
    seen: set[str] = set()
    groups: list[tuple[str, ...]] = []
    for name in topo:
        r = find(name)
        if r not in seen:
            seen.add(r)
            groups.append(tuple(group_of[r]))

    if dominant is not None:
        balancing = "resource"
    elif len(groups) == 1:
        balancing = "throughput"
    elif all(len(g) == 1 for g in groups):
        balancing = "resource"
    else:
        balancing = "mixed"   # paper's CFD case: Alg.2 across groups,
                              # Alg.1 inside each pipeline group

    return ExecutionPlan(
        graph=graph,
        edges=tuple(edge_plans),
        groups=tuple(groups),
        dominant=dominant,
        balancing=balancing,
    )
