"""Lower an ExecutionPlan to a single jitted callable (paper §5.4/§5.7).

Mechanisms and their TPU realizations:

KBK        stage fns composed with `lax.optimization_barrier` between every
           stage: XLA may not fuse across, intermediates round-trip HBM —
           the faithful baseline ("kernels executed one after another").
fuse       stage fns composed freely; XLA/Pallas fuse producer+consumer so
           the intermediate stays on-chip.  When the consumer registered a
           fused impl (`impls["fuse"]` consuming the producer's inputs
           directly), it is used (the kernels/ fused Pallas kernels).
channel    same dataflow as fusion but tile-granular hand-off; a stage pair
           may register `impls["channel"]` (one pallas_call with a VMEM
           revolving buffer).  Falls back to fused composition: on TPU a
           channel between two always-co-scheduled grids *is* a fused grid.
globalmem  chunked software pipeline: the producer's tiles are computed in
           dispatch order, interleaved with consumer tiles in id_queue
           order; a consumer tile runs as soon as its producers are done
           (§5.4.3 flags + §5.4.4 remapping).  Intermediate buffers are
           NaN-poisoned, so any dependency-order bug in the queue poisons
           the output and fails the correctness tests — the numerics prove
           queue legality.

All mechanisms compute the same function; `StageGraph.run_reference` is the
oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from .decision import EdgePlan, ExecutionPlan
from .graph import Stage, StageGraph
from .idremap import RemapPlan

Array = Any


def _barrier(tree):
    return jax.lax.optimization_barrier(tree)


def _run_stage(stage: Stage, env: dict[str, Array]) -> None:
    outs = stage.fn({k: env[k] for k in stage.reads})
    env.update(outs)


def _tile_offsets(stage: Stage, buffer: str, tile_flat: int) -> tuple[int, ...]:
    grid = stage.grid
    idx = []
    rem = tile_flat
    for g in reversed(grid):
        idx.append(rem % g)
        rem //= g
    tile = tuple(reversed(idx))
    region = stage.tile_maps[buffer].region(tile)
    return tuple(lo for lo, _hi in region), tile


def _run_globalmem_pair(
    producer: Stage,
    consumer: Stage,
    remap: RemapPlan,
    env: dict[str, Array],
) -> None:
    """Chunked producer/consumer interleave in id-queue order."""
    p_tile = producer.impls.get("tile")
    c_tile = consumer.impls.get("tile")
    if p_tile is None or c_tile is None:
        # No tile-wise implementation registered: run composed (still
        # correct; scheduling benefit is modeled, not executed).
        _run_stage(producer, env)
        _run_stage(consumer, env)
        return

    # Poison producer-written buffers: reads of unproduced tiles → NaN.
    for b in producer.writes:
        shape_src = env.get(b)
        if shape_src is None:
            # derive the full shape from the tile map over the whole grid
            tm = producer.tile_maps[b]
            hi = [0] * len(tm.const)
            for t in producer.tiles():
                for d, (_lo, h) in enumerate(tm.region(t)):
                    hi[d] = max(hi[d], h)
            env[b] = jnp.full(tuple(hi), jnp.nan, dtype=jnp.float32)
        else:
            env[b] = jnp.full_like(shape_src, jnp.nan)

    consumer_acc: dict[str, Array] = {}
    for b in consumer.writes:
        tm = consumer.tile_maps[b]
        hi = [0] * len(tm.const)
        for t in consumer.tiles():
            for d, (_lo, h) in enumerate(tm.region(t)):
                hi[d] = max(hi[d], h)
        consumer_acc[b] = jnp.full(tuple(hi), jnp.nan, dtype=jnp.float32)

    p_done = 0
    n_p = producer.n_tiles()

    def produce(tile_flat: int) -> None:
        outs = p_tile(env, tile_flat)
        for b, block in outs.items():
            offs, _ = _tile_offsets(producer, b, tile_flat)
            env[b] = jax.lax.dynamic_update_slice(
                env[b], block.astype(env[b].dtype), offs)

    def consume(tile_flat: int) -> None:
        outs = c_tile(env, tile_flat)
        for b, block in outs.items():
            offs, _ = _tile_offsets(consumer, b, tile_flat)
            consumer_acc[b] = jax.lax.dynamic_update_slice(
                consumer_acc[b], block.astype(consumer_acc[b].dtype), offs)

    for pos, cid in enumerate(remap.queue):
        need = remap.ready_after[pos]
        while p_done < need:
            produce(p_done)
            p_done += 1
        consume(cid)
    while p_done < n_p:      # drain producers nobody waited on
        produce(p_done)
        p_done += 1
    env.update(consumer_acc)


@dataclasses.dataclass
class CompiledPlan:
    plan: ExecutionPlan
    mode: str
    fn: Callable[[Mapping[str, Array]], dict[str, Array]]

    def __call__(self, buffers: Mapping[str, Array]) -> dict[str, Array]:
        return self.fn(buffers)


def compile_plan(plan: ExecutionPlan, mode: str | None = None,
                 jit: bool = True) -> CompiledPlan:
    """Build the executable for a plan.

    mode=None follows the plan's per-edge mechanisms; mode="kbk" forces the
    sequential baseline (used for A/B benchmarking, paper Fig. 14).
    """
    graph = plan.graph
    topo = graph.topo_order()
    forced_kbk = mode == "kbk"

    def runner(buffers: Mapping[str, Array]) -> dict[str, Array]:
        env: dict[str, Array] = dict(buffers)
        done: set[str] = set()
        for name in topo:
            if name in done:
                continue
            stage = graph.stage(name)
            handled = False
            if not forced_kbk:
                for e in plan.edges:
                    if e.producer != name:
                        continue
                    consumer = graph.stage(e.consumer)
                    if e.mechanism == "globalmem" and e.remap is not None:
                        # chunked interleave in id-queue order
                        _run_globalmem_pair(stage, consumer, e.remap, env)
                        done.update({name, e.consumer})
                        handled = True
                        break
                    if e.mechanism in ("fuse", "channel"):
                        # a registered pair kernel replaces producer+consumer
                        fused = (consumer.impls.get(e.mechanism)
                                 or consumer.impls.get("fuse"))
                        if fused is not None:
                            keys = (set(stage.reads) | set(consumer.reads)) \
                                - set(stage.writes)
                            env.update(fused({k: env[k] for k in keys
                                              if k in env}))
                            done.update({name, e.consumer})
                            handled = True
                            break
            if handled:
                continue
            _run_stage(stage, env)
            done.add(name)
            if forced_kbk:
                # materialize every intermediate: no cross-stage fusion
                for b in stage.writes:
                    env[b] = _barrier(env[b])
            else:
                # barrier only at global syncs (group boundaries)
                for e in plan.edges:
                    if e.producer == name and e.mechanism == "sync":
                        for b in stage.writes:
                            env[b] = _barrier(env[b])
        return {k: env[k] for k in graph.outputs}

    fn = jax.jit(runner) if jit else runner
    return CompiledPlan(plan=plan, mode=mode or "planned", fn=fn)
