"""Kernel balancing (paper §5.5): Algorithms 1 & 2 + Fig. 13 factor split.

Algorithm 1 (throughput balancing, §5.5.1): kernels in one CKE pipeline.
Iteratively grant +1 unified performance factor (N_uni) to the stage with
the lowest estimated throughput until some chip resource saturates.
Throughput of a stage with factor N is estimated as N × naive throughput.

Algorithm 2 (resource balancing, §5.5.2): kernels separated by global
synchronization.  Iteratively grant +1 N_uni to the kernel with the highest
marginal benefit ΔT/ΔU, where ΔT = T/(N(N+1)) and ΔU is the increase in the
critical resource's utilization, until the critical resource saturates.

Fig. 13: realize N_uni as (Unroll, SIMD, CU) in increasing resource-cost
order; SIMD must be a power of two (→ when the next grant lands on SIMD it
doubles N_uni rather than incrementing it — the "×2 if SIMD is used" note in
both algorithms).

Both algorithms finish with the paper's auto-tuning pass: re-evaluate
factors in [N_uni − p, N_uni + p] with the *measured* evaluator when one is
supplied (ours: lowered-HLO cost analysis instead of full synthesis).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping, Sequence

from .graph import Stage
from .resources import Factors, ResourceModel, RESOURCE_KEYS

MAX_STEPS = 512


@dataclasses.dataclass
class BalanceResult:
    factors: dict[str, Factors]
    totals: dict[str, float]          # final aggregate utilization
    trace: list[dict]                 # per-iteration log (for EXPERIMENTS.md)

    def n_uni(self) -> dict[str, int]:
        return {k: f.n_uni for k, f in self.factors.items()}


def realize_factors(stage: Stage, n_uni: int,
                    max_unroll: int, vectorizable: bool,
                    max_cu: int = 4) -> Factors:
    """Fig. 13: split N_uni into unroll × simd × cu, cheapest first.

    The realized product equals the grant whenever the grant is
    realizable within the bounds (unroll ≤ max_unroll, SIMD a power of
    two ≤ 16, cu ≤ max_cu); otherwise the largest realizable product ≤
    N_uni wins.  Ties prefer more unroll, then more SIMD — the paper's
    increasing resource-cost order.  (The old greedy
    ``unroll = min(n_uni, max_unroll)`` silently dropped granted factors
    whenever the truncating ``n_uni // unroll`` lost a remainder:
    N_uni=12 with max_unroll=8 realized only unroll=8 — product 8 — where
    unroll=6 × cu=2 realizes the full grant.)
    """
    n = max(1, int(n_uni))
    simd_opts = [s for s in (16, 8, 4, 2, 1) if s <= n] \
        if vectorizable else [1]
    best = Factors()
    best_key = (best.n_uni, best.unroll, best.simd)
    for unroll in range(min(n, max(1, int(max_unroll))), 0, -1):
        for simd in simd_opts:
            if unroll * simd > n:
                continue
            cu = min(n // (unroll * simd), max_cu)
            cand = Factors(unroll=unroll, simd=simd, cu=cu)
            key = (cand.n_uni, cand.unroll, cand.simd)
            if key > best_key:
                best, best_key = cand, key
            if cand.n_uni == n and cand.unroll == min(n, int(max_unroll)):
                # full grant at the maximal unroll: nothing later in
                # either loop can compare greater
                return best
    return best


def _grant(n_uni: int, stage: Stage, max_unroll: int) -> int:
    """+1 N_uni, or ×2 when the increment would be realized by SIMD
    (paper: 'x2 if SIMD is used')."""
    if n_uni >= max_unroll and (stage.profile is None
                                or stage.profile.vectorizable):
        return n_uni * 2
    return n_uni + 1


def throughput_balance(
    stages: Sequence[Stage],
    model: ResourceModel,
    max_unroll: Mapping[str, int] | None = None,
    resident_bytes: Mapping[str, float] | None = None,
) -> BalanceResult:
    """Algorithm 1 — throughput balancing for a multi-stage pipeline."""
    max_unroll = dict(max_unroll or {})
    resident = dict(resident_bytes or {})
    n_uni = {s.name: 1 for s in stages}
    trace: list[dict] = []

    def factors_of(s: Stage) -> Factors:
        return realize_factors(
            s, n_uni[s.name],
            max_unroll.get(s.name, model.chip.max_unroll_lanes),
            s.profile.vectorizable if s.profile else True,
        )

    def totals() -> dict[str, float]:
        per = {
            s.name: model.estimate(s, factors_of(s),
                                   resident_bytes=resident.get(s.name, 0.0))
            for s in stages
        }
        return model.total(per)

    for _ in range(MAX_STEPS):
        tp = {
            s.name: n_uni[s.name] * (s.profile.throughput if s.profile else 1.0)
            for s in stages
        }
        # find stage j with lowest estimated throughput
        j = min(stages, key=lambda s: tp[s.name])
        candidate = dict(n_uni)
        candidate[j.name] = _grant(
            n_uni[j.name], j, max_unroll.get(j.name, model.chip.max_unroll_lanes))
        saved = n_uni
        n_uni = candidate
        tot = totals()
        if model.saturated(tot):
            n_uni = saved          # roll back the grant that overflowed
            break
        trace.append({"granted": j.name, "n_uni": dict(n_uni),
                      "min_throughput": tp[j.name], "totals": tot})
    return BalanceResult(
        factors={s.name: factors_of(s) for s in stages},
        totals=totals(),
        trace=trace,
    )


def resource_balance(
    stages: Sequence[Stage],
    model: ResourceModel,
    max_unroll: Mapping[str, int] | None = None,
    resident_bytes: Mapping[str, float] | None = None,
) -> BalanceResult:
    """Algorithm 2 — resource balancing across globally-synchronized kernels.

    Note the aggregation difference vs Alg. 1: globally-synchronized kernels
    never *run* concurrently, so rate resources (mxu/hbm_bw/ici) are bounded
    by the max over kernels, while static residency (vmem/hbm_cap) still adds
    — matching the FPGA situation where all kernels' logic is synthesized
    simultaneously but only one is active.
    """
    max_unroll = dict(max_unroll or {})
    resident = dict(resident_bytes or {})
    n_uni = {s.name: 1 for s in stages}
    trace: list[dict] = []

    def factors_of(s: Stage) -> Factors:
        return realize_factors(
            s, n_uni[s.name],
            max_unroll.get(s.name, model.chip.max_unroll_lanes),
            s.profile.vectorizable if s.profile else True,
        )

    def totals() -> dict[str, float]:
        per = {
            s.name: model.estimate(s, factors_of(s),
                                   resident_bytes=resident.get(s.name, 0.0))
            for s in stages
        }
        out = {}
        for k in RESOURCE_KEYS:
            vals = [u[k] for u in per.values()]
            out[k] = sum(vals) if k in ("vmem", "hbm_cap") else max(vals)
        return out

    for _ in range(MAX_STEPS):
        tot = totals()
        crit = model.critical_resource(tot)
        best, best_ratio, best_candidate = None, -1.0, None
        for s in stages:
            cand = dict(n_uni)
            cand[s.name] = _grant(
                n_uni[s.name], s,
                max_unroll.get(s.name, model.chip.max_unroll_lanes))
            if cand[s.name] == n_uni[s.name]:
                continue
            # ΔT = T/(N(N+1)) — paper line 4
            t = s.profile.time_s if s.profile else 1.0
            n = n_uni[s.name]
            dT = t / (n * (cand[s.name]))
            saved = n_uni[s.name]
            n_uni[s.name] = cand[s.name]
            new_tot = totals()
            n_uni[s.name] = saved
            # ΔU on the critical resource (paper line 3); on FPGA every
            # grant consumes area so ΔU>0 — on TPU a grant may not move the
            # critical *rate* resource, so fall back to the largest
            # utilization increase to keep the greedy well-defined.
            dU = max(new_tot[crit] - tot[crit],
                     max(new_tot[k] - tot[k] for k in RESOURCE_KEYS),
                     1e-9)
            if model.saturated(new_tot):
                continue
            if dT / dU > best_ratio:
                best, best_ratio, best_candidate = s, dT / dU, cand[s.name]
        if best is None:
            break
        n_uni[best.name] = best_candidate
        trace.append({"granted": best.name, "n_uni": dict(n_uni),
                      "ratio": best_ratio, "critical": crit})
    return BalanceResult(
        factors={s.name: factors_of(s) for s in stages},
        totals=totals(),
        trace=trace,
    )


def auto_tune(
    result: BalanceResult,
    evaluate: Callable[[Mapping[str, int]], float],
    p: int = 2,
) -> tuple[dict[str, int], float, list[dict]]:
    """Paper §5.5.1 auto-tuning: search N_uni ± p per kernel with a measured
    evaluator (lower = better, e.g. modeled step time from lowered HLO).
    Kernels are tuned coordinate-wise (each kernel's 2p+1 candidates can be
    evaluated in parallel in a real deployment — §5.8)."""
    base = result.n_uni()
    best = dict(base)
    best_score = evaluate(best)
    log = [{"n_uni": dict(best), "score": best_score, "phase": "baseline"}]
    for name in sorted(base):
        for delta in range(-p, p + 1):
            if delta == 0:
                continue
            cand = dict(best)
            cand[name] = max(1, base[name] + delta)
            score = evaluate(cand)
            log.append({"n_uni": dict(cand), "score": score, "phase": name})
            if score < best_score:
                best, best_score = cand, score
    return best, best_score, log
