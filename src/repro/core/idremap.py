"""Workitem/workgroup id remapping (paper §5.3 id_queue + §5.4.4).

The paper builds a constant ``id_queue``: it simulates producer workitems
completing in dispatch (increasing-id) order and, after each completion,
pushes every consumer workitem whose dependencies just became fully resolved.
Consumers then execute in queue order instead of their natural id order, so
no consumer busy-waits on data that is not ready while ready work exists.

On TPU the queue becomes a permutation applied to a Pallas ``index_map`` or
to the chunk order of a ``lax.scan`` software pipeline.  The same machinery
doubles as the causal block-skipping order of flash attention (consumer
tiles whose producers are all masked are dropped entirely).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .depanalysis import DepInfo


@dataclasses.dataclass(frozen=True)
class RemapPlan:
    """``queue[k]`` = consumer tile id to execute at position ``k``.

    ``ready_after[k]`` = number of producer tiles that must have completed
    (in producer dispatch order) before queue position ``k`` may start —
    used by the chunked executor to schedule producer/consumer interleaving
    and by tests to verify the queue is a legal dependency-resolution order.
    """

    queue: tuple[int, ...]
    ready_after: tuple[int, ...]

    def as_array(self) -> np.ndarray:
        return np.asarray(self.queue, dtype=np.int32)


def build_id_queue(dep: DepInfo) -> RemapPlan:
    """Simulate the paper's queue construction.

    Producer tiles complete in id order 0,1,2,...  After producer tile p
    completes, every consumer tile whose dependency set is now fully resolved
    is pushed (ties pushed together, in consumer-id order, matching "all
    their workitem ids will be pushed in the id_queue").
    """
    n_c = dep.n_consumer_tiles
    # last (max) producer id each consumer waits for; -1 = no deps (ready
    # immediately).
    last_dep = np.full(n_c, -1, dtype=np.int64)
    for cid, ps in enumerate(dep.deps):
        if ps:
            last_dep[cid] = max(ps)
    queue: list[int] = []
    ready_after: list[int] = []
    # consumers with no producers run first (paper: dispatched immediately)
    for cid in range(n_c):
        if last_dep[cid] < 0:
            queue.append(cid)
            ready_after.append(0)
    for p in range(dep.n_producer_tiles):
        for cid in range(n_c):
            if last_dep[cid] == p:
                queue.append(cid)
                ready_after.append(p + 1)
    if len(queue) != n_c:
        raise AssertionError("id_queue lost consumer tiles")
    return RemapPlan(queue=tuple(queue), ready_after=tuple(ready_after))


def is_identity(plan: RemapPlan) -> bool:
    return list(plan.queue) == list(range(len(plan.queue)))


def validate_queue(dep: DepInfo, plan: RemapPlan) -> bool:
    """A queue is legal iff each consumer appears exactly once and its
    dependencies are complete at its scheduled position."""
    if sorted(plan.queue) != list(range(dep.n_consumer_tiles)):
        return False
    for pos, cid in enumerate(plan.queue):
        need = max(dep.deps[cid], default=-1) + 1
        if plan.ready_after[pos] < need:
            return False
        # ready_after must be monotone (producers complete in order)
        if pos and plan.ready_after[pos] < plan.ready_after[pos - 1]:
            return False
    return True


def wait_free_prefix(dep: DepInfo, plan: RemapPlan,
                     producer_rate: float = 1.0,
                     consumer_rate: float = 1.0) -> float:
    """Fraction of consumer tiles that never stall when producer and
    consumer run concurrently at the given tile rates (tiles/unit-time).
    This is the metric id-remapping improves (paper Fig. 11 discussion)."""
    stalls = 0
    t_consumer = 0.0
    for pos in range(len(plan.queue)):
        t_ready = plan.ready_after[pos] / producer_rate
        start = max(t_consumer, t_ready)
        if t_ready > t_consumer:
            stalls += 1
        t_consumer = start + 1.0 / consumer_rate
    return 1.0 - stalls / max(len(plan.queue), 1)


def pipeline_makespan(dep: DepInfo, plan: RemapPlan,
                      producer_rate: float = 1.0,
                      consumer_rate: float = 1.0) -> float:
    """Completion time of the last consumer tile under the queue order —
    the executor/cost-model uses this to score remapping benefit."""
    t_consumer = 0.0
    for pos in range(len(plan.queue)):
        t_ready = plan.ready_after[pos] / producer_rate
        t_consumer = max(t_consumer, t_ready) + 1.0 / consumer_rate
    return t_consumer
