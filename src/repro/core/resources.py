"""TPU resource model — the analogue of the paper's FPGA resource vector.

The paper tracks static resources (ALUTs, FFs, RAMs, DSPs) plus dynamic DRAM
bandwidth, reading estimates from the OpenCL compiler's log.  The TPU
analogue is the roofline resource vector of one chip:

    mxu      — bf16 matmul throughput          (the DSP analogue)
    hbm_bw   — HBM bandwidth                   (the DRAM-BW analogue)
    vmem     — on-chip VMEM capacity           (the RAM-block analogue)
    hbm_cap  — HBM capacity                    (a hard feasibility limit)
    ici      — inter-chip interconnect BW      (no FPGA analogue; needed at
                                                multi-chip scale)

Utilizations are fractions in [0, 1]; ERU = max over them (Eq. 1).
`estimate()` plays the role of the paper's "resource estimate extracted from
the OpenCL compiler log": a fast analytic model over a stage's tile shape and
its optimization factors, used inside the balancing loops.  The *compiled*
numbers from the dry-run (`cost_analysis`/HLO parsing) calibrate/validate it.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from .graph import Stage, StageProfile

# TPU v5e-like hardware constants (per chip), per the assignment spec.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW_PER_LINK = 50e9            # bytes/s/link
VMEM_BYTES = 128 * 1024 * 1024    # 128 MiB VMEM (v5e-class)
HBM_BYTES = 16 * 1024**3          # 16 GiB HBM

RESOURCE_KEYS = ("mxu", "hbm_bw", "vmem", "hbm_cap", "ici")


@dataclasses.dataclass(frozen=True)
class Factors:
    """The paper's per-kernel optimization factors (Fig. 13).

    unroll — inner-loop unroll (deepens the pipeline; cheapest resource-wise)
    simd   — lane widening; must be a power of two (on TPU: minor-dim tile
             multiple of 128 lanes)
    cu     — compute-unit replication (grid replication across cores;
             the most resource-hungry)
    """

    unroll: int = 1
    simd: int = 1
    cu: int = 1

    @property
    def n_uni(self) -> int:
        return self.unroll * self.simd * self.cu


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    vmem: float = VMEM_BYTES
    hbm_cap: float = HBM_BYTES
    ici_bw: float = ICI_BW_PER_LINK
    max_unroll_lanes: int = 8      # VPU sublanes usable for unrolling
    n_cores: int = 1               # grid replication budget ("CUs")

    @staticmethod
    def cpu() -> "ChipSpec":
        """Roofline constants of the machine the workload suite is
        *profiled* on — utilizations derived from CPU wall-clock profiles
        must be normalized against CPU peaks, not TPU peaks (the paper's
        profiling step measures on the same device it deploys to)."""
        return ChipSpec(peak_flops=2e11, hbm_bw=3e10,
                        vmem=32 * 1024 * 1024, hbm_cap=8 * 1024**3,
                        ici_bw=1e10)


class ResourceModel:
    """Analytic per-stage resource estimates under optimization factors."""

    def __init__(self, chip: ChipSpec | None = None):
        self.chip = chip or ChipSpec()

    def estimate(self, stage: Stage, factors: Factors,
                 resident_bytes: float = 0.0,
                 ici_bytes: float = 0.0) -> dict[str, float]:
        """Utilization fractions for one stage under the given factors.

        Scaling rules mirror the paper's observations:
        - throughput scales ~linearly with N_uni = unroll*simd*cu;
        - HBM-bandwidth demand scales with N_uni (paper: "utilization is the
          bandwidth of the naive kernel times the unified performance
          factor");
        - compute (MXU/VPU) demand scales with N_uni;
        - VMEM footprint scales with unroll*simd per CU, times cu overall
          (each replica holds its own working set);
        - HBM capacity is factor-independent (weights/activations resident).
        """
        prof = stage.profile or StageProfile(time_s=1.0)
        n = factors.n_uni
        t_naive = max(prof.time_s, 1e-12)
        flops_rate = (prof.flops / t_naive) * n
        bw_rate = (prof.hbm_bytes / t_naive) * n
        # working set per tile ~ hbm_bytes / n_tiles, widened by unroll*simd
        tile_bytes = prof.hbm_bytes / max(stage.n_tiles(), 1)
        vmem_foot = tile_bytes * factors.unroll * factors.simd * factors.cu * 2
        return {
            "mxu": flops_rate / self.chip.peak_flops,
            "hbm_bw": bw_rate / self.chip.hbm_bw,
            "vmem": vmem_foot / self.chip.vmem,
            "hbm_cap": resident_bytes / self.chip.hbm_cap,
            "ici": (ici_bytes / t_naive) * n / self.chip.ici_bw,
        }

    def total(self, per_stage: Mapping[str, Mapping[str, float]]
              ) -> dict[str, float]:
        """Aggregate utilization across co-resident stages.

        Static-like resources (vmem, hbm_cap) add up — every co-resident
        stage's working set occupies the chip simultaneously, exactly like
        the paper's ALUT/FF/RAM synthesis area.  Rate resources (mxu, hbm_bw,
        ici) also add for *concurrently executing* stages; the caller passes
        only the stages of one concurrent group.
        """
        out = {k: 0.0 for k in RESOURCE_KEYS}
        for util in per_stage.values():
            for k in RESOURCE_KEYS:
                out[k] += util.get(k, 0.0)
        return out

    def saturated(self, total: Mapping[str, float]) -> bool:
        return any(total[k] > 1.0 for k in RESOURCE_KEYS)

    def critical_resource(self, total: Mapping[str, float]) -> str:
        return max(RESOURCE_KEYS, key=lambda k: total[k])
