"""MKPipe core: the paper's multi-kernel pipeline compiler, on TPU/JAX."""
from .graph import AffineTileMap, Stage, StageGraph, StageProfile
from .depanalysis import DepInfo, analyze_edge, analyze_graph
from .idremap import RemapPlan, build_id_queue, validate_queue
from .decision import EdgePlan, ExecutionPlan, plan_cke
from .balancing import (BalanceResult, Factors, auto_tune, realize_factors,
                        resource_balance, throughput_balance)
from .resources import ChipSpec, ResourceModel, RESOURCE_KEYS
from .eru import Timeline, cke_timeline, eru, kbk_timeline
from .splitting import SplitDecision, explore_split
from .executor import CompiledPlan, compile_plan
from .planner import MKPipeReport, optimize, profile_graph

__all__ = [
    "AffineTileMap", "Stage", "StageGraph", "StageProfile",
    "DepInfo", "analyze_edge", "analyze_graph",
    "RemapPlan", "build_id_queue", "validate_queue",
    "EdgePlan", "ExecutionPlan", "plan_cke",
    "BalanceResult", "Factors", "auto_tune", "realize_factors",
    "resource_balance", "throughput_balance",
    "ChipSpec", "ResourceModel", "RESOURCE_KEYS",
    "Timeline", "cke_timeline", "eru", "kbk_timeline",
    "SplitDecision", "explore_split",
    "CompiledPlan", "compile_plan",
    "MKPipeReport", "optimize", "profile_graph",
]
