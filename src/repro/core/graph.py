"""Stage graph: the TPU analogue of MKPipe's kernel data-flow graph.

In the paper (§5.2) the compiler derives a kernel data-flow graph from the
OpenCL host code: kernels are nodes, and an edge exists when one kernel
writes a global-memory buffer that another reads.  Here a *Stage* is the
kernel analogue (a pure JAX-traceable op group), buffers are named arrays,
and the graph is derived from each stage's declared read/write sets — the
same information `clSetKernelArg` provides to the paper's compiler.

Each stage also carries an abstract *tile grid* and per-buffer affine tile
maps (the workitem/workgroup structure the paper's polyhedral pass analyses).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import numpy as np

Array = Any


@dataclasses.dataclass(frozen=True)
class AffineTileMap:
    """Affine map from a stage's tile index to a rectangular buffer region.

    For tile index ``i`` (tuple over grid dims) the accessed region of the
    buffer along output dim ``d`` is::

        offset[d] = sum_k coeff[d][k] * i[k] + const[d]
        region[d] = [offset[d], offset[d] + block[d])

    This is the restricted (rectangular, per-dim affine) polyhedral form —
    the same class of index expressions the paper handles ("array indices in
    OpenCL workloads are typically affine functions of workitem ids").
    """

    coeff: tuple[tuple[int, ...], ...]   # [buffer_dim][grid_dim]
    const: tuple[int, ...]               # [buffer_dim]
    block: tuple[int, ...]               # [buffer_dim]

    @staticmethod
    def identity_1d(block: int) -> "AffineTileMap":
        return AffineTileMap(coeff=((block,),), const=(0,), block=(block,))

    @staticmethod
    def broadcast(ndim_grid: int, shape: Sequence[int]) -> "AffineTileMap":
        """Whole-buffer access from every tile (e.g. read-only weights)."""
        return AffineTileMap(
            coeff=tuple((0,) * ndim_grid for _ in shape),
            const=tuple(0 for _ in shape),
            block=tuple(int(s) for s in shape),
        )

    def region(self, tile: Sequence[int]) -> tuple[tuple[int, int], ...]:
        """Half-open interval per buffer dim accessed by ``tile``."""
        out = []
        for d in range(len(self.const)):
            off = self.const[d] + sum(
                c * int(t) for c, t in zip(self.coeff[d], tile)
            )
            out.append((off, off + self.block[d]))
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class StageProfile:
    """Profiling data for a *naive* stage (paper §5.1: execution time and
    throughput of each naive kernel; throughput = output bytes / time)."""

    time_s: float
    out_bytes: int = 0
    flops: float = 0.0
    hbm_bytes: float = 0.0          # bytes moved to/from HBM ("global memory")
    vectorizable: bool = True       # the paper's per-kernel `VEC` boolean

    @property
    def throughput(self) -> float:
        return self.out_bytes / max(self.time_s, 1e-12)


@dataclasses.dataclass(frozen=True)
class Stage:
    """One kernel analogue.

    ``fn(buffers: dict) -> dict`` consumes the buffers named in ``reads`` and
    returns the buffers named in ``writes``.  ``mode`` mirrors the paper's
    NDRange vs single-workitem distinction: ``ndrange`` stages have a
    parallel tile grid, ``single`` stages are sequential loops (their "grid"
    is the loop trip count).
    """

    name: str
    fn: Callable[[Mapping[str, Array]], Mapping[str, Array]]
    reads: tuple[str, ...]
    writes: tuple[str, ...]
    grid: tuple[int, ...] = (1,)
    mode: str = "ndrange"                      # "ndrange" | "single"
    tile_maps: Mapping[str, AffineTileMap] = dataclasses.field(
        default_factory=dict
    )
    profile: StageProfile | None = None
    # Registered fused/pallas implementations, keyed by plan kind.
    impls: Mapping[str, Callable] = dataclasses.field(default_factory=dict)

    def tiles(self) -> np.ndarray:
        """All tile indices in dispatch (row-major id) order — the paper's
        'workitems with increasing ids are dispatched in sequential order'."""
        grids = [np.arange(g) for g in self.grid]
        mesh = np.meshgrid(*grids, indexing="ij")
        return np.stack([m.reshape(-1) for m in mesh], axis=-1)

    def n_tiles(self) -> int:
        return int(np.prod(self.grid))


@dataclasses.dataclass
class StageGraph:
    """Kernel data-flow graph + host-side structure annotations."""

    stages: list[Stage]
    # Buffers that live before/after the graph (host I/O).
    inputs: tuple[str, ...]
    outputs: tuple[str, ...]
    # Stages inside a host-side loop (paper Fig. 17: BP's K2..K3 loop), as
    # {loop_name: (stage names, trip_count)}.  Used by splitting criterion (a).
    loops: dict[str, tuple[tuple[str, ...], int]] = dataclasses.field(
        default_factory=dict
    )
    # Dependencies carried through the host CPU (paper §5.2 exclusion rule),
    # as edges (producer, consumer) that must NOT be made concurrent.
    host_dependencies: tuple[tuple[str, str], ...] = ()

    def __post_init__(self) -> None:
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate stage names: {names}")
        self._by_name = {s.name: s for s in self.stages}
        writers: dict[str, str] = {}
        for s in self.stages:
            for b in s.writes:
                if b in writers:
                    raise ValueError(
                        f"buffer {b!r} written by both {writers[b]} and {s.name}"
                    )
                writers[b] = s.name
        self.writers = writers

    def stage(self, name: str) -> Stage:
        return self._by_name[name]

    def edges(self) -> list[tuple[str, str, str]]:
        """(producer, consumer, buffer) edges — data flows through buffers."""
        out = []
        for consumer in self.stages:
            for b in consumer.reads:
                p = self.writers.get(b)
                if p is not None and p != consumer.name:
                    out.append((p, consumer.name, b))
        return out

    def predecessors(self, name: str) -> list[str]:
        return sorted({p for p, c, _ in self.edges() if c == name})

    def successors(self, name: str) -> list[str]:
        return sorted({c for p, c, _ in self.edges() if p == name})

    def topo_order(self) -> list[str]:
        order: list[str] = []
        seen: set[str] = set()
        edges = self.edges()
        indeg = {s.name: 0 for s in self.stages}
        for _, c, _ in edges:
            indeg[c] += 1
        ready = [s.name for s in self.stages if indeg[s.name] == 0]
        while ready:
            n = ready.pop(0)
            order.append(n)
            seen.add(n)
            for p, c, _ in edges:
                if p == n:
                    indeg[c] -= 1
                    if indeg[c] == 0 and c not in seen and c not in ready:
                        ready.append(c)
        if len(order) != len(self.stages):
            raise ValueError("stage graph has a cycle")
        return order

    def in_same_loop(self, a: str, b: str) -> str | None:
        for lname, (members, _trip) in self.loops.items():
            if a in members and b in members:
                return lname
        return None

    def run_reference(self, buffers: dict[str, Array]) -> dict[str, Array]:
        """Plain sequential (KBK) execution — the correctness oracle."""
        env = dict(buffers)
        for name in self.topo_order():
            s = self.stage(name)
            env.update(s.fn({k: env[k] for k in s.reads}))
        return {k: env[k] for k in self.outputs}
