"""MKPipe front door (paper Fig. 3).

    profile naive stages → derive dataflow graph (given) → dependency
    analysis → CKE decision tree → kernel balancing → splitting
    → optimized executable + report

`profile_graph` is the profiling step: it runs each *naive* stage once on
real inputs and records time, output bytes and throughput — the same three
inputs the paper's compiler takes.  FLOP/byte estimates for the resource
model come from jaxpr-level cost estimation of each stage fn.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

import jax
import numpy as np

from . import balancing as bal
from .decision import ExecutionPlan, plan_cke
from .depanalysis import analyze_graph
from .eru import Timeline, cke_timeline, kbk_timeline
from .executor import CompiledPlan, compile_plan
from .graph import Stage, StageGraph, StageProfile
from .resources import ResourceModel
from .splitting import SplitDecision, explore_split

Array = Any


def _stage_cost(stage: Stage, env: Mapping[str, Array]) -> tuple[float, float]:
    """FLOPs and HBM bytes of one stage via XLA cost analysis."""
    inputs = {k: env[k] for k in stage.reads}
    try:
        compiled = jax.jit(stage.fn).lower(inputs).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):  # jax<=0.4 returns [dict]
            ca = ca[0] if ca else {}
        flops = float(ca.get("flops", 0.0))
        bts = float(ca.get("bytes accessed", 0.0))
        return flops, bts
    except Exception:
        return 0.0, 0.0


def profile_graph(graph: StageGraph, buffers: Mapping[str, Array],
                  repeats: int = 3) -> StageGraph:
    """Run each naive stage; attach StageProfile (paper's profiling data)."""
    env = dict(buffers)
    new_stages = []
    for name in graph.topo_order():
        s = graph.stage(name)
        inputs = {k: env[k] for k in s.reads}
        fn = jax.jit(s.fn)
        outs = fn(inputs)                       # compile + warm
        jax.block_until_ready(outs)
        # min over individually-timed runs (≥2): scheduler noise only ever
        # inflates a sample, and a single inflated sample on a µs-scale
        # kernel can flip the Fig. 5 dominance/threshold decisions
        samples = []
        for _ in range(max(repeats, 2)):
            t0 = time.perf_counter()
            outs = fn(inputs)
            jax.block_until_ready(outs)
            samples.append(time.perf_counter() - t0)
        dt = min(samples)
        out_bytes = sum(int(np.prod(v.shape)) * v.dtype.itemsize
                        for v in outs.values())
        flops, hbm = _stage_cost(s, env)
        prof = StageProfile(time_s=dt, out_bytes=out_bytes,
                            flops=flops, hbm_bytes=hbm,
                            vectorizable=(s.profile.vectorizable
                                          if s.profile else True))
        env.update(outs)
        new_stages.append(dataclasses.replace(s, profile=prof))
    return dataclasses.replace(
        graph, stages=new_stages) if dataclasses.is_dataclass(graph) else graph


@dataclasses.dataclass
class MKPipeReport:
    plan: ExecutionPlan
    balance: bal.BalanceResult | None
    split: SplitDecision | None
    kbk_timeline: Timeline
    cke_timeline: Timeline
    dep_categories: dict[tuple[str, str, str], str]

    @property
    def modeled_speedup(self) -> float:
        m = self.cke_timeline.makespan
        return self.kbk_timeline.makespan / m if m > 0 else 1.0


def optimize(graph: StageGraph,
             model: ResourceModel | None = None,
             explore_splitting: bool = True,
             channel_threshold_s: float | None = None,
             ) -> tuple[CompiledPlan, MKPipeReport]:
    """The full MKPipe pass over a *profiled* stage graph."""
    model = model or ResourceModel()
    if any(s.profile is None for s in graph.stages):
        raise ValueError("graph must be profiled first (profile_graph)")

    infos = analyze_graph(graph)
    kwargs = {}
    if channel_threshold_s is not None:
        kwargs["channel_threshold_s"] = channel_threshold_s
    plan = plan_cke(graph, infos, **kwargs)

    times = {s.name: s.profile.time_s for s in graph.stages}
    utils = {
        s.name: model.estimate(s, bal.Factors())
        for s in graph.stages
    }

    # Balancing: Alg.1 inside pipeline groups, Alg.2 across sync groups
    # (the paper's CFD 'mixed' case treats each pipeline as one virtual
    # kernel at the outer level).
    if plan.balancing == "throughput":
        balance = bal.throughput_balance(
            [graph.stage(n) for n in plan.groups[0]], model)
    elif plan.balancing == "resource":
        balance = bal.resource_balance(list(graph.stages), model)
    else:
        # outer: resource-balance virtual kernels; inner: throughput-balance
        # each multi-stage group.  We report the inner result of the largest
        # pipeline (the balancing that matters most).
        inner_groups = [g for g in plan.groups if len(g) > 1]
        balance = bal.throughput_balance(
            [graph.stage(n) for n in inner_groups[0]], model)

    split = None
    if explore_splitting:
        pipelines = [g for g in plan.groups if len(g) > 1]
        split = explore_split(graph, times, utils, pipelines)

    t_kbk = kbk_timeline(graph.topo_order(), times, utils)
    t_cke = cke_timeline(plan.groups, times, utils)
    report = MKPipeReport(
        plan=plan, balance=balance, split=split,
        kbk_timeline=t_kbk, cke_timeline=t_cke,
        dep_categories={k: v.category for k, v in infos.items()},
    )
    return compile_plan(plan), report
