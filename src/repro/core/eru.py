"""Effective Resource Utilization (paper Eq. 1) and ERU-over-time timelines.

ERU = max over per-resource utilizations — identical in spirit to a roofline
bottleneck fraction.  The timeline reproduces Fig. 2: under KBK each stage
occupies its own time segment with its own ERU; under CKE concurrent stages
share a segment whose utilization is the sum of theirs (and whose duration
is set by the slowest stage / pipeline makespan).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from .resources import RESOURCE_KEYS


def eru(util: Mapping[str, float]) -> float:
    """Eq. 1: ERU = Max(U_ALUT, U_FF, U_RAM, U_DSP, U_BW) → TPU resources."""
    return max(util.get(k, 0.0) for k in RESOURCE_KEYS)


@dataclasses.dataclass(frozen=True)
class Segment:
    t0: float
    t1: float
    stages: tuple[str, ...]
    util: Mapping[str, float]

    @property
    def eru(self) -> float:
        return eru(self.util)


@dataclasses.dataclass(frozen=True)
class Timeline:
    segments: tuple[Segment, ...]

    @property
    def makespan(self) -> float:
        return self.segments[-1].t1 if self.segments else 0.0

    @property
    def time_weighted_eru(self) -> float:
        ms = self.makespan
        if ms <= 0:
            return 0.0
        return sum(s.eru * (s.t1 - s.t0) for s in self.segments) / ms

    def accumulated_eru(self) -> float:
        """∑ T_i × ERU_i — the quantity in splitting criterion (c)."""
        return sum(s.eru * (s.t1 - s.t0) for s in self.segments)


def kbk_timeline(stage_order: Sequence[str],
                 times: Mapping[str, float],
                 utils: Mapping[str, Mapping[str, float]]) -> Timeline:
    """Fig. 2a: sequential stage execution → stepwise ERU."""
    t = 0.0
    segs = []
    for name in stage_order:
        dt = times[name]
        segs.append(Segment(t, t + dt, (name,), dict(utils[name])))
        t += dt
    return Timeline(tuple(segs))


def cke_timeline(groups: Sequence[Sequence[str]],
                 times: Mapping[str, float],
                 utils: Mapping[str, Mapping[str, float]]) -> Timeline:
    """Fig. 2b: each group runs concurrently (duration = slowest member,
    i.e. the pipeline drains at the bottleneck stage's rate); groups are
    separated by global synchronization."""
    t = 0.0
    segs = []
    for group in groups:
        dt = max(times[n] for n in group)
        agg = {k: sum(utils[n].get(k, 0.0) for n in group)
               for k in RESOURCE_KEYS}
        segs.append(Segment(t, t + dt, tuple(group), agg))
        t += dt
    return Timeline(tuple(segs))
