"""Program ("bitstream") splitting — paper §5.6, Eq. 2.

The FPGA tradeoff: two bitstreams give each kernel the whole chip (so more
aggressive per-kernel optimization) but cost reprogramming (T_r ≈ 1400 ms)
plus host↔device data movement (T_d).  The TPU analogue: compile the stage
graph into one XLA executable vs two, where swapping executables costs
recompile/load plus weight/activation re-transfer.  Serving systems face
exactly this choice for prefill vs decode programs.

Bi-partitioning criteria (paper):
  (a) loops are not split unless one iteration's time ≫ reprogram overhead;
  (b) a CKE pipeline is never broken by a partition;
  (c) among legal partitions minimize |T1·ERU1 − T2·ERU2| (isolate the
      long-running resource-constrained kernels).

Decision (Eq. 2): keep co-residence iff
      T1 + T2 < T1·ERU1 + T2·ERU2 + T_r + T_d
where Ti·ERUi estimates the *improved* time of partition i when it
monopolizes the chip (critical-resource headroom 1/ERU → time × ERU).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

from .eru import eru as eru_fn
from .graph import StageGraph

# Program-swap overheads (TPU analogue of the measured 1400 ms reprogram).
DEFAULT_T_REPROGRAM = 1.4      # s: executable swap + compile-cache load
DEFAULT_T_DTRANSFER = 0.0      # s: extra host<->device transfer; workload-set


@dataclasses.dataclass(frozen=True)
class SplitDecision:
    split: bool
    partition: tuple[tuple[str, ...], tuple[str, ...]] | None
    t_coreside: float
    t_split: float
    candidates: tuple[dict, ...]    # scored legal partitions (for the log)


def _legal(graph: StageGraph,
           part_a: frozenset[str],
           part_b: frozenset[str],
           pipelines: Sequence[Sequence[str]],
           times: Mapping[str, float],
           t_reprogram: float) -> bool:
    # (b) never break a CKE pipeline
    for pipe in pipelines:
        s = set(pipe)
        if s & part_a and s & part_b:
            return False
    # (a) don't split a loop unless per-iteration time >> reprogram overhead
    for _name, (members, trips) in graph.loops.items():
        m = set(members)
        if m & part_a and m & part_b:
            iter_time = sum(times[x] for x in m)
            if not (iter_time > 10.0 * t_reprogram):
                return False
    # partitions must respect dataflow direction (a clean cut: no edge from
    # B back to A when A runs first) — choose orientation A→B
    for p, c, _ in graph.edges():
        if p in part_b and c in part_a:
            return False
    return True


def explore_split(
    graph: StageGraph,
    times: Mapping[str, float],
    utils: Mapping[str, Mapping[str, float]],
    pipelines: Sequence[Sequence[str]] = (),
    t_reprogram: float = DEFAULT_T_REPROGRAM,
    t_dtransfer: float = DEFAULT_T_DTRANSFER,
    loop_trip_multiplier: bool = True,
) -> SplitDecision:
    """Exhaustively score all bi-partitions (the paper notes kernel counts
    are small, so exhaustive search is fine)."""
    names = [s.name for s in graph.stages]
    # effective time of each stage including host-loop trip counts
    eff_times = dict(times)
    if loop_trip_multiplier:
        for _lname, (members, trips) in graph.loops.items():
            for m in members:
                eff_times[m] = times[m] * trips

    candidates = []
    n = len(names)
    for mask in range(1, 2 ** n - 1):
        a = frozenset(names[i] for i in range(n) if mask >> i & 1)
        b = frozenset(names) - a
        if not _legal(graph, a, b, pipelines, eff_times, t_reprogram):
            continue
        ta = sum(eff_times[x] for x in a)
        tb = sum(eff_times[x] for x in b)
        # partition ERU: time-weighted max utilization of members
        def part_eru(part: frozenset[str], t_part: float) -> float:
            if t_part <= 0:
                return 0.0
            return sum(eff_times[x] * eru_fn(utils[x]) for x in part) / t_part
        ea, eb = part_eru(a, ta), part_eru(b, tb)
        # reprogram count: loops crossing the partition pay per iteration;
        # we only allow that when legal per (a), with the measured times.
        swaps = 1
        balance = abs(ta * ea - tb * eb)          # criterion (c)
        candidates.append({
            "a": tuple(sorted(a)), "b": tuple(sorted(b)),
            "t1": ta, "t2": tb, "eru1": ea, "eru2": eb,
            "t_split": ta * ea + tb * eb + swaps * (t_reprogram + t_dtransfer),
            "balance": balance,
        })

    t_coreside = sum(eff_times[x] for x in names)
    if not candidates:
        return SplitDecision(False, None, t_coreside, float("inf"), ())

    # criterion (c): pick the balance-minimizing legal partition...
    best = min(candidates, key=lambda c: c["balance"])
    # ...then apply Eq. 2 to decide split vs co-reside.
    split = not (t_coreside < best["t_split"])
    return SplitDecision(
        split=split,
        partition=(best["a"], best["b"]),
        t_coreside=t_coreside,
        t_split=best["t_split"],
        candidates=tuple(sorted(candidates, key=lambda c: c["balance"])[:8]),
    )
