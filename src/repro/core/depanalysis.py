"""Cross-kernel dependency analysis (paper §5.3).

The paper runs polyhedral analysis (Candl) over affine array indices to find
which producer workitems each consumer workitem depends on, then classifies
the relationship as few-to-few / few-to-many / many-to-few / many-to-many.

Here stages expose rectangular affine tile maps (`AffineTileMap`), so the
dependency set is computed *exactly* by interval intersection per buffer
dimension: consumer tile `ic` depends on producer tile `ip` iff the write
region of `ip` intersects the read region of `ic` on the shared buffer.

For the affine maps used in practice the per-dimension problem
``a1*ip + b1 <= x < a1*ip + b1 + s1``  ∩  ``a2*ic + b2 <= x < a2*ic + b2 + s2``
is solved in closed form per consumer tile (a strided-interval overlap), so
the analysis is O(#consumer tiles · fan-in) rather than O(#p · #c).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

import numpy as np

from .graph import AffineTileMap, Stage, StageGraph

# Fan thresholds for the paper's classification.  "few" == bounded constant
# fan; the paper's examples use one-to-one and one-to-many, we keep a small
# constant so e.g. halo reads (fan-in 2-3) still count as "few".
FEW = 4


@dataclasses.dataclass(frozen=True)
class DepInfo:
    producer: str
    consumer: str
    buffer: str
    # dependency sets: per consumer tile id, sorted producer tile ids
    deps: tuple[tuple[int, ...], ...]
    max_fan_in: int          # producers needed by one consumer tile
    max_fan_out: int         # consumers fed by one producer tile
    n_producer_tiles: int
    n_consumer_tiles: int

    @property
    def category(self) -> str:
        fi = self.max_fan_in >= min(FEW + 1, self.n_producer_tiles)
        fo = self.max_fan_out >= min(FEW + 1, self.n_consumer_tiles)
        fan_in_many = self.max_fan_in > FEW
        fan_out_many = self.max_fan_out > FEW
        if not fan_in_many and not fan_out_many:
            return "few-to-few"
        if not fan_in_many and fan_out_many:
            return "few-to-many"
        if fan_in_many and not fan_out_many:
            return "many-to-few"
        return "many-to-many"

    @property
    def one_to_one(self) -> bool:
        return self.max_fan_in <= 1 and self.max_fan_out <= 1


def _intersecting_tiles_1d(
    a1: int, b1: int, s1: int, n1: int, lo: int, hi: int
) -> range:
    """Producer tiles ip in [0, n1) with [a1*ip+b1, a1*ip+b1+s1) ∩ [lo,hi) ≠ ∅.

    Needs a1*ip + b1 < hi  and  a1*ip + b1 + s1 > lo.
    """
    if a1 == 0:
        # every tile touches the same band
        if b1 < hi and b1 + s1 > lo:
            return range(0, n1)
        return range(0)
    if a1 > 0:
        lo_ip = math.ceil((lo - s1 + 1 - b1) / a1)
        hi_ip = math.floor((hi - 1 - b1) / a1)
    else:
        lo_ip = math.ceil((hi - 1 - b1) / a1)
        hi_ip = math.floor((lo - s1 + 1 - b1) / a1)
    lo_ip = max(lo_ip, 0)
    hi_ip = min(hi_ip, n1 - 1)
    return range(lo_ip, hi_ip + 1)


def dependency_sets(
    producer: Stage,
    consumer: Stage,
    buffer: str,
) -> list[set[int]]:
    """For each consumer tile (by row-major id): set of producer tile ids."""
    wmap = producer.tile_maps[buffer]
    rmap = consumer.tile_maps[buffer]
    p_tiles = producer.tiles()
    c_tiles = consumer.tiles()
    ndim = len(wmap.const)

    # Row-major strides to convert producer tile tuples to flat ids.
    p_strides = np.ones(len(producer.grid), dtype=np.int64)
    for d in range(len(producer.grid) - 2, -1, -1):
        p_strides[d] = p_strides[d + 1] * producer.grid[d + 1]

    deps: list[set[int]] = []
    for ic in c_tiles:
        r = rmap.region(ic)
        # Per buffer-dim: candidate producer tile coordinates along each grid
        # dim.  The general case couples grid dims; the maps we build keep at
        # most one grid dim per buffer dim (pure-rectangular), which covers
        # all workloads here — fall back to enumeration otherwise.
        per_grid_dim: list[set[int] | None] = [None] * len(producer.grid)
        feasible = True
        for d in range(ndim):
            (lo, hi) = r[d]
            coefs = wmap.coeff[d]
            nz = [k for k, c in enumerate(coefs) if c != 0]
            if len(nz) == 0:
                if not (wmap.const[d] < hi and wmap.const[d] + wmap.block[d] > lo):
                    feasible = False
                    break
                continue
            if len(nz) > 1:
                # coupled dims: enumerate producer tiles (exact, slower)
                return _dependency_sets_enum(producer, consumer, buffer)
            k = nz[0]
            rng = _intersecting_tiles_1d(
                coefs[k], wmap.const[d], wmap.block[d], producer.grid[k], lo, hi
            )
            s = set(rng)
            per_grid_dim[k] = s if per_grid_dim[k] is None else (per_grid_dim[k] & s)
            if not per_grid_dim[k]:
                feasible = False
                break
        if not feasible:
            deps.append(set())
            continue
        # Cartesian product over grid dims (unconstrained dims → full range).
        axes = [
            sorted(per_grid_dim[k]) if per_grid_dim[k] is not None
            else list(range(producer.grid[k]))
            for k in range(len(producer.grid))
        ]
        ids: set[int] = set()
        def rec(k: int, acc: int) -> None:
            if k == len(axes):
                ids.add(acc)
                return
            for v in axes[k]:
                rec(k + 1, acc + v * int(p_strides[k]))
        rec(0, 0)
        deps.append(ids)
    return deps


def _dependency_sets_enum(
    producer: Stage, consumer: Stage, buffer: str
) -> list[set[int]]:
    """Exact fallback by full enumeration (used for coupled affine maps)."""
    wmap = producer.tile_maps[buffer]
    rmap = consumer.tile_maps[buffer]
    p_regions = [wmap.region(t) for t in producer.tiles()]
    deps: list[set[int]] = []
    for ic in consumer.tiles():
        r = rmap.region(ic)
        s = set()
        for pid, w in enumerate(p_regions):
            if all(w[d][0] < r[d][1] and w[d][1] > r[d][0] for d in range(len(r))):
                s.add(pid)
        deps.append(s)
    return deps


def analyze_edge(graph: StageGraph, producer: str, consumer: str,
                 buffer: str) -> DepInfo:
    p, c = graph.stage(producer), graph.stage(consumer)
    if buffer not in p.tile_maps or buffer not in c.tile_maps:
        # No tile information: conservatively many-to-many (global sync),
        # mirroring the paper's fallback when polyhedral analysis fails.
        nt_p, nt_c = p.n_tiles(), c.n_tiles()
        deps = tuple(tuple(range(nt_p)) for _ in range(nt_c))
        return DepInfo(producer, consumer, buffer, deps,
                       max_fan_in=nt_p, max_fan_out=nt_c,
                       n_producer_tiles=nt_p, n_consumer_tiles=nt_c)
    dsets = dependency_sets(p, c, buffer)
    fan_out: dict[int, int] = {}
    for s in dsets:
        for pid in s:
            fan_out[pid] = fan_out.get(pid, 0) + 1
    return DepInfo(
        producer=producer,
        consumer=consumer,
        buffer=buffer,
        deps=tuple(tuple(sorted(s)) for s in dsets),
        max_fan_in=max((len(s) for s in dsets), default=0),
        max_fan_out=max(fan_out.values(), default=0),
        n_producer_tiles=p.n_tiles(),
        n_consumer_tiles=c.n_tiles(),
    )


def analyze_graph(graph: StageGraph) -> dict[tuple[str, str, str], DepInfo]:
    """DepInfo for every producer→consumer edge in the graph."""
    out = {}
    for p, c, b in graph.edges():
        out[(p, c, b)] = analyze_edge(graph, p, c, b)
    return out


def merge_deps(infos: Iterable[DepInfo]) -> DepInfo:
    """Union the dependency sets of one stage pair across all shared buffers
    (a consumer tile must wait for *every* buffer it reads)."""
    infos = list(infos)
    first = infos[0]
    n_c = first.n_consumer_tiles
    merged = [set() for _ in range(n_c)]
    for info in infos:
        assert info.n_consumer_tiles == n_c, "inconsistent consumer grids"
        for cid, ps in enumerate(info.deps):
            merged[cid] |= set(ps)
    fan_out: dict[int, int] = {}
    for s in merged:
        for pid in s:
            fan_out[pid] = fan_out.get(pid, 0) + 1
    return DepInfo(
        producer=first.producer,
        consumer=first.consumer,
        buffer="+".join(sorted({i.buffer for i in infos})),
        deps=tuple(tuple(sorted(s)) for s in merged),
        max_fan_in=max((len(s) for s in merged), default=0),
        max_fan_out=max(fan_out.values(), default=0),
        n_producer_tiles=first.n_producer_tiles,
        n_consumer_tiles=n_c,
    )


def merge_edge_infos(infos: Iterable[DepInfo]) -> str:
    """Combine categories across multiple shared buffers of one stage pair:
    the *most restrictive* (largest-fan) category wins."""
    order = ["few-to-few", "few-to-many", "many-to-few", "many-to-many"]
    worst = "few-to-few"
    for i in infos:
        if order.index(i.category) > order.index(worst):
            worst = i.category
    return worst
