"""The paper's evaluation suite (Table 1), rebuilt as JAX stage graphs.

| workload | key characteristic          | expected key optimization   |
|----------|-----------------------------|-----------------------------|
| bfs      | dominant kernel             | kernel (resource) balancing |
| hist     | one-to-one, long            | kernel fusion               |
| cfd      | one-to-one, short           | CKE with channels           |
| lud      | one-to-many                 | CKE with global memory      |
| bp       | splitting beneficial        | program splitting           |
| tdm      | dependency through host CPU | kernel balancing            |
| color    | one-to-one, long            | kernel fusion               |
| dijkstra | one-to-one, short           | CKE with channels           |

Each module exposes ``build(n) -> (StageGraph, buffers)`` plus the workload's
expected decision, used by tests and the Fig. 14 benchmark.
"""
from . import bfs, bp, cfd, color, dijkstra, hist, lud, tdm

ALL = {
    "bfs": bfs, "hist": hist, "cfd": cfd, "lud": lud,
    "bp": bp, "tdm": tdm, "color": color, "dijkstra": dijkstra,
}
