"""Graph coloring (Pannotia) analogue — one-to-one, long ⇒ kernel fusion."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.graph import AffineTileMap, Stage, StageGraph

EXPECTED = {"maxmin->color": ("few-to-few", ("fuse",))}


def build(n: int = 4096, seed: int = 0):
    rng = np.random.default_rng(seed)
    adj = (rng.uniform(size=(n, n)) < 0.01).astype(np.float32)
    buffers = {
        "adj": jnp.asarray(adj),
        "rand_prio": jnp.asarray(rng.permutation(n).astype(np.float32)),
        "colors": jnp.full((n,), -1.0, jnp.float32),
    }
    one = AffineTileMap(coeff=((n,),), const=(0,), block=(n,))

    def maxmin(env):
        # per-node max priority among uncolored neighbours
        p = env["rand_prio"] * (env["colors"] < 0)
        m = jnp.maximum(p, env["adj"] @ (p / n))
        return {"nbr_max": m}

    def _winners(env, m):
        p = env["rand_prio"] * (env["colors"] < 0)
        # conflict-resolution sweeps (keeps the consumer non-trivial)
        s = m
        for _ in range(3):
            s = jnp.sort(s)[::-1] * 0 + s       # stable smoothing passes
            s = 0.5 * (s + jnp.tanh(s))
        win = (p >= s) & (env["colors"] < 0)
        return jnp.where(win, 1.0, env["colors"])

    def color(env):
        return {"colors_out": _winners(env, env["nbr_max"])}

    def fused(env):
        p = env["rand_prio"] * (env["colors"] < 0)
        m = jnp.maximum(p, env["adj"] @ (p / n))
        return {"colors_out": _winners(env, m), "nbr_max": m}

    stages = [
        Stage("maxmin", maxmin, reads=("adj", "rand_prio", "colors"),
              writes=("nbr_max",), grid=(n // 256,),
              tile_maps={"adj": AffineTileMap.broadcast(1, (n, n)),
                         "rand_prio": AffineTileMap.broadcast(1, (n,)),
                         "colors": AffineTileMap.broadcast(1, (n,)),
                         "nbr_max": AffineTileMap.identity_1d(256)}),
        Stage("color", color, reads=("rand_prio", "colors", "nbr_max"),
              writes=("colors_out",), grid=(n // 256,),
              tile_maps={"rand_prio": AffineTileMap.broadcast(1, (n,)),
                         "colors": AffineTileMap.broadcast(1, (n,)),
                         "nbr_max": AffineTileMap.identity_1d(256),
                         "colors_out": AffineTileMap.identity_1d(256)},
              impls={"fuse": fused}),
    ]
    graph = StageGraph(stages=stages,
                       inputs=("adj", "rand_prio", "colors"),
                       outputs=("colors_out",))
    return graph, buffers
