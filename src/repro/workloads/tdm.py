"""Tdm (OpenDwarf, time-domain matched filter) analogue — host dependency.

The two kernels exchange data **through the host CPU** (a host-side argmax
between filter stages), so the paper's §5.2 rule excludes them from CKE;
MKPipe only applies kernel balancing (the paper's biggest Tdm win came from
searching the optimization-parameter space efficiently).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.graph import AffineTileMap, Stage, StageGraph

EXPECTED = {"filter->detect": ("sync",)}


def build(n: int = 1 << 14, taps: int = 64, seed: int = 0):
    rng = np.random.default_rng(seed)
    buffers = {
        "signal": jnp.asarray(rng.normal(size=n), jnp.float32),
        "template": jnp.asarray(rng.normal(size=taps), jnp.float32),
    }
    one = AffineTileMap(coeff=((n,),), const=(0,), block=(n,))

    def filt(env):
        return {"corr": jnp.correlate(env["signal"], env["template"],
                                      mode="same")}

    def detect(env):
        c = env["corr"]
        mu, sd = c.mean(), c.std()
        return {"peaks": (c - mu) / (sd + 1e-6)}

    stages = [
        Stage("filter", filt, reads=("signal", "template"),
              writes=("corr",), grid=(1,), mode="single",
              tile_maps={"signal": one, "corr": one,
                         "template": AffineTileMap.broadcast(1, (taps,))}),
        Stage("detect", detect, reads=("corr",), writes=("peaks",),
              grid=(1,), mode="single",
              tile_maps={"corr": one, "peaks": one}),
    ]
    graph = StageGraph(
        stages=stages, inputs=("signal", "template"), outputs=("peaks",),
        host_dependencies=(("filter", "detect"),),   # threshold picked on CPU
    )
    return graph, buffers
