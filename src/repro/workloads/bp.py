"""Backpropagation (Rodinia) analogue — program-splitting showcase (§7.3.2).

Four kernels: K1 layer-forward, K2/K3 hidden forward / output error inside
the host training loop (Fig. 17), K4 weight update.  The paper's profile:
K1 ≈ 20%, K4 ≈ 76% of total time.  MKPipe resource-balances and then splits
K4 into its own program ("bitstream"), letting both K1 and K4 be optimized
aggressively — the reduced time outweighs the reprogramming cost (1.43×).

`PAPER_PROFILE` reproduces the published percentages for the splitting
decision; `build()` provides real (small) numerics for correctness tests.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.graph import AffineTileMap, Stage, StageGraph

# times normalized to a 100 s workload with the paper's proportions
PAPER_PROFILE = {"K1": 20.0, "K2": 2.0, "K3": 2.0, "K4": 76.0}
# per-kernel utilization of the critical resource (paper Table 2: BP DSPs
# base 31% → the long-running K4 is resource-constrained)
PAPER_UTILS = {
    "K1": {"mxu": 0.30, "hbm_bw": 0.25, "vmem": 0.1, "hbm_cap": 0.1, "ici": 0},
    "K2": {"mxu": 0.10, "hbm_bw": 0.10, "vmem": 0.05, "hbm_cap": 0.1, "ici": 0},
    "K3": {"mxu": 0.10, "hbm_bw": 0.10, "vmem": 0.05, "hbm_cap": 0.1, "ici": 0},
    "K4": {"mxu": 0.55, "hbm_bw": 0.45, "vmem": 0.2, "hbm_cap": 0.2, "ici": 0},
}
EXPECTED = {"split": ("K4",)}


def build(d_in: int = 256, d_h: int = 128, batch: int = 64, seed: int = 0):
    rng = np.random.default_rng(seed)
    buffers = {
        "x": jnp.asarray(rng.normal(size=(batch, d_in)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(batch, 1)), jnp.float32),
        "w1": jnp.asarray(rng.normal(size=(d_in, d_h)) / 16, jnp.float32),
        "w2": jnp.asarray(rng.normal(size=(d_h, 1)) / 16, jnp.float32),
    }

    def k1(env):
        return {"h": jnp.tanh(env["x"] @ env["w1"])}

    def k2(env):
        return {"o": env["h"] @ env["w2"]}

    def k3(env):
        err_o = env["o"] - env["y"]
        err_h = (err_o @ env["w2"].T) * (1 - env["h"] ** 2)
        return {"err_o": err_o, "err_h": err_h}

    def k4(env):
        lr = 1e-2
        return {"w1_out": env["w1"] - lr * env["x"].T @ env["err_h"],
                "w2_out": env["w2"] - lr * env["h"].T @ env["err_o"]}

    bm = lambda shape: AffineTileMap.broadcast(1, shape)
    stages = [
        Stage("K1", k1, reads=("x", "w1"), writes=("h",), grid=(1,),
              tile_maps={"x": bm((batch, d_in)), "w1": bm((d_in, d_h)),
                         "h": bm((batch, d_h))}),
        Stage("K2", k2, reads=("h", "w2"), writes=("o",), grid=(1,),
              tile_maps={"h": bm((batch, d_h)), "w2": bm((d_h, 1)),
                         "o": bm((batch, 1))}),
        Stage("K3", k3, reads=("o", "y", "w2", "h"),
              writes=("err_o", "err_h"), grid=(1,),
              tile_maps={"o": bm((batch, 1)), "y": bm((batch, 1)),
                         "w2": bm((d_h, 1)), "h": bm((batch, d_h)),
                         "err_o": bm((batch, 1)),
                         "err_h": bm((batch, d_h))}),
        Stage("K4", k4, reads=("x", "h", "err_o", "err_h", "w1", "w2"),
              writes=("w1_out", "w2_out"), grid=(1,),
              tile_maps={"x": bm((batch, d_in)), "h": bm((batch, d_h)),
                         "err_o": bm((batch, 1)),
                         "err_h": bm((batch, d_h)),
                         "w1": bm((d_in, d_h)), "w2": bm((d_h, 1)),
                         "w1_out": bm((d_in, d_h)),
                         "w2_out": bm((d_h, 1))}),
    ]
    graph = StageGraph(
        stages=stages,
        inputs=("x", "y", "w1", "w2"),
        outputs=("w1_out", "w2_out"),
        loops={"train_loop": (("K2", "K3"), 8)},   # paper Fig. 17
    )
    return graph, buffers
