"""Dijkstra / SSSP (Pannotia) analogue — one-to-one, *short* kernels ⇒
CKE with channels (paper: "Dijkstra benefits from CKE with channel due to
the low execution time of its kernels", Fig. 8 launch-overhead effect).

The graph is a circulant (banded) lattice: vertex v's in-neighbors are
v-1..v-k, so one relaxation sweep is k shifted add+min passes — no dense
(n, n) matrix, matching Pannotia's sparse adjacency.  The select kernel
does the algorithm's real per-sweep bookkeeping (distance update + count
of relaxed vertices for the host's convergence check), which keeps both
kernels short *and* comparable — the profile regime where the Fig. 5 tree
picks channels rather than declaring a dominant kernel.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.graph import AffineTileMap, Stage, StageGraph

EXPECTED = {"relax->select": ("few-to-few", ("channel",))}


def build(n: int = 8192, k: int = 4, seed: int = 0):
    rng = np.random.default_rng(seed)
    # w[j, v] = weight of the edge (v-1-j) -> v  (circulant band)
    w = rng.uniform(1, 10, size=(k, n)).astype(np.float32)
    buffers = {
        "w": jnp.asarray(w),
        "dist": jnp.asarray(
            np.where(np.arange(n) == 0, 0.0, 1e9).astype(np.float32)),
    }

    def _sweep(dist, w):
        # cand[v] = min_j dist[v-1-j] + w[j, v]
        cands = jnp.stack([jnp.roll(dist, j + 1)
                           for j in range(w.shape[0])]) + w
        return jnp.min(cands, axis=0)

    def relax(env):
        return {"cand": _sweep(env["dist"], env["w"])}

    def select(env):
        nd = jnp.minimum(env["dist"], env["cand"])
        changed = (nd < env["dist"]).astype(jnp.float32)
        return {"dist_out": nd, "n_changed": jnp.sum(changed)[None]}

    def fused(env):
        cand = _sweep(env["dist"], env["w"])
        nd = jnp.minimum(env["dist"], cand)
        changed = (nd < env["dist"]).astype(jnp.float32)
        return {"dist_out": nd, "n_changed": jnp.sum(changed)[None],
                "cand": cand}

    stages = [
        Stage("relax", relax, reads=("w", "dist"), writes=("cand",),
              grid=(n // 128,),
              tile_maps={"w": AffineTileMap.broadcast(1, (k, n)),
                         "dist": AffineTileMap.broadcast(1, (n,)),
                         "cand": AffineTileMap.identity_1d(128)}),
        Stage("select", select, reads=("dist", "cand"),
              writes=("dist_out", "n_changed"), grid=(n // 128,),
              tile_maps={"dist": AffineTileMap.broadcast(1, (n,)),
                         "cand": AffineTileMap.identity_1d(128),
                         "dist_out": AffineTileMap.identity_1d(128),
                         "n_changed": AffineTileMap.broadcast(1, (1,))},
              impls={"channel": fused, "fuse": fused}),
    ]
    graph = StageGraph(stages=stages, inputs=("w", "dist"),
                       outputs=("dist_out",))
    return graph, buffers
