"""Dijkstra / SSSP (Pannotia) analogue — one-to-one, *short* kernels ⇒
CKE with channels (paper: "Dijkstra benefits from CKE with channel due to
the low execution time of its kernels", Fig. 8 launch-overhead effect)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.graph import AffineTileMap, Stage, StageGraph

EXPECTED = {"relax->select": ("few-to-few", ("channel",))}


def build(n: int = 512, seed: int = 0):
    rng = np.random.default_rng(seed)
    w = rng.uniform(1, 10, size=(n, n)).astype(np.float32)
    w[rng.uniform(size=(n, n)) > 0.05] = 1e9        # sparse-ish
    buffers = {
        "w": jnp.asarray(w),
        "dist": jnp.asarray(
            np.where(np.arange(n) == 0, 0.0, 1e9).astype(np.float32)),
    }
    one = AffineTileMap(coeff=((n,),), const=(0,), block=(n,))

    def relax(env):
        # one relaxation sweep: cand[v] = min_u dist[u] + w[u,v]
        return {"cand": jnp.min(env["dist"][:, None] + env["w"], axis=0)}

    def select(env):
        return {"dist_out": jnp.minimum(env["dist"], env["cand"])}

    def fused(env):
        cand = jnp.min(env["dist"][:, None] + env["w"], axis=0)
        return {"dist_out": jnp.minimum(env["dist"], cand), "cand": cand}

    stages = [
        Stage("relax", relax, reads=("w", "dist"), writes=("cand",),
              grid=(n // 128,),
              tile_maps={"w": AffineTileMap.broadcast(1, (n, n)),
                         "dist": AffineTileMap.broadcast(1, (n,)),
                         "cand": AffineTileMap.identity_1d(128)}),
        Stage("select", select, reads=("dist", "cand"),
              writes=("dist_out",), grid=(n // 128,),
              tile_maps={"dist": AffineTileMap.broadcast(1, (n,)),
                         "cand": AffineTileMap.identity_1d(128),
                         "dist_out": AffineTileMap.identity_1d(128)},
              impls={"channel": fused, "fuse": fused}),
    ]
    graph = StageGraph(stages=stages, inputs=("w", "dist"),
                       outputs=("dist_out",))
    return graph, buffers
