"""LUD (Rodinia) analogue — paper Figs. 9-12: the id-remapping showcase.

Blocked right-looking LU step: a `perimeter` kernel produces the row panel
and column panel for every block index b, and an `internal` kernel updates
trailing block (i, j) with `m[i,j] − rowp[i] @ colp[j]`.

Dependency (paper Fig. 11): internal tile (i, j) needs perimeter tiles
{i, j} → fan-in 2 ("few"), while perimeter tile b feeds every (b, *) and
(*, b) → fan-out ~2·nb ("many") ⇒ **few-to-many ⇒ CKE through global
memory**, and the natural row-major consumer order stalls: (0,2) waits for
perimeter 2 while (1,0),(1,1) are already ready.  The id_queue reorders
consumers into the wavefront max(i,j) = 0, 1, 2, … exactly as in the paper.

The NaN-poisoned chunked executor makes this executable proof: running
consumer tiles in queue order against a partially-written panel buffer
yields bit-correct results only if the queue is dependency-legal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.graph import AffineTileMap, Stage, StageGraph

B = 32                     # block size (paper's BSIZE)
EXPECTED = {"perimeter->internal": ("few-to-many", ("globalmem",))}


def build(nb: int = 8, seed: int = 0):
    n = nb * B
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.normal(size=(n, n)).astype(np.float32))
    buffers = {"m": m}

    def perimeter(env):
        mm = env["m"]
        blocks = mm.reshape(nb, B, nb, B)
        # row panel for b: normalized diagonal-block transform of block row b
        diag = jnp.einsum("bibj->bij", blocks)            # (nb, B, B)
        rowp = jnp.tanh(diag) / B                          # (nb, B, B)
        colp = jnp.tanh(jnp.swapaxes(diag, 1, 2)) / B      # (nb, B, B)
        return {"rowp": rowp.reshape(nb * B, B),
                "colp": colp.reshape(nb * B, B)}

    def internal(env):
        mm = env["m"]
        rowp = env["rowp"].reshape(nb, B, B)
        colp = env["colp"].reshape(nb, B, B)
        blocks = mm.reshape(nb, B, nb, B).transpose(0, 2, 1, 3)  # (i,j,B,B)
        upd = blocks - jnp.einsum("iab,jbc->ijac", rowp, colp)
        return {"out": upd.transpose(0, 2, 1, 3).reshape(n, n)}

    # tile-wise impls for the chunked (global-memory CKE) executor
    def perimeter_tile(env, b):
        mm = env["m"]
        db = jax.lax.dynamic_slice(mm, (b * B, b * B), (B, B))
        return {"rowp": jnp.tanh(db) / B, "colp": jnp.tanh(db.T) / B}

    def internal_tile(env, flat):
        i, j = flat // nb, flat % nb
        mm = env["m"]
        blk = jax.lax.dynamic_slice(mm, (i * B, j * B), (B, B))
        ri = jax.lax.dynamic_slice(env["rowp"], (i * B, 0), (B, B))
        cj = jax.lax.dynamic_slice(env["colp"], (j * B, 0), (B, B))
        return {"out": blk - ri @ cj}

    stages = [
        Stage("perimeter", perimeter,
              reads=("m",), writes=("rowp", "colp"), grid=(nb,),
              tile_maps={
                  "m": AffineTileMap(coeff=((B,), (B,)), const=(0, 0),
                                     block=(B, B)),
                  "rowp": AffineTileMap(coeff=((B,), (0,)), const=(0, 0),
                                        block=(B, B)),
                  "colp": AffineTileMap(coeff=((B,), (0,)), const=(0, 0),
                                        block=(B, B)),
              },
              impls={"tile": perimeter_tile}),
        Stage("internal", internal,
              reads=("m", "rowp", "colp"), writes=("out",), grid=(nb, nb),
              tile_maps={
                  "m": AffineTileMap(coeff=((B, 0), (0, B)), const=(0, 0),
                                     block=(B, B)),
                  # internal (i,j) reads rowp rows of block i …
                  "rowp": AffineTileMap(coeff=((B, 0), (0, 0)), const=(0, 0),
                                        block=(B, B)),
                  # … and colp rows of block j
                  "colp": AffineTileMap(coeff=((0, B), (0, 0)), const=(0, 0),
                                        block=(B, B)),
                  "out": AffineTileMap(coeff=((B, 0), (0, B)), const=(0, 0),
                                       block=(B, B)),
              },
              impls={"tile": internal_tile}),
    ]
    graph = StageGraph(stages=stages, inputs=("m",), outputs=("out",))
    return graph, buffers
