"""Histogram (Spector) analogue — one-to-one producer/consumer ⇒ fusion.

Producer computes bin values per element; consumer accumulates a histogram
(single-workitem reduction loop, like the paper's rewritten Hist_SI).  The
grids match and the run is long ⇒ the Fig. 5 tree picks **kernel fusion**,
which removes the `vals` HBM round-trip (paper: 1.7× on Hist_SI).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.graph import AffineTileMap, Stage, StageGraph

BLOCK = 1024
NBINS = 64
EXPECTED = {"compute->accumulate": ("few-to-few", ("fuse",))}


def build(n: int = 1 << 22, seed: int = 0):
    assert n % BLOCK == 0
    rng = np.random.default_rng(seed)
    buffers = {"img": jnp.asarray(rng.uniform(0, 1, n), jnp.float32)}
    grid = (n // BLOCK,)
    one = AffineTileMap(coeff=((BLOCK,),), const=(0,), block=(BLOCK,))

    def _binval(x):
        # sRGB decode to linear light, then display-gamma re-encode for
        # binning: two transcendental passes, so the compute kernel is a
        # non-trivial fraction of the scatter-heavy accumulate (the
        # paper's Hist profile — no single dominant kernel)
        lin = jnp.where(x > 0.04045,
                        jnp.power((x + 0.055) / 1.055, 2.4), x / 12.92)
        enc = jnp.where(lin > 0.0031308,
                        1.055 * jnp.power(lin, 1 / 2.4) - 0.055,
                        12.92 * lin)
        return jnp.clip(enc * NBINS, 0, NBINS - 1)

    def compute(env):
        return {"vals": _binval(env["img"])}

    def accumulate(env):
        bins = env["vals"].astype(jnp.int32)
        return {"hist": jnp.zeros(NBINS, jnp.int32).at[bins].add(1)}

    def fused(env):
        vals = _binval(env["img"])
        return {"hist": jnp.zeros(NBINS, jnp.int32)
                .at[vals.astype(jnp.int32)].add(1),
                "vals": vals}

    stages = [
        Stage("compute", compute, reads=("img",), writes=("vals",),
              grid=grid, mode="single",
              tile_maps={"img": one, "vals": one}),
        Stage("accumulate", accumulate, reads=("vals",), writes=("hist",),
              grid=grid, mode="single",
              tile_maps={"vals": one,
                         "hist": AffineTileMap.broadcast(1, (NBINS,))},
              impls={"fuse": fused}),
    ]
    graph = StageGraph(stages=stages, inputs=("img",), outputs=("hist",))
    return graph, buffers
