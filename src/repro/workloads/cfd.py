"""CFD (Rodinia) analogue — paper Figs. 1/4/6/7 and §7.3.1.

Three kernels: K1 `compute_step_factor` (ends with a global sync — its
output feeds *all* downstream iterations), K2 `compute_flux`, K3 `time_step`.
K2→K3 is one-to-one at the iteration level (`fluxes[i]` produced by i,
consumed by i), so MKPipe enables CKE between K2 and K3 — choosing channels
when the execution time is short (§5.4.2) — while K1 keeps its sync.

The arithmetic is a faithful miniature of Rodinia CFD's Euler solver update:
per-element flux from density/momentum/energy plus a relaxation time step.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.graph import AffineTileMap, Stage, StageGraph

BLOCK = 256
EXPECTED = {"K2->K3": ("few-to-few", ("channel", "fuse"))}


def _one_to_one(n: int) -> AffineTileMap:
    return AffineTileMap(coeff=((BLOCK,),), const=(0,), block=(BLOCK,))


def build(n: int = 4096, seed: int = 0):
    assert n % BLOCK == 0
    rng = np.random.default_rng(seed)
    buffers = {
        "density": jnp.asarray(rng.uniform(0.5, 2.0, n), jnp.float32),
        "momentum": jnp.asarray(rng.uniform(-1.0, 1.0, n), jnp.float32),
        "energy": jnp.asarray(rng.uniform(1.0, 3.0, n), jnp.float32),
    }
    grid = (n // BLOCK,)

    def k1(env):
        # step factor ~ CFL condition: 0.5 / (speed of sound-ish)
        c = jnp.sqrt(jnp.abs(1.4 * env["energy"] / env["density"]) + 1e-6)
        return {"step_factor": 0.5 / (c + jnp.abs(env["momentum"]))}

    def k2(env):
        v = env["momentum"] / env["density"]
        p = 0.4 * (env["energy"] - 0.5 * env["momentum"] * v)
        return {"fluxes": env["momentum"] * v + p}

    def k3(env):
        return {"v_out": env["energy"] + env["step_factor"] * env["fluxes"]}

    def k2k3_fused(env):
        # paper Fig. 6: loop fusion removes the fluxes round-trip
        v = env["momentum"] / env["density"]
        p = 0.4 * (env["energy"] - 0.5 * env["momentum"] * v)
        fluxes = env["momentum"] * v + p
        return {"v_out": env["energy"] + env["step_factor"] * fluxes,
                "fluxes": fluxes}

    stages = [
        Stage("compute_step_factor", k1,
              reads=("density", "momentum", "energy"),
              writes=("step_factor",), grid=grid, mode="single",
              tile_maps={b: _one_to_one(n) for b in
                         ("density", "momentum", "energy", "step_factor")}),
        Stage("compute_flux", k2,
              reads=("density", "momentum", "energy"),
              writes=("fluxes",), grid=grid, mode="single",
              tile_maps={b: _one_to_one(n) for b in
                         ("density", "momentum", "energy", "fluxes")}),
        Stage("time_step", k3,
              reads=("energy", "step_factor", "fluxes"),
              writes=("v_out",), grid=grid, mode="single",
              tile_maps={b: _one_to_one(n) for b in
                         ("energy", "step_factor", "fluxes", "v_out")},
              impls={"fuse": k2k3_fused, "channel": k2k3_fused}),
    ]
    graph = StageGraph(
        stages=stages,
        inputs=("density", "momentum", "energy"),
        outputs=("v_out",),
        # K1 feeds everything downstream in the real solver's outer loop →
        # the paper ends K1 with a global synchronization (§5.5: "K1 should
        # be ended with a global synchronization").
        host_dependencies=(("compute_step_factor", "compute_flux"),
                           ("compute_step_factor", "time_step")),
    )
    return graph, buffers
