"""BFS (Spector) analogue — dominant-kernel case ⇒ balancing only.

The frontier-expansion kernel takes ~96% of the time (paper: 95.8%), so the
Fig. 5 tree short-circuits: CKE has no leverage; MKPipe applies resource
balancing across the kernels instead (paper speedup 1.1×).

Graph: `expand` (dense frontier × adjacency matmul — the hot kernel) and
`update` (visited-mask update).  Implemented densely so times are stable.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..core.graph import AffineTileMap, Stage, StageGraph

EXPECTED = {"dominant": "expand"}


def build(n: int = 2048, seed: int = 0):
    rng = np.random.default_rng(seed)
    adj = (rng.uniform(size=(n, n)) < 0.05).astype(np.float32)
    buffers = {
        "adj": jnp.asarray(adj),
        "frontier": jnp.asarray(
            (rng.uniform(size=n) < 0.1).astype(np.float32)),
        "visited": jnp.zeros(n, jnp.float32),
    }

    def expand(env):
        f = env["frontier"]
        # several sparse-to-dense hops to make this the dominant kernel
        for _ in range(24):
            f = jnp.tanh(env["adj"] @ f)
        return {"reached": f}

    def update(env):
        nv = jnp.maximum(env["visited"], (env["reached"] > 0.05) * 1.0)
        return {"visited_out": nv}

    one = AffineTileMap(coeff=((n,),), const=(0,), block=(n,))
    stages = [
        Stage("expand", expand, reads=("adj", "frontier"),
              writes=("reached",), grid=(1,),
              tile_maps={"adj": AffineTileMap.broadcast(1, (n, n)),
                         "frontier": one, "reached": one}),
        Stage("update", update, reads=("visited", "reached"),
              writes=("visited_out",), grid=(1,),
              tile_maps={"visited": one, "reached": one,
                         "visited_out": one}),
    ]
    graph = StageGraph(stages=stages,
                       inputs=("adj", "frontier", "visited"),
                       outputs=("visited_out",))
    return graph, buffers
