"""Pipeline parallelism: stage balancing (Alg. 1) + a shard_map executor.

`balance_stages` is the MKPipe throughput-balancing idea applied across
devices: partition a chain of layers into contiguous stages so the slowest
stage — the pipeline's bottleneck kernel — is as fast as possible.  It is
the exact linear-partition DP, not a greedy split, because a heavy tail
(e.g. MoE layers at the end of a hybrid stack) makes greedy splits
arbitrarily bad.

`pipeline_apply` runs inside `shard_map` over a ``"stage"`` axis: stage
params arrive sharded with a leading per-stage dim of 1, activations are
passed stage-to-stage through collectives, and the final activations come
back replicated.  It is the numerics oracle for pipeline placement (every
stage computes every tick; scheduling efficiency is modeled separately by
`pipeline_bubble_fraction`).
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Tree = Any


def balance_stages(times: Sequence[float], n_stages: int) -> list[int]:
    """Partition `times` into `n_stages` contiguous groups minimizing the
    max group sum.  Returns group sizes (every group non-empty)."""
    n = len(times)
    if not 1 <= n_stages <= n:
        raise ValueError(f"need 1 <= n_stages={n_stages} <= n_layers={n}")
    prefix = [0.0, *itertools.accumulate(times)]

    # best[k][i]: minimal max-stage-time for the first i layers in k stages
    inf = float("inf")
    best = [[inf] * (n + 1) for _ in range(n_stages + 1)]
    cut = [[0] * (n + 1) for _ in range(n_stages + 1)]
    best[0][0] = 0.0
    for k in range(1, n_stages + 1):
        for i in range(k, n + 1):
            for j in range(k - 1, i):
                cand = max(best[k - 1][j], prefix[i] - prefix[j])
                # strict < keeps the earliest (most front-loaded) optimal
                # cut, so ties put extra layers on earlier stages
                if cand < best[k][i]:
                    best[k][i] = cand
                    cut[k][i] = j
    sizes: list[int] = []
    i = n
    for k in range(n_stages, 0, -1):
        j = cut[k][i]
        sizes.append(i - j)
        i = j
    return sizes[::-1]


def pipeline_bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe fill/drain bubble: (S-1) / (M + S-1) of device-ticks idle."""
    if n_micro < 1 or n_stages < 1:
        raise ValueError("need n_micro >= 1 and n_stages >= 1")
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn: Callable[[Tree, Any], Any], stage_params: Tree,
                   x: Any, axis: str = "stage") -> Any:
    """Apply `n_stages` stages sequentially under shard_map.

    stage_params: pytree whose leaves are sharded over `axis` with a
    leading per-stage dim (locally 1); `stage_fn(params, x)` computes one
    stage from the unstacked local params.  `x` must arrive replicated and
    the result is replicated — stage s's output is broadcast each tick, so
    the value entering stage s+1 is exactly the sequential composition.
    """
    idx = jax.lax.axis_index(axis)
    n_stages = jax.lax.psum(1, axis)          # static under shard_map
    local = jax.tree.map(lambda p: p[0], stage_params)
    for s in range(n_stages):
        y = stage_fn(local, x)
        # keep only stage s's output and hand it to everyone (the
        # numerics-oracle form of the stage-to-stage ppermute)
        x = jax.lax.psum(jnp.where(idx == s, y, jnp.zeros_like(y)), axis)
    return x


def pipeline_apply_microbatched(stage_fn: Callable[..., Tree],
                                stage_params: Tree, x: Tree, n_micro: int,
                                axis: str = "stage",
                                static: Tree | None = None) -> Tree:
    """The GPipe fill/drain schedule under shard_map: the scheduling form
    whose efficiency `pipeline_bubble_fraction` models.

    `x` is a pytree whose leaves all carry a leading batch dim divisible by
    `n_micro`; it is split into `n_micro` microbatches, and stage s
    processes microbatch m at tick t = s + m, with activations moving
    stage-to-stage through a ring `ppermute` (the GLOBALMEM channel of the
    paper, across devices).  `stage_fn(local_params, x) -> x` must preserve
    the tree structure (residual-stream style).  Every device computes on
    every tick — fill/drain ticks compute garbage that is masked out — so
    wall-clock cost scales with the (M + S - 1) · S device-tick area and
    the measured bubble can be compared against the analytic model.

    `static` is an optional batch-leading tree of per-microbatch side
    inputs the stages *read* but don't produce (e.g. encoder output for
    cross-attention): it is not rotated through the ring — each device
    locally indexes the slice of its in-flight microbatch (t - s) and
    `stage_fn(local_params, x, static_mb)` receives it as a third
    argument.

    Per microbatch the op sequence is exactly the sequential composition of
    the stages, and the whole schedule is reverse-mode differentiable
    (ppermute/psum transposes carry gradients stage-to-stage backwards).
    The result is replicated over `axis`.
    """
    if n_micro < 1:
        raise ValueError(f"need n_micro >= 1, got {n_micro}")
    idx = jax.lax.axis_index(axis)
    n_stages = jax.lax.psum(1, axis)          # static under shard_map
    local = jax.tree.map(lambda p: p[0], stage_params)
    M = int(n_micro)

    def split(leaf):
        if leaf.shape[0] % M:
            raise ValueError(
                f"batch dim {leaf.shape[0]} not divisible by n_micro={M}")
        return leaf.reshape(M, leaf.shape[0] // M, *leaf.shape[1:])

    x_mb = jax.tree.map(split, x)
    static_mb = (None if static is None
                 else jax.tree.map(split, static))
    state = jax.tree.map(lambda l: jnp.zeros_like(l[0]), x_mb)
    outbuf = jax.tree.map(jnp.zeros_like, x_mb)
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def tick(carry, t):
        state, outbuf = carry
        # stage 0 injects microbatch t (clipped re-injections during drain
        # compute garbage whose outputs never reach the last stage in time)
        m_in = jnp.clip(t, 0, M - 1)
        state = jax.tree.map(
            lambda buf, s: jnp.where(
                idx == 0,
                jax.lax.dynamic_index_in_dim(buf, m_in, 0, keepdims=False),
                s),
            x_mb, state)
        if static_mb is None:
            y = stage_fn(local, state)
        else:
            # this device's in-flight microbatch is t - s; fill/drain
            # ticks index a clipped slot whose outputs are masked anyway
            m_cur = jnp.clip(t - idx, 0, M - 1)
            s_cur = jax.tree.map(
                lambda buf: jax.lax.dynamic_index_in_dim(
                    buf, m_cur, 0, keepdims=False), static_mb)
            y = stage_fn(local, state, s_cur)
        # the last stage completes microbatch t - (S-1) on this tick
        m_out = jnp.clip(t - (n_stages - 1), 0, M - 1)
        take = jnp.logical_and(idx == n_stages - 1, t >= n_stages - 1)

        def write(buf, yl):
            cur = jax.lax.dynamic_index_in_dim(buf, m_out, 0, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(
                buf, jnp.where(take, yl, cur), m_out, 0)

        outbuf = jax.tree.map(write, outbuf, y)
        state = jax.tree.map(lambda l: jax.lax.ppermute(l, axis, perm), y)
        return (state, outbuf), None

    n_ticks = M + n_stages - 1
    (_, outbuf), _ = jax.lax.scan(tick, (state, outbuf),
                                  jnp.arange(n_ticks))
    out = jax.tree.map(
        lambda buf: jax.lax.psum(
            jnp.where(idx == n_stages - 1, buf, jnp.zeros_like(buf)), axis),
        outbuf)
    return jax.tree.map(
        lambda l: l.reshape(l.shape[0] * l.shape[1], *l.shape[2:]), out)
