"""Pipeline parallelism: stage balancing (Alg. 1) + a shard_map executor.

`balance_stages` is the MKPipe throughput-balancing idea applied across
devices: partition a chain of layers into contiguous stages so the slowest
stage — the pipeline's bottleneck kernel — is as fast as possible.  It is
the exact linear-partition DP, not a greedy split, because a heavy tail
(e.g. MoE layers at the end of a hybrid stack) makes greedy splits
arbitrarily bad.

`pipeline_apply` runs inside `shard_map` over a ``"stage"`` axis: stage
params arrive sharded with a leading per-stage dim of 1, activations are
passed stage-to-stage through collectives, and the final activations come
back replicated.  It is the numerics oracle for pipeline placement (every
stage computes every tick; scheduling efficiency is modeled separately by
`pipeline_bubble_fraction`).

Every executor here names **only the stage axis** in its own collectives
(the stage-to-stage ppermute rings, the n_stages probe, the
replicated-output psum epilogue) — both the GPipe scan-transpose backward
and the 1F1B custom-VJP/stash path.  That is what lets pipeline stages
compose with tensor parallelism: on a ``("stage", "data", "model")`` mesh
the same schedules run unchanged while `stage_fn`'s block math carries
its *own* collectives over the other manual axes (e.g. explicit
``psum("model")`` after row-parallel projections — see
`repro.models.layers` and `repro.dist.context.manual_tp_size`), and the
rotated activations stay replicated over data/model so stage-axis
ppermute bytes are independent of the tp degree.

Scheduling (see docs/pipeline-schedules.md for diagrams and formulas):

- `pipeline_apply_microbatched(schedule="gpipe"|"1f1b"|"interleaved")`
  — the microbatched forward executor; GPipe differentiates through the
  scan, 1F1B attaches a custom VJP whose backward is an explicit step
  program with a stash/pop activation buffer, and interleaved composes
  `virtual_stages` 1F1B chunk passes (device s holds chunks of virtual
  stages q = c·S + s).
- `make_step_program` / `program_peak_inflight` — the statically
  unrolled per-tick (op, microbatch[, chunk]) schedule and its
  stash-occupancy simulator.
- `pipeline_train_microbatched` — the fused forward+backward executor
  (loss inside the schedule) that realizes 1F1B's min(M, S) activation
  bound — and, for ``schedule="interleaved"``, the reduced
  (S-1)/(vM+S-1) bubble with an optional double-buffered activation
  ppermute (``overlap=True``); `pipeline_bubble_fraction` and
  `pipeline_peak_inflight` / `pipeline_peak_activation_bytes` are the
  matching analytic models.
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Tree = Any


def balance_stages(times: Sequence[float], n_stages: int) -> list[int]:
    """Partition `times` into `n_stages` contiguous groups minimizing the
    max group sum.  Returns group sizes (every group non-empty).

    Ties are front-loaded: among the optimal partitions the last group is
    as small as any of them allows, recursively for the prefix at its own
    optimum, so extra layers land on earlier stages — e.g.
    ``balance_stages([1]*4, 3) == [2, 1, 1]``.  `plan_pipeline` relies on
    this so padded per-stage stacks pad the *tail* stages.
    """
    n = len(times)
    if not 1 <= n_stages <= n:
        raise ValueError(f"need 1 <= n_stages={n_stages} <= n_layers={n}")
    prefix = [0.0, *itertools.accumulate(times)]

    # best[k][i]: minimal max-stage-time for the first i layers in k stages
    inf = float("inf")
    best = [[inf] * (n + 1) for _ in range(n_stages + 1)]
    cut = [[0] * (n + 1) for _ in range(n_stages + 1)]
    best[0][0] = 0.0
    for k in range(1, n_stages + 1):
        for i in range(k, n + 1):
            for j in range(k - 1, i):
                cand = max(best[k - 1][j], prefix[i] - prefix[j])
                # <= keeps the *latest* optimal cut, so the trailing group
                # is as small as possible and ties front-load: extra
                # layers go to earlier stages
                if cand <= best[k][i]:
                    best[k][i] = cand
                    cut[k][i] = j
    sizes: list[int] = []
    i = n
    for k in range(n_stages, 0, -1):
        j = cut[k][i]
        sizes.append(i - j)
        i = j
    return sizes[::-1]


# The analytic schedule models (bubble fraction, peak inflight/activation
# bytes, the step-program stash simulator) and the PIPE_* op codes live in
# `repro.analysis.costmodel` — the unified cost-model API — and are
# re-exported here so existing import sites keep working.  This module
# keeps the *executors*; the pricing moved behind the typed surface.
from repro.analysis.costmodel import (  # noqa: F401  (re-exports)
    PIPE_BWD,
    PIPE_FWD,
    PIPE_IDLE,
    SCHEDULES,
    _check_virtual_stages,
    _program_books,
    pipeline_bubble_fraction,
    pipeline_peak_activation_bytes,
    pipeline_peak_inflight,
    program_peak_inflight,
)

# ------------------------------------------------------- step programs
# One pipeline tick = one stage executing one micro-step (a forward or a
# backward of one microbatch) while activations ppermute stage s → s+1
# and cotangents ppermute s → s-1.  A *step program* fixes, per tick and
# per stage, which micro-step runs — the statically unrolled schedule the
# executors scan over.  Flat schedules use (op, m) entries; interleaved
# programs use (op, m, c) with c the chunk index (virtual stage
# q = c·S + s lives on device s).  Op codes PIPE_IDLE/PIPE_FWD/PIPE_BWD
# are defined in `repro.analysis.costmodel` (imported above).


def make_step_program(n_micro: int, n_stages: int,
                      schedule: str = "1f1b", virtual_stages: int = 1,
                      overlap: bool = False) -> list[list[tuple]]:
    """Build the per-tick step program for a schedule.

    Returns a list over ticks; each tick is a list over stages of
    ``(op, m)`` — or ``(op, m, c)`` for interleaved programs, with c the
    chunk index — where op ∈ {PIPE_IDLE, PIPE_FWD, PIPE_BWD} and m is
    the microbatch index (0 for idle slots).  Every program satisfies,
    by construction (on *virtual* stages q = c·S + s for interleaved):

    - F(q, m) runs ≥ 1 tick after F(q-1, m) (activations arrive by ring
      ppermute with one tick of latency; ≥ 2 ticks under
      ``overlap=True``, whose executor double-buffers the activation
      transfer);
    - B(q, m) runs exactly 1 tick after B(q+1, m) (cotangents arrive the
      tick they are consumed, so no cotangent buffering is needed);
    - B(V-1, m) runs ≥ 1 tick after F(V-1, m), V = v·S.

    Both flat schedules span exactly 2·(M + S - 1) ticks — same bubble.
    GPipe: all forwards (stage s runs F(m) at tick s + m), then all
    backwards (B(m) at tick (M+S-1) + m + (S-1-s)).  1F1B: stage s runs
    min(M, S-s) warmup forwards back-to-back from tick s, then strictly
    alternates backward/forward — F(s, m) at tick 2m + s once steady,
    B(s, m) at tick 2S-1-s + 2m — so its stash never holds more than
    min(M, S-s) microbatches (`pipeline_peak_inflight`).

    ``schedule="interleaved"`` builds the Megatron-style interleaved
    1F1B program over V = virtual_stages·S virtual stages: a greedy
    tick-by-tick scheduler commits each microbatch's exact backward
    chain as soon as its last virtual-stage forward has landed and the
    whole diagonal fits, then fills free devices with ready forwards
    (deepest chunk first, throttled to the analytic stash bound).  The
    span approaches the ideal 2·(vM + S - 1) chunk ticks, dropping the
    bubble toward (S-1)/(vM+S-1); ``virtual_stages=1`` (without
    ``overlap``) returns literally the flat 1f1b program.
    """
    M, S = int(n_micro), int(n_stages)
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; want {SCHEDULES}")
    if M < 1 or S < 1:
        raise ValueError("need n_micro >= 1 and n_stages >= 1")
    v = _check_virtual_stages(schedule, virtual_stages)
    if overlap and schedule != "interleaved":
        raise ValueError(
            f"overlap=True (double-buffered activation ppermute) requires "
            f"schedule='interleaved', got {schedule!r}")
    if overlap and v == 1:
        raise ValueError(
            "overlap=True requires virtual_stages >= 2: with one chunk "
            "per device interleaved degenerates *exactly* to plain 1f1b, "
            "and the stretched transfer latency would break that")
    if schedule == "interleaved":
        if v == 1:
            # exact degeneration: one chunk per device IS plain 1f1b
            return make_step_program(M, S, "1f1b")
        prog = _make_interleaved_program(M, S, v, f_lat=2 if overlap else 1)
        _check_program(prog, M, S, schedule=schedule, virtual_stages=v)
        return prog
    T = 2 * (M + S - 1)
    prog = [[(PIPE_IDLE, 0)] * S for _ in range(T)]

    def put(t, s, op, m):
        prev_op, prev_m = prog[t][s]
        if prev_op != PIPE_IDLE:
            # a real raise (asserts vanish under python -O) naming the
            # schedule/tick/stage/microbatch, matching mklint's wording
            prev = "F" if prev_op == PIPE_FWD else "B"
            this = "F" if op == PIPE_FWD else "B"
            raise ValueError(
                f"make_step_program({schedule!r}): tick {t} stage {s} "
                f"already runs {prev}(microbatch={prev_m}), cannot also "
                f"run {this}(microbatch={m}) — one micro-step per stage "
                "per tick")
        prog[t][s] = (op, m)

    for s in range(S):
        warm = min(M, S - s)
        for m in range(M):
            if schedule == "gpipe":
                put(s + m, s, PIPE_FWD, m)
                put((M + S - 1) + m + (S - 1 - s), s, PIPE_BWD, m)
            else:
                put(s + m if m < warm else 2 * m + s, s, PIPE_FWD, m)
                put(2 * S - 1 - s + 2 * m, s, PIPE_BWD, m)
    _check_program(prog, M, S, schedule=schedule)
    return prog


def _make_interleaved_program(M: int, S: int, v: int,
                              f_lat: int = 1) -> list[list[tuple]]:
    """Greedy constructive interleaved-1F1B scheduler (see
    `make_step_program`).

    Virtual stage q = c·S + s runs on device s = q mod S, so *every*
    boundary transfer — chunk wraps S-1 → 0 included — rides the same
    uniform ring ppermute the flat executors use.  Per tick, in order:

    1. **Commit the next backward diagonal** (FIFO by microbatch): once
       F(V-1, m) has landed ≥ 1 tick ago and the whole exact chain
       B(q, m) at t + (V-1-q) fits in unoccupied cells, reserve it
       outright — cotangents are consumed the tick they arrive, so the
       chain must land intact or not at all.
    2. **Fill free devices with ready forwards**, deepest chunk first
       (driving microbatches toward their loss, which is what retires
       stash), where F(q, m) is ready at ≥ F(q-1, m) + f_lat.  A forward
       is throttled when the stash it grows (its own device for the
       q = 0 injection, the consumer device for the arrival it emits)
       already holds the steady-state budget min(vM, vS+S-1) — except
       for the next-to-retire microbatch, which is exempt so the
       backward diagonal it feeds can always make progress (deadlock
       freedom).  The exemption can park up to v extra chunks of that
       one microbatch, which is exactly the slack the analytic bound
       `pipeline_peak_inflight` = min(vM, vS+S-1+v) allows.

    `f_lat` is the activation arrival latency the forwards must respect:
    1 for the plain ring, 2 for the double-buffered ``overlap`` ring
    (the transfer is issued one tick after the producing forward).
    """
    V = v * S
    # steady-state throttle; the next-to-retire exemption below may add
    # up to v more, which pipeline_peak_inflight's +v slack covers
    bound = min(v * M, v * S + S - 1)
    occ: dict[tuple[int, int], tuple[int, int, int]] = {}  # (t, s) → entry
    f_tick: dict[tuple[int, int], int] = {}
    nf = [0] * V              # per virtual stage: next microbatch to forward
    stash = [0] * S           # conservative live-slot count per device
    next_b = 0                # next backward diagonal to commit (FIFO)
    t, t_max = 0, 4 * (f_lat + 1) * (V + v * M) + 64
    while next_b < M:
        if t > t_max:         # pragma: no cover - construction invariant
            raise RuntimeError(
                f"interleaved scheduler did not converge "
                f"(M={M}, S={S}, v={v}, f_lat={f_lat})")
        # (1) the next backward diagonal, committed whole
        m = next_b
        ft = f_tick.get((V - 1, m))
        if (ft is not None and t >= ft + 1
                and not any((t + V - 1 - q, q % S) in occ
                            for q in range(V))):
            for q in range(V):
                occ[(t + V - 1 - q, q % S)] = (PIPE_BWD, m, q // S)
            next_b += 1
        # (2) forward fill, deepest chunk first
        for s in range(S):
            if (t, s) in occ:
                continue
            for c in range(v - 1, -1, -1):
                q = c * S + s
                m = nf[q]
                if m >= M:
                    continue
                if q > 0 and (f_tick.get((q - 1, m)) is None
                              or t < f_tick[(q - 1, m)] + f_lat):
                    continue
                grows = ([0] if q == 0 else []) \
                    + ([(q + 1) % S] if q < V - 1 else [])
                if m != next_b and any(stash[d] >= bound for d in grows):
                    continue
                occ[(t, s)] = (PIPE_FWD, m, c)
                f_tick[(q, m)] = t
                nf[q] += 1
                for d in grows:
                    stash[d] += 1
                break
        # backwards at this tick retire their device's stashed slot
        for s in range(S):
            ent = occ.get((t, s))
            if ent is not None and ent[0] == PIPE_BWD:
                stash[s] -= 1
        t += 1
    T = max(tt for tt, _ in occ) + 1
    prog = [[(PIPE_IDLE, 0, 0)] * S for _ in range(T)]
    for (tt, s), ent in occ.items():
        prog[tt][s] = ent
    return prog


def _check_program(prog, n_micro: int, n_stages: int,
                   schedule: str | None = None,
                   virtual_stages: int = 1) -> None:
    """Validate a step program's dataflow (see `make_step_program`).

    Thin raising wrapper over the reporting verifier
    (`repro.analysis.dataflow.check_step_program`): any error-severity
    diagnostic becomes a `DiagnosticError` (a ValueError) whose message
    names the schedule, tick, stage and microbatch — unlike the bare
    assert tuples this used to raise, it survives ``python -O``.  The
    import is lazy to keep this module's import graph analysis-free.
    """
    from repro.analysis.dataflow import check_step_program
    from repro.analysis.diagnostics import DiagnosticError

    diags = [d for d in check_step_program(prog, n_micro, n_stages,
                                           schedule=schedule,
                                           virtual_stages=virtual_stages)
             if d.is_error]
    if diags:
        raise DiagnosticError(
            diags, prefix=f"invalid step program "
                          f"(n_micro={n_micro}, n_stages={n_stages}):")


def pipeline_apply(stage_fn: Callable[[Tree, Any], Any], stage_params: Tree,
                   x: Any, axis: str = "stage") -> Any:
    """Apply `n_stages` stages sequentially under shard_map.

    stage_params: pytree whose leaves are sharded over `axis` with a
    leading per-stage dim (locally 1); `stage_fn(params, x)` computes one
    stage from the unstacked local params.  `x` must arrive replicated and
    the result is replicated — stage s's output is broadcast each tick, so
    the value entering stage s+1 is exactly the sequential composition.
    """
    idx = jax.lax.axis_index(axis)
    n_stages = jax.lax.psum(1, axis)          # static under shard_map
    local = jax.tree.map(lambda p: p[0], stage_params)
    for s in range(n_stages):
        y = stage_fn(local, x)
        # keep only stage s's output and hand it to everyone (the
        # numerics-oracle form of the stage-to-stage ppermute)
        x = jax.lax.psum(jnp.where(idx == s, y, jnp.zeros_like(y)), axis)
    return x


def pipeline_apply_microbatched(stage_fn: Callable[..., Tree],
                                stage_params: Tree, x: Tree, n_micro: int,
                                axis: str = "stage",
                                static: Tree | None = None,
                                schedule: str = "gpipe",
                                virtual_stages: int = 1) -> Tree:
    """Microbatched pipeline schedule under shard_map: the scheduling form
    whose efficiency `pipeline_bubble_fraction` models.

    `schedule` selects how the backward pass is ordered (the forward
    semantics — and the forward wall-clock schedule — are identical):

    - ``"gpipe"`` differentiates through the forward scan with jax's
      native transpose machinery: all forwards complete, then all
      backwards run, so every stage stashes all M microbatch activations
      (plus per-tick scan residuals).
    - ``"1f1b"`` wraps the same forward in a custom VJP whose backward is
      an explicit 1F1B-ordered step program: each stage stashes exactly
      its per-microbatch *inputs* and recomputes the stage under `jax.vjp`
      as its backward micro-steps fire, cotangents flowing by reverse
      ring ppermute.  Numerics match "gpipe" to reduction-order
      tolerance.  Note: because the loss lives *outside* this function,
      the backward can only start after all forwards — the S-bounded
      stash of true 1F1B (`pipeline_peak_inflight`) is realized by
      `pipeline_train_microbatched`, which owns the loss and interleaves
      F/B micro-steps in one program.

    `x` is a pytree whose leaves all carry a leading batch dim divisible by
    `n_micro`; it is split into `n_micro` microbatches, and stage s
    processes microbatch m at tick t = s + m, with activations moving
    stage-to-stage through a ring `ppermute` (the GLOBALMEM channel of the
    paper, across devices).  `stage_fn(local_params, x) -> x` must preserve
    the tree structure (residual-stream style).  All schedule collectives
    (both schedules, forward and backward) name only `axis`; `stage_fn`
    may freely use the mesh's other manual axes for its own collectives
    (tensor-parallel psums), which compose with either backward path.  Every device computes on
    every tick — fill/drain ticks compute garbage that is masked out — so
    wall-clock cost scales with the (M + S - 1) · S device-tick area and
    the measured bubble can be compared against the analytic model.

    `static` is an optional batch-leading tree of per-microbatch side
    inputs the stages *read* but don't produce (e.g. encoder output for
    cross-attention): it is not rotated through the ring — each device
    locally indexes the slice of its in-flight microbatch (t - s) and
    `stage_fn(local_params, x, static_mb)` receives it as a third
    argument.

    ``"interleaved"`` runs the chunk composition: stage params carry a
    second leading per-device chunk dim of `virtual_stages` (leaves
    shaped ``(1, v, ...)`` locally — virtual stage q = c·S + s on device
    s), and the executor applies one 1F1B pass per chunk in order, so
    the value entering chunk c+1 is exactly the sequential composition
    through virtual stage (c+1)·S - 1.  `virtual_stages=1` is literally
    one 1F1B pass.  (This is the numerics/differentiation form; the
    schedule-realizing fused form — reduced bubble, per-chunk events in
    one step program — is `pipeline_train_microbatched`.)

    Per microbatch the op sequence is exactly the sequential composition of
    the stages, and the whole schedule is reverse-mode differentiable
    (ppermute/psum transposes carry gradients stage-to-stage backwards).
    The result is replicated over `axis`.
    """
    if n_micro < 1:
        raise ValueError(f"need n_micro >= 1, got {n_micro}")
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; want {SCHEDULES}")
    v = _check_virtual_stages(schedule, virtual_stages)
    if schedule == "interleaved":
        for c in range(v):
            chunk = jax.tree.map(lambda p, _c=c: p[:, _c], stage_params)
            x = _apply_1f1b(stage_fn, chunk, x, n_micro, axis, static)
        return x
    if schedule == "1f1b":
        return _apply_1f1b(stage_fn, stage_params, x, n_micro, axis, static)
    return _apply_gpipe(stage_fn, stage_params, x, n_micro, axis, static)


def _apply_gpipe(stage_fn: Callable[..., Tree], stage_params: Tree, x: Tree,
                 n_micro: int, axis: str, static: Tree | None) -> Tree:
    """The GPipe fill/drain forward scan (see the public docstring)."""
    idx = jax.lax.axis_index(axis)
    n_stages = jax.lax.psum(1, axis)          # static under shard_map
    local = jax.tree.map(lambda p: p[0], stage_params)
    M = int(n_micro)

    x_mb = jax.tree.map(lambda l: _split_mb(l, M), x)
    static_mb = (None if static is None
                 else jax.tree.map(lambda l: _split_mb(l, M), static))
    state = jax.tree.map(lambda l: jnp.zeros_like(l[0]), x_mb)
    outbuf = jax.tree.map(jnp.zeros_like, x_mb)
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def tick(carry, t):
        state, outbuf = carry
        # stage 0 injects microbatch t (clipped re-injections during drain
        # compute garbage whose outputs never reach the last stage in time)
        m_in = jnp.clip(t, 0, M - 1)
        state = jax.tree.map(
            lambda buf, s: jnp.where(idx == 0, _at(buf, m_in), s),
            x_mb, state)
        if static_mb is None:
            y = stage_fn(local, state)
        else:
            # this device's in-flight microbatch is t - s; fill/drain
            # ticks index a clipped slot whose outputs are masked anyway
            m_cur = jnp.clip(t - idx, 0, M - 1)
            s_cur = jax.tree.map(lambda buf: _at(buf, m_cur), static_mb)
            y = stage_fn(local, state, s_cur)
        # the last stage completes microbatch t - (S-1) on this tick
        m_out = jnp.clip(t - (n_stages - 1), 0, M - 1)
        take = jnp.logical_and(idx == n_stages - 1, t >= n_stages - 1)

        def write(buf, yl):
            cur = _at(buf, m_out)
            return _put(buf, jnp.where(take, yl, cur), m_out)

        outbuf = jax.tree.map(write, outbuf, y)
        state = jax.tree.map(lambda l: jax.lax.ppermute(l, axis, perm), y)
        return (state, outbuf), None

    n_ticks = M + n_stages - 1
    (_, outbuf), _ = jax.lax.scan(tick, (state, outbuf),
                                  jnp.arange(n_ticks))
    out = jax.tree.map(
        lambda buf: jax.lax.psum(
            jnp.where(idx == n_stages - 1, buf, jnp.zeros_like(buf)), axis),
        outbuf)
    return jax.tree.map(_merge_mb, out)


# -------------------------------------------------- shared tree helpers
def _split_mb(leaf, n_micro: int):
    """(B, ...) → (M, B/M, ...) microbatch view of a batch-leading leaf."""
    if leaf.shape[0] % n_micro:
        raise ValueError(
            f"batch dim {leaf.shape[0]} not divisible by n_micro={n_micro}")
    return leaf.reshape(n_micro, leaf.shape[0] // n_micro, *leaf.shape[1:])


def _merge_mb(leaf):
    """(M, B/M, ...) → (B, ...): undo `_split_mb`."""
    return leaf.reshape(leaf.shape[0] * leaf.shape[1], *leaf.shape[2:])


def _at(buf, i):
    """buf[i] with a traced index (keepdims dropped)."""
    return jax.lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)


def _put(buf, val, i):
    """buf with buf[i] = val, traced index."""
    return jax.lax.dynamic_update_index_in_dim(buf, val, i, 0)


def _tree_where(cond, a: Tree, b: Tree) -> Tree:
    """Leafwise `jnp.where(cond, a, b)` for a scalar predicate."""
    return jax.tree.map(lambda u, v: jnp.where(cond, u, v), a, b)


def _apply_1f1b(stage_fn: Callable[..., Tree], stage_params: Tree, x: Tree,
                n_micro: int, axis: str, static: Tree | None) -> Tree:
    """Forward-compatible 1F1B: GPipe's forward scan + a custom VJP whose
    backward is the explicit 1F1B-ordered step program.

    fwd: runs `_apply_gpipe`'s tick loop, additionally stashing each
    stage's *input* activation per microbatch — the stash/pop buffer the
    backward pops (stage 0's injected microbatches included, so the
    residuals are exactly (M, mb, ...) per stage plus the static side
    inputs).  bwd: scans the backward half of the 1F1B program — stage s
    retires microbatch m at tick m + (S-1-s), recomputing the stage from
    its stashed input under `jax.vjp` and sending the input cotangent to
    stage s-1 by reverse ring ppermute; the last stage seeds cotangents
    from the output gradient, stage 0 accumulates the input gradient.
    Parameter gradients stay per-stage local (leading dim 1, like the
    primal params); `static` gradients are accumulated across every
    stage's micro-steps.

    The custom VJP wraps only the per-device *local* computation
    (microbatch buffers in, per-stage output buffer out); the microbatch
    split and the replicated psum-extraction of the last stage's buffer
    stay in plain autodiff land, so shard_map's boundary cotangent
    conventions apply to this schedule exactly as they do to "gpipe" —
    the bwd returns plain local cotangents (zeros off-stage-0 for the
    input buffer) and never compensates for boundary scaling.
    """
    M = int(n_micro)
    n_stages = jax.lax.psum(1, axis)          # static under shard_map

    def scan_core(stage_params, x_mb, static_mb):
        """Stacked params + microbatch buffers → (outbuf, stash), both
        per-device local: the last stage's outbuf holds every
        microbatch's final activations (other stages' are zeros) and
        stash holds this stage's input per microbatch."""
        idx = jax.lax.axis_index(axis)
        local = jax.tree.map(lambda p: p[0], stage_params)
        state = jax.tree.map(lambda l: jnp.zeros_like(l[0]), x_mb)
        outbuf = jax.tree.map(jnp.zeros_like, x_mb)
        stash = jax.tree.map(jnp.zeros_like, x_mb)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            state, outbuf, stash = carry
            m_in = jnp.clip(t, 0, M - 1)
            state = jax.tree.map(
                lambda buf, s: jnp.where(idx == 0, _at(buf, m_in), s),
                x_mb, state)
            # stash this stage's input for its in-flight microbatch t-s
            m_cur = jnp.clip(t - idx, 0, M - 1)
            live = jnp.logical_and(t >= idx, t - idx <= M - 1)
            stash = jax.tree.map(
                lambda buf, s: jnp.where(live, _put(buf, s, m_cur), buf),
                stash, state)
            if static_mb is None:
                y = stage_fn(local, state)
            else:
                s_cur = jax.tree.map(lambda b: _at(b, m_cur), static_mb)
                y = stage_fn(local, state, s_cur)
            m_out = jnp.clip(t - (n_stages - 1), 0, M - 1)
            take = jnp.logical_and(idx == n_stages - 1, t >= n_stages - 1)

            def write(buf, yl):
                cur = _at(buf, m_out)
                return _put(buf, jnp.where(take, yl, cur), m_out)

            outbuf = jax.tree.map(write, outbuf, y)
            state = jax.tree.map(lambda l: jax.lax.ppermute(l, axis, perm),
                                 y)
            return (state, outbuf, stash), None

        n_ticks = M + n_stages - 1
        (_, outbuf, stash), _ = jax.lax.scan(
            tick, (state, outbuf, stash), jnp.arange(n_ticks))
        return outbuf, stash

    def core(stage_params, x_mb, static_mb):
        outbuf, _ = scan_core(stage_params, x_mb, static_mb)
        return outbuf

    core_vjp = jax.custom_vjp(core)

    def fwd(stage_params, x_mb, static_mb):
        outbuf, stash = scan_core(stage_params, x_mb, static_mb)
        local = jax.tree.map(lambda p: p[0], stage_params)
        return outbuf, (local, stash, static_mb)

    def bwd(res, g_outbuf):
        # g_outbuf is the cotangent of the *local* outbuf: the epilogue's
        # psum/where transpose makes it the full per-microbatch output
        # gradient on the last stage and zeros elsewhere
        local, stash, static_mb = res
        idx = jax.lax.axis_index(axis)
        cot = jax.tree.map(lambda l: jnp.zeros_like(l[0]), stash)
        gx_buf = jax.tree.map(jnp.zeros_like, stash)
        g_local = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), local)
        gs_buf = (None if static_mb is None
                  else jax.tree.map(jnp.zeros_like, static_mb))
        perm = [(i, (i - 1) % n_stages) for i in range(n_stages)]

        def btick(carry, tau):
            cot, gx_buf, g_local, gs_buf = carry
            # 1F1B backward order: stage s retires m at tick m + (S-1-s)
            m = tau - (n_stages - 1 - idx)
            valid = jnp.logical_and(m >= 0, m <= M - 1)
            m_c = jnp.clip(m, 0, M - 1)
            xin = jax.tree.map(lambda b: _at(b, m_c), stash)
            seed = jax.tree.map(lambda b: _at(b, m_c), g_outbuf)
            cot_in = _tree_where(idx == n_stages - 1, seed, cot)
            if static_mb is None:
                _, vjp_fn = jax.vjp(stage_fn, local, xin)
                g_p, g_x = vjp_fn(cot_in)
                g_s = None
            else:
                s_cur = jax.tree.map(lambda b: _at(b, m_c), static_mb)
                _, vjp_fn = jax.vjp(stage_fn, local, xin, s_cur)
                g_p, g_x, g_s = vjp_fn(cot_in)
            g_local = jax.tree.map(
                lambda acc, gp: acc + jnp.where(valid, gp,
                                                jnp.zeros_like(gp)),
                g_local, g_p)
            take0 = jnp.logical_and(valid, idx == 0)
            gx_buf = jax.tree.map(
                lambda b, gx: jnp.where(take0, _put(b, gx, m_c), b),
                gx_buf, g_x)
            if g_s is not None:
                gs_buf = jax.tree.map(
                    lambda b, gs: jnp.where(
                        valid, _put(b, _at(b, m_c) + gs, m_c), b),
                    gs_buf, g_s)
            payload = jax.tree.map(
                lambda gx: jnp.where(valid, gx, jnp.zeros_like(gx)), g_x)
            cot = jax.tree.map(lambda l: jax.lax.ppermute(l, axis, perm),
                               payload)
            return (cot, gx_buf, g_local, gs_buf), None

        n_ticks = M + n_stages - 1
        (_, gx_buf, g_local, gs_buf), _ = jax.lax.scan(
            btick, (cot, gx_buf, g_local, gs_buf), jnp.arange(n_ticks))
        # plain local cotangents: gx_buf is nonzero only on stage 0 and
        # gs_buf holds this stage's contributions — the shard_map boundary
        # combines per-device contributions exactly as it does for the
        # autodiff-transposed "gpipe" body
        g_params = jax.tree.map(lambda gl: gl[None], g_local)
        g_static = gs_buf
        return g_params, gx_buf, g_static

    core_vjp.defvjp(fwd, bwd)

    x_mb = jax.tree.map(lambda l: _split_mb(l, M), x)
    static_mb = (None if static is None
                 else jax.tree.map(lambda l: _split_mb(l, M), static))
    outbuf = core_vjp(stage_params, x_mb, static_mb)
    idx = jax.lax.axis_index(axis)
    out = jax.tree.map(
        lambda buf: jax.lax.psum(
            jnp.where(idx == n_stages - 1, buf, jnp.zeros_like(buf)), axis),
        outbuf)
    return jax.tree.map(_merge_mb, out)


def pipeline_train_microbatched(stage_fn: Callable[..., Tree],
                                stage_params: Tree, x: Tree,
                                loss_fn: Callable[[Tree], Any],
                                n_micro: int, schedule: str = "1f1b",
                                axis: str = "stage",
                                busy_idle: bool = False,
                                virtual_stages: int = 1,
                                overlap: bool = False) -> tuple[Any, Tree]:
    """Fused forward+backward pipeline step under shard_map: scan one
    step program (`make_step_program`) end to end and return
    ``(loss, stage_param_grads)``.

    This is the executor where 1F1B's memory bound is *real*: because the
    per-microbatch loss lives inside the schedule (applied to the last
    stage's output), backward micro-steps interleave with forwards, and
    the activation stash holds at most `pipeline_peak_inflight(M, S,
    schedule)` microbatches — min(M, S) for 1F1B vs M for GPipe — which
    shows up directly in the compiled step's peak memory
    (`benchmarks/pipeline_bubble.py` measures it).

    Arguments mirror `pipeline_apply_microbatched`: `x` leaves carry a
    leading batch dim divisible by `n_micro`; `stage_fn(local_params, x)
    -> x` preserves tree structure.  `loss_fn(x_tree) -> scalar` is the
    per-microbatch loss, evaluated at the last stage; the returned loss
    is the **sum** over microbatches, replicated over `axis`.  Gradients
    are per-stage local with the params' leading stage dim of 1 (give
    them ``out_specs=P(axis)`` to reassemble the stacked layout).

    Mechanics, per tick: (1) apply last tick's ppermute arrivals — a
    forward activation is pushed into the stash slot ``m % K``, a
    cotangent overwrites the (single) cotangent register, which is safe
    because both programs consume cotangents the tick they arrive; (2)
    `lax.switch` on this stage's event — forward: pop/inject the input,
    run `stage_fn`, emit the activation; backward: recompute the stage
    from its stashed input under `jax.vjp` (the last stage seeds the
    cotangent from `jax.value_and_grad(loss_fn)`), accumulate parameter
    gradients, emit the input cotangent; (3) ppermute activations +1 and
    cotangents -1 around the ring.

    `busy_idle=True` makes idle slots run a discarded stage forward —
    for host-device *emulation* benchmarks only, where fake devices
    serialize onto shared cores and wall-clock tracks total, not
    critical-path, work: busy idles make t_pipe proportional to the
    device-tick area so 1 - t_seq/t_pipe exposes the bubble (same trick
    as the GPipe-only benchmark; keep it False on real hardware).

    ``schedule="interleaved"`` takes stage params with a second leading
    per-device chunk dim (leaves ``(1, virtual_stages, ...)`` locally;
    grads come back the same shape) and scans the interleaved step
    program — v micro-step slots per device per microbatch, bubble
    toward (S-1)/(vM+S-1).  ``overlap=True`` double-buffers the
    stage-boundary activation ppermute: the transfer of a forward's
    output is issued at the *top* of the next tick, before that tick's
    compute, so it depends only on carried state and XLA can overlap it
    with the compute (the step program spaces consumer forwards ≥ 2
    ticks after producers to cover the extra hop; cotangents keep the
    single-buffered exact-chain ring).  ``virtual_stages=1`` degenerates
    to plain 1f1b on the chunk-squeezed params (``overlap`` needs v ≥ 2).
    """
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; want {SCHEDULES}")
    v = _check_virtual_stages(schedule, virtual_stages)
    if overlap and schedule != "interleaved":
        raise ValueError(
            f"overlap=True (double-buffered activation ppermute) requires "
            f"schedule='interleaved', got {schedule!r}")
    if overlap and v == 1:
        raise ValueError(
            "overlap=True requires virtual_stages >= 2 (v=1 degenerates "
            "exactly to plain 1f1b, which keeps the single-buffered ring)")
    if schedule == "interleaved":
        if v == 1:
            flat = jax.tree.map(lambda p: p[:, 0], stage_params)
            loss, grads = pipeline_train_microbatched(
                stage_fn, flat, x, loss_fn, n_micro, schedule="1f1b",
                axis=axis, busy_idle=busy_idle)
            return loss, jax.tree.map(lambda g: g[:, None], grads)
        return _train_interleaved(stage_fn, stage_params, x, loss_fn,
                                  n_micro, v, axis, busy_idle, overlap)
    import numpy as np

    idx = jax.lax.axis_index(axis)
    S = int(jax.lax.psum(1, axis))            # static under shard_map
    M = int(n_micro)
    local = jax.tree.map(lambda p: p[0], stage_params)
    x_mb = jax.tree.map(lambda l: _split_mb(l, M), x)

    prog = make_step_program(M, S, schedule)
    T = len(prog)
    K = max(1, program_peak_inflight(prog, S))

    # executor-internal op encoding: last-stage backwards get their own
    # code so only that stage's switch branch evaluates loss_fn (other
    # stages' backwards consume the arrived cotangent instead)
    BWD_LOSS = 3
    op = np.zeros((T, S), np.int32)
    mb = np.zeros((T, S), np.int32)
    for t, row in enumerate(prog):
        for s, (o, m) in enumerate(row):
            if o == PIPE_BWD and s == S - 1:
                o = BWD_LOSS
            op[t, s], mb[t, s] = o, m
    # arrival routing, derived from the program: what each stage receives
    # at tick t is what its neighbour emitted at tick t-1
    fvalid = np.zeros((T, S), np.int32)
    fslot = np.zeros((T, S), np.int32)
    bvalid = np.zeros((T, S), np.int32)
    for t in range(1, T):
        for s in range(S):
            if s >= 1 and op[t - 1, s - 1] == PIPE_FWD:
                fvalid[t, s] = 1
                fslot[t, s] = mb[t - 1, s - 1] % K
            if s <= S - 2 and op[t - 1, s + 1] in (PIPE_BWD, BWD_LOSS):
                bvalid[t, s] = 1
    xs = {"op": jnp.asarray(op), "mb": jnp.asarray(mb),
          "fvalid": jnp.asarray(fvalid), "fslot": jnp.asarray(fslot),
          "bvalid": jnp.asarray(bvalid)}

    stash0 = jax.tree.map(
        lambda l: jnp.zeros((K, *l.shape[1:]), l.dtype), x_mb)
    zero_slot = jax.tree.map(lambda l: jnp.zeros_like(l[0]), x_mb)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), local)
    perm_f = [(i, (i + 1) % S) for i in range(S)]
    perm_b = [(i, (i - 1) % S) for i in range(S)]

    def tick(carry, xs_t):
        stash, cot, f_in, b_in, g_acc, loss = carry
        opv = xs_t["op"][idx]
        mv = xs_t["mb"][idx]
        slot = jnp.mod(mv, K)
        # (1) arrivals from last tick's ppermutes
        stash = jax.tree.map(
            lambda b, v: jnp.where(xs_t["fvalid"][idx],
                                   _put(b, v, xs_t["fslot"][idx]), b),
            stash, f_in)
        cot = _tree_where(xs_t["bvalid"][idx], b_in, cot)

        def do_idle(opd):
            stash, cot, g_acc, loss = opd
            if busy_idle:
                y = stage_fn(local, jax.tree.map(lambda b: _at(b, 0),
                                                 stash))
                # keep the discarded compute alive past DCE
                leaf = jax.tree.leaves(y)[0]
                loss = loss + 1e-30 * jnp.sum(leaf).astype(jnp.float32)
            return stash, cot, g_acc, loss, zero_slot, zero_slot

        def do_fwd(opd):
            stash, cot, g_acc, loss = opd
            xin = _tree_where(
                idx == 0,
                jax.tree.map(lambda b: _at(b, mv), x_mb),
                jax.tree.map(lambda b: _at(b, slot), stash))
            stash = jax.tree.map(lambda b, v: _put(b, v, slot), stash, xin)
            y = stage_fn(local, xin)
            return stash, cot, g_acc, loss, y, zero_slot

        def do_bwd(opd):
            # mid-pipeline backward: cotangent arrived on the ring
            stash, cot, g_acc, loss = opd
            xin = jax.tree.map(lambda b: _at(b, slot), stash)
            _, vjp_fn = jax.vjp(stage_fn, local, xin)
            g_p, g_x = vjp_fn(cot)
            g_acc = jax.tree.map(lambda a, gp: a + gp.astype(a.dtype),
                                 g_acc, g_p)
            return stash, cot, g_acc, loss, zero_slot, g_x

        def do_bwd_loss(opd):
            # last stage's backward: seed the cotangent from loss_fn
            stash, cot, g_acc, loss = opd
            xin = jax.tree.map(lambda b: _at(b, slot), stash)
            y, vjp_fn = jax.vjp(stage_fn, local, xin)
            l, gy = jax.value_and_grad(loss_fn)(y)
            g_p, g_x = vjp_fn(gy)
            g_acc = jax.tree.map(lambda a, gp: a + gp.astype(a.dtype),
                                 g_acc, g_p)
            loss = loss + l.astype(jnp.float32)
            return stash, cot, g_acc, loss, zero_slot, g_x

        stash, cot, g_acc, loss, pay_f, pay_b = jax.lax.switch(
            opv, [do_idle, do_fwd, do_bwd, do_bwd_loss],
            (stash, cot, g_acc, loss))
        f_in = jax.tree.map(lambda l: jax.lax.ppermute(l, axis, perm_f),
                            pay_f)
        b_in = jax.tree.map(lambda l: jax.lax.ppermute(l, axis, perm_b),
                            pay_b)
        return (stash, cot, f_in, b_in, g_acc, loss), None

    carry0 = (stash0, zero_slot, zero_slot, zero_slot, g0,
              jnp.zeros((), jnp.float32))
    (_, _, _, _, g_acc, loss), _ = jax.lax.scan(tick, carry0, xs)
    loss = jax.lax.psum(loss, axis)           # loss lives on the last stage
    grads = jax.tree.map(lambda g, p: g[None].astype(p.dtype), g_acc, local)
    return loss, grads


def _train_interleaved(stage_fn: Callable[..., Tree], stage_params: Tree,
                       x: Tree, loss_fn: Callable[[Tree], Any],
                       n_micro: int, v: int, axis: str,
                       busy_idle: bool, overlap: bool) -> tuple[Any, Tree]:
    """The fused interleaved-1F1B executor (see
    `pipeline_train_microbatched`): scan the chunked step program with
    per-event chunk params, a free-list-allocated activation stash, and
    (optionally) a double-buffered activation ring.

    Stage params carry a per-device chunk dim — leaves ``(1, v, ...)``
    locally, virtual stage q = c·S + s in slot ``[0, c]`` of device s —
    and gradients come back the same shape.  Stash slots are assigned
    *statically* by replaying the program through a per-device free
    list: a slot is written by the ring arrival of the producing
    forward's output (the injection itself for virtual stage 0), read
    by this device's F and B events of that (chunk, microbatch), and
    freed the tick after the B retires it, so K is exactly the peak
    concurrent live count (`program_peak_inflight`).  Cotangents keep
    the flat executors' single register — the interleaved program also
    schedules exact backward chains, so a cotangent is consumed the
    tick it arrives, chunk wraps included (the ring's s → s-1 shift is
    device (q-1) mod S for every virtual stage q).
    """
    import numpy as np

    idx = jax.lax.axis_index(axis)
    S = int(jax.lax.psum(1, axis))            # static under shard_map
    M = int(n_micro)
    V = v * S
    lat = 2 if overlap else 1
    local = jax.tree.map(lambda p: p[0], stage_params)   # (v, ...)
    for leaf in jax.tree.leaves(local):
        if leaf.shape[0] != v:
            raise ValueError(
                f"interleaved stage params need a per-device chunk dim of "
                f"virtual_stages={v} after the stage dim, got local leaf "
                f"shape {leaf.shape}")
    x_mb = jax.tree.map(lambda l: _split_mb(l, M), x)

    prog = make_step_program(M, S, "interleaved", virtual_stages=v,
                             overlap=overlap)
    T = len(prog)
    f_tick: dict = {}
    b_tick: dict = {}
    for t, row in enumerate(prog):
        for s, (o, m, c) in enumerate(row):
            if o == PIPE_FWD:
                f_tick[(c * S + s, m)] = t
            elif o == PIPE_BWD:
                b_tick[(c * S + s, m)] = t

    # static stash-slot assignment: replay the program through a
    # per-device free list (writes land before the tick's event, frees
    # land after it, so a slot retired by a B is reusable next tick)
    writes: list = [[] for _ in range(T)]
    frees: list = [[] for _ in range(T)]
    for (q, m), t in f_tick.items():
        wt = t if q == 0 else f_tick[(q - 1, m)] + lat
        writes[wt].append((q % S, q, m))
    for (q, m), t in b_tick.items():
        frees[t].append((q % S, q, m))
    slot_of: dict = {}
    free_list: list = [[] for _ in range(S)]
    high = [0] * S
    for t in range(T):
        for s, q, m in sorted(writes[t]):
            if free_list[s]:
                slot_of[(q, m)] = free_list[s].pop()
            else:
                slot_of[(q, m)] = high[s]
                high[s] += 1
        for s, q, m in sorted(frees[t]):
            free_list[s].append(slot_of[(q, m)])
    K = max(1, *high)

    # executor-internal op encoding as in the flat path: the *last
    # virtual stage's* backward evaluates loss_fn; every other backward
    # consumes the arrived cotangent
    BWD_LOSS = 3
    op = np.zeros((T, S), np.int32)
    mb = np.zeros((T, S), np.int32)
    ch = np.zeros((T, S), np.int32)
    eslot = np.zeros((T, S), np.int32)
    inject = np.zeros((T, S), np.int32)
    fvalid = np.zeros((T, S), np.int32)
    fslot = np.zeros((T, S), np.int32)
    bvalid = np.zeros((T, S), np.int32)
    for t, row in enumerate(prog):
        for s, (o, m, c) in enumerate(row):
            q = c * S + s
            if o == PIPE_BWD and q == V - 1:
                o = BWD_LOSS
            op[t, s], mb[t, s], ch[t, s] = o, m, c
            if o != PIPE_IDLE:
                eslot[t, s] = slot_of[(q, m)]
            if o == PIPE_FWD and q == 0:
                inject[t, s] = 1
    # arrival routing off the books: a forward's output reaches virtual
    # stage q+1's device `lat` ticks later (the last virtual stage's
    # output and virtual stage 0's input cotangent ride the ring too,
    # but nothing consumes them); cotangents always arrive next tick
    for (q, m), t in f_tick.items():
        if q < V - 1:
            fvalid[t + lat, (q + 1) % S] = 1
            fslot[t + lat, (q + 1) % S] = slot_of[(q + 1, m)]
    for (q, m), t in b_tick.items():
        if q > 0:
            bvalid[t + 1, (q - 1) % S] = 1
    xs = {"op": jnp.asarray(op), "mb": jnp.asarray(mb),
          "ch": jnp.asarray(ch), "eslot": jnp.asarray(eslot),
          "inject": jnp.asarray(inject), "fvalid": jnp.asarray(fvalid),
          "fslot": jnp.asarray(fslot), "bvalid": jnp.asarray(bvalid)}

    stash0 = jax.tree.map(
        lambda l: jnp.zeros((K, *l.shape[1:]), l.dtype), x_mb)
    zero_slot = jax.tree.map(lambda l: jnp.zeros_like(l[0]), x_mb)
    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), local)
    perm_f = [(i, (i + 1) % S) for i in range(S)]
    perm_b = [(i, (i - 1) % S) for i in range(S)]

    def send_f(tree):
        return jax.tree.map(lambda l: jax.lax.ppermute(l, axis, perm_f),
                            tree)

    def send_b(tree):
        return jax.tree.map(lambda l: jax.lax.ppermute(l, axis, perm_b),
                            tree)

    def tick(carry, xs_t):
        if overlap:
            stash, cot, pay_prev, f_in, b_in, g_acc, loss = carry
            # double buffering: issue LAST tick's activation transfer
            # before this tick's compute — it reads only carried state,
            # so XLA is free to run the ppermute concurrently with the
            # switch below; consumers see their input two ticks after
            # the producing forward, which the step program's f_lat=2
            # spacing already covers
            f_out = send_f(pay_prev)
        else:
            stash, cot, f_in, b_in, g_acc, loss = carry
        opv = xs_t["op"][idx]
        mv = xs_t["mb"][idx]
        cv = xs_t["ch"][idx]
        es = xs_t["eslot"][idx]
        # (1) arrivals from the ring land in their free-list slots
        stash = jax.tree.map(
            lambda b, vl: jnp.where(xs_t["fvalid"][idx],
                                    _put(b, vl, xs_t["fslot"][idx]), b),
            stash, f_in)
        cot = _tree_where(xs_t["bvalid"][idx], b_in, cot)
        lp = jax.tree.map(lambda p: _at(p, cv), local)   # chunk params

        def do_idle(opd):
            stash, cot, g_acc, loss = opd
            if busy_idle:
                y = stage_fn(lp, jax.tree.map(lambda b: _at(b, 0), stash))
                # keep the discarded compute alive past DCE
                leaf = jax.tree.leaves(y)[0]
                loss = loss + 1e-30 * jnp.sum(leaf).astype(jnp.float32)
            return stash, cot, g_acc, loss, zero_slot, zero_slot

        def do_fwd(opd):
            stash, cot, g_acc, loss = opd
            xin = _tree_where(
                xs_t["inject"][idx],
                jax.tree.map(lambda b: _at(b, mv), x_mb),
                jax.tree.map(lambda b: _at(b, es), stash))
            stash = jax.tree.map(lambda b, vl: _put(b, vl, es), stash, xin)
            y = stage_fn(lp, xin)
            return stash, cot, g_acc, loss, y, zero_slot

        def do_bwd(opd):
            # mid-program backward: cotangent arrived on the ring
            stash, cot, g_acc, loss = opd
            xin = jax.tree.map(lambda b: _at(b, es), stash)
            _, vjp_fn = jax.vjp(stage_fn, lp, xin)
            g_p, g_x = vjp_fn(cot)
            g_acc = jax.tree.map(
                lambda a, gp: a.at[cv].add(gp.astype(a.dtype)),
                g_acc, g_p)
            return stash, cot, g_acc, loss, zero_slot, g_x

        def do_bwd_loss(opd):
            # last virtual stage's backward: seed from loss_fn
            stash, cot, g_acc, loss = opd
            xin = jax.tree.map(lambda b: _at(b, es), stash)
            y, vjp_fn = jax.vjp(stage_fn, lp, xin)
            l, gy = jax.value_and_grad(loss_fn)(y)
            g_p, g_x = vjp_fn(gy)
            g_acc = jax.tree.map(
                lambda a, gp: a.at[cv].add(gp.astype(a.dtype)),
                g_acc, g_p)
            loss = loss + l.astype(jnp.float32)
            return stash, cot, g_acc, loss, zero_slot, g_x

        stash, cot, g_acc, loss, pay_f, pay_b = jax.lax.switch(
            opv, [do_idle, do_fwd, do_bwd, do_bwd_loss],
            (stash, cot, g_acc, loss))
        b_out = send_b(pay_b)
        if overlap:
            return (stash, cot, pay_f, f_out, b_out, g_acc, loss), None
        f_out = send_f(pay_f)
        return (stash, cot, f_out, b_out, g_acc, loss), None

    loss0 = jnp.zeros((), jnp.float32)
    if overlap:
        carry0 = (stash0, zero_slot, zero_slot, zero_slot, zero_slot,
                  g0, loss0)
        (_, _, _, _, _, g_acc, loss), _ = jax.lax.scan(tick, carry0, xs)
    else:
        carry0 = (stash0, zero_slot, zero_slot, zero_slot, g0, loss0)
        (_, _, _, _, g_acc, loss), _ = jax.lax.scan(tick, carry0, xs)
    loss = jax.lax.psum(loss, axis)       # loss lives on the last device
    grads = jax.tree.map(lambda g, p: g[None].astype(p.dtype), g_acc, local)
    return loss, grads
