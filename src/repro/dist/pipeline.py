"""Pipeline parallelism: stage balancing (Alg. 1) + a shard_map executor.

`balance_stages` is the MKPipe throughput-balancing idea applied across
devices: partition a chain of layers into contiguous stages so the slowest
stage — the pipeline's bottleneck kernel — is as fast as possible.  It is
the exact linear-partition DP, not a greedy split, because a heavy tail
(e.g. MoE layers at the end of a hybrid stack) makes greedy splits
arbitrarily bad.

`pipeline_apply` runs inside `shard_map` over a ``"stage"`` axis: stage
params arrive sharded with a leading per-stage dim of 1, activations are
passed stage-to-stage through collectives, and the final activations come
back replicated.  It is the numerics oracle for pipeline placement (every
stage computes every tick; scheduling efficiency is modeled separately by
`pipeline_bubble_fraction`).
"""
from __future__ import annotations

import itertools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

Tree = Any


def balance_stages(times: Sequence[float], n_stages: int) -> list[int]:
    """Partition `times` into `n_stages` contiguous groups minimizing the
    max group sum.  Returns group sizes (every group non-empty)."""
    n = len(times)
    if not 1 <= n_stages <= n:
        raise ValueError(f"need 1 <= n_stages={n_stages} <= n_layers={n}")
    prefix = [0.0, *itertools.accumulate(times)]

    # best[k][i]: minimal max-stage-time for the first i layers in k stages
    inf = float("inf")
    best = [[inf] * (n + 1) for _ in range(n_stages + 1)]
    cut = [[0] * (n + 1) for _ in range(n_stages + 1)]
    best[0][0] = 0.0
    for k in range(1, n_stages + 1):
        for i in range(k, n + 1):
            for j in range(k - 1, i):
                cand = max(best[k - 1][j], prefix[i] - prefix[j])
                # strict < keeps the earliest (most front-loaded) optimal
                # cut, so ties put extra layers on earlier stages
                if cand < best[k][i]:
                    best[k][i] = cand
                    cut[k][i] = j
    sizes: list[int] = []
    i = n
    for k in range(n_stages, 0, -1):
        j = cut[k][i]
        sizes.append(i - j)
        i = j
    return sizes[::-1]


def pipeline_bubble_fraction(n_micro: int, n_stages: int) -> float:
    """GPipe fill/drain bubble: (S-1) / (M + S-1) of device-ticks idle."""
    if n_micro < 1 or n_stages < 1:
        raise ValueError("need n_micro >= 1 and n_stages >= 1")
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_apply(stage_fn: Callable[[Tree, Any], Any], stage_params: Tree,
                   x: Any, axis: str = "stage") -> Any:
    """Apply `n_stages` stages sequentially under shard_map.

    stage_params: pytree whose leaves are sharded over `axis` with a
    leading per-stage dim (locally 1); `stage_fn(params, x)` computes one
    stage from the unstacked local params.  `x` must arrive replicated and
    the result is replicated — stage s's output is broadcast each tick, so
    the value entering stage s+1 is exactly the sequential composition.
    """
    idx = jax.lax.axis_index(axis)
    n_stages = jax.lax.psum(1, axis)          # static under shard_map
    local = jax.tree.map(lambda p: p[0], stage_params)
    for s in range(n_stages):
        y = stage_fn(local, x)
        # keep only stage s's output and hand it to everyone (the
        # numerics-oracle form of the stage-to-stage ppermute)
        x = jax.lax.psum(jnp.where(idx == s, y, jnp.zeros_like(y)), axis)
    return x
