"""Shims over jax API drift, so call sites read like current jax.

jax moved `shard_map` from `jax.experimental.shard_map` to top-level and
renamed its replication-check kwarg `check_rep` → `check_vma`; meshes grew
an `axis_types` argument.  These wrappers accept the new spelling and run
on either version.
"""
from __future__ import annotations

import inspect
from typing import Any

try:                                    # jax >= 0.5
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:                     # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f: Any, *, mesh: Any, in_specs: Any, out_specs: Any,
              check_vma: bool | None = None, **kwargs: Any) -> Any:
    """`jax.shard_map` with the `check_vma` kwarg on any jax version.

    `f` runs per device on the locally-sharded arguments; `in_specs` /
    `out_specs` are PartitionSpec trees matching the argument/result
    trees (a `P(axis, ...)` entry maps that dim over `mesh`'s `axis`,
    `None` replicates).  `check_vma=False` maps to `check_rep=False` on
    jax 0.4.x — the setting every executor here uses, since the bodies
    mix collectives the replication checker can't type.  Returns the
    wrapped callable, exactly like `jax.shard_map`.
    """
    if check_vma is not None:
        key = "check_vma" if "check_vma" in _SHARD_MAP_PARAMS else "check_rep"
        kwargs[key] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)
