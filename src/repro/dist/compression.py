"""Gradient compression: int8 quantization with error feedback.

The cross-replica gradient reduction is the dominant collective of
data-parallel training; int8 halves-of-halves its bytes.  Plain
quantization biases the update, so `compressed_psum` keeps a per-replica
error-feedback residual: the quantization error of step t is added back
into the gradient of step t+1, making the *cumulative* transmitted signal
track the true gradient sum (the residual stays bounded).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Tree = Any


def quantize_int8(x: jax.Array, axis=None, keepdims: bool = False
                  ) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8, per tensor (default) or per `axis` slice: returns
    (q int8, scale f32) with dequantization error bounded by scale/2
    elementwise."""
    scale = jnp.max(jnp.abs(x), axis=axis,
                    keepdims=keepdims).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Invert `quantize_int8`: int8 values × their (broadcastable) f32
    scale → f32 approximation of the original tensor."""
    return q.astype(jnp.float32) * scale


def init_errors(tree: Tree) -> Tree:
    """Zero error-feedback residuals matching `tree`'s shapes."""
    return jax.tree.map(lambda l: jnp.zeros(jnp.shape(l), jnp.float32), tree)


def init_stacked_errors(tree: Tree, n_shards: int) -> Tree:
    """Per-replica residuals for a shard_map reduction island: each leaf
    gains a leading `n_shards` dim that shards over the data axes, so
    every replica carries (and updates) only its own residual slice."""
    return jax.tree.map(
        lambda l: jnp.zeros((n_shards, *jnp.shape(l)), jnp.float32), tree)


def compressed_psum(grad: jax.Array, axis: str, error: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 mean over a shard_map axis, int8 on the wire.

    The all-reduce is decomposed so both transport phases move int8, not
    f32 — the compressed analogue of ring reduce-scatter + all-gather:

      1. split the carried gradient into one chunk per replica, quantize
         each chunk against its own scale, and `all_to_all` the int8
         payload (replica k receives every replica's contribution to
         chunk k);
      2. dequantize + mean locally in f32;
      3. re-quantize the reduced chunk and `all_gather` it as int8.

    Returns (mean, new residual).  The residual is the step-1 quantization
    error of *this replica's* transmitted signal, so the cumulative
    transmitted sum tracks the true gradient sum; step-3 re-quantization
    adds a bounded (≤ scale/2 elementwise), non-accumulating broadcast
    error.  Total elementwise error is within the max per-replica scale.
    """
    carried = grad.astype(jnp.float32) + error
    n = jax.lax.psum(1, axis)
    if n == 1:
        q, scale = quantize_int8(carried)
        sent = dequantize_int8(q, scale)
        return sent.astype(grad.dtype), carried - sent

    flat = carried.ravel()
    size = flat.shape[0]
    m = -(-size // n)                          # chunk length, padded
    flat = jnp.pad(flat, (0, n * m - size))
    chunks = flat.reshape(n, m)

    # per-destination-chunk symmetric int8
    q, scale = quantize_int8(chunks, axis=1, keepdims=True)
    sent = q.astype(jnp.float32) * scale       # what the wire carried

    recv_q = jax.lax.all_to_all(q, axis, 0, 0, tiled=True)
    recv_scale = jax.lax.all_to_all(scale, axis, 0, 0, tiled=True)
    mean_chunk = (recv_q.astype(jnp.float32) * recv_scale).sum(0) / n

    q2, scale2 = quantize_int8(mean_chunk)
    all_q2 = jax.lax.all_gather(q2, axis, tiled=True)      # (n·m,) int8
    all_s2 = jax.lax.all_gather(scale2, axis)              # (n,)
    mean = (all_q2.reshape(n, m).astype(jnp.float32)
            * all_s2[:, None]).ravel()[:size].reshape(grad.shape)

    err = (carried.ravel() - sent.ravel()[:size]).reshape(grad.shape)
    return mean.astype(grad.dtype), err
