"""Gradient compression: int8 quantization with error feedback.

The cross-replica gradient reduction is the dominant collective of
data-parallel training; int8 halves-of-halves its bytes.  Plain
quantization biases the update, so `compressed_psum` keeps a per-replica
error-feedback residual: the quantization error of step t is added back
into the gradient of step t+1, making the *cumulative* transmitted signal
track the true gradient sum (the residual stays bounded).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Tree = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale f32 scalar) with
    dequantization error bounded by scale/2 elementwise."""
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_errors(tree: Tree) -> Tree:
    """Zero error-feedback residuals matching `tree`'s shapes."""
    return jax.tree.map(lambda l: jnp.zeros(jnp.shape(l), jnp.float32), tree)


def compressed_psum(grad: jax.Array, axis: str, error: jax.Array
                    ) -> tuple[jax.Array, jax.Array]:
    """Error-feedback int8 mean over a shard_map axis.

    Returns (mean of the dequantized per-replica contributions, new
    residual).  Each replica's contribution is off by at most scale/2, so
    the mean is within max-replica-scale/2 of the true mean.
    """
    carried = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(carried)
    sent = dequantize_int8(q, scale)
    n = jax.lax.psum(1, axis)
    mean = jax.lax.psum(sent, axis) / n
    return mean.astype(grad.dtype), carried - sent
