"""Distributed substrate: the layer between the MKPipe scheduler core and
the model/launch/bench layers.

- ``context``      scoped active mesh + optimization flags (`sharding_context`,
                   `constrain`, `flag`, `moe_groups`)
- ``sharding``     PartitionSpec construction for batches, params and decode
                   caches (`batch_spec`, `param_specs`, `cache_specs`,
                   `shard_tree_specs`, `with_shardings`, `data_axes`)
- ``pipeline``     pipeline parallelism: Alg.1 stage balancing
                   (`balance_stages`), the analytic bubble and
                   peak-activation models (`pipeline_bubble_fraction`,
                   `pipeline_peak_inflight`), step programs
                   (`make_step_program`, `program_peak_inflight`), and
                   three shard_map executors — `pipeline_apply` (lock-step
                   numerics oracle), `pipeline_apply_microbatched`
                   (GPipe/1F1B forward, differentiable), and
                   `pipeline_train_microbatched` (fused fwd+bwd with the
                   loss inside the schedule).  See
                   docs/pipeline-schedules.md.
- ``compression``  int8 gradient compression with error feedback
                   (`quantize_int8`, `compressed_psum`)
- ``compat``       shims over jax API drift (`shard_map`)

Every entry point degrades to an identity / sensible default outside a
`sharding_context`, so single-device code paths never pay for the substrate.
"""
from .context import (constrain, flag, manual_tp_size, moe_groups,
                      sharding_context)
from .pipeline import (SCHEDULES, balance_stages, pipeline_bubble_fraction,
                       pipeline_peak_activation_bytes, pipeline_peak_inflight)
from .sharding import (batch_spec, cache_specs, data_axes, param_specs,
                       pipeline_stage_specs, shard_tree_specs,
                       with_shardings)

__all__ = [
    "sharding_context", "constrain", "flag", "manual_tp_size", "moe_groups",
    "data_axes", "batch_spec", "param_specs", "cache_specs",
    "pipeline_stage_specs", "shard_tree_specs", "with_shardings",
    "SCHEDULES", "balance_stages", "pipeline_bubble_fraction",
    "pipeline_peak_inflight", "pipeline_peak_activation_bytes",
]
