"""Distributed substrate: the layer between the MKPipe scheduler core and
the model/launch/bench layers.

- ``context``      scoped active mesh + optimization flags (`sharding_context`,
                   `constrain`, `flag`, `moe_groups`)
- ``sharding``     PartitionSpec construction for batches, params and decode
                   caches (`batch_spec`, `param_specs`, `cache_specs`,
                   `shard_tree_specs`, `with_shardings`, `data_axes`)
- ``pipeline``     pipeline parallelism: Alg.1 stage balancing + a shard_map
                   stage executor (`balance_stages`, `pipeline_apply`)
- ``compression``  int8 gradient compression with error feedback
                   (`quantize_int8`, `compressed_psum`)
- ``compat``       shims over jax API drift (`shard_map`)

Every entry point degrades to an identity / sensible default outside a
`sharding_context`, so single-device code paths never pay for the substrate.
"""
from .context import constrain, flag, moe_groups, sharding_context
from .sharding import (batch_spec, cache_specs, data_axes, param_specs,
                       shard_tree_specs, with_shardings)

__all__ = [
    "sharding_context", "constrain", "flag", "moe_groups",
    "data_axes", "batch_spec", "param_specs", "cache_specs",
    "shard_tree_specs", "with_shardings",
]
