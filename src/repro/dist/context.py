"""Scoped sharding context: the active mesh and optimization flags.

Model code refers to *logical* axes, not mesh axes:

- ``"dp"``  — data parallelism: every axis of the active mesh that belongs
  to ``("pod", "data")``.  On the single-pod ``("data", "model")`` mesh this
  is ``("data",)``; on the multi-pod ``("pod", "data", "model")`` mesh it is
  ``("pod", "data")``, so batch dims shard over both without the model code
  knowing how many pods exist.
- ``"tp"``  — tensor/model parallelism: the ``"model"`` axis.

`constrain` maps logical axes to a `with_sharding_constraint` against the
active mesh, and is an exact no-op (returns its input) outside a context —
layers can sprinkle constraints freely without breaking single-device runs
or pure-numpy oracles.

Flags (`ar_bf16`, `seq_shard`, `decode_bf16_scores`, `no_flash_vjp`, ...)
are the §Perf hillclimb knobs: the dry-run lowers each variant by passing
``flags=`` and the layers branch on `flag(name)` at trace time.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Iterable, Iterator

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# mesh axes carrying data parallelism, outermost first
DATA_AXES = ("pod", "data")
MODEL_AXIS = "model"

# kernel execution modes for the compute hot-spots (--kernels CLI):
#   off    — pure-jnp layer math (the XLA baseline)
#   ref    — the kernels' jnp oracles (validates the dispatch plumbing
#            and f32-accumulation numerics without interpret-mode cost)
#   pallas — the Pallas kernels (interpret mode on CPU, compiled on TPU)
KERNEL_MODES = ("off", "ref", "pallas")


class _State(threading.local):
    """Per-thread active context (jit tracing happens on the calling
    thread, so thread-local is the right scope)."""

    def __init__(self) -> None:
        self.mesh: Mesh | None = None
        self.flags: frozenset[str] = frozenset()


_STATE = _State()


@contextlib.contextmanager
def sharding_context(mesh: Mesh, flags: Iterable[str] = ()
                     ) -> Iterator[Mesh]:
    """Scope `mesh` and `flags` as the active sharding context.

    Reentrant: nesting restores the outer context on exit.  Lowering /
    tracing must happen inside the context for `constrain`/`flag` to see it.
    """
    prev = (_STATE.mesh, _STATE.flags)
    _STATE.mesh = mesh
    _STATE.flags = frozenset(flags)
    try:
        yield mesh
    finally:
        _STATE.mesh, _STATE.flags = prev


def active_mesh() -> Mesh | None:
    """The mesh of the innermost active `sharding_context`, or None."""
    return _STATE.mesh


def flag(name: str) -> bool:
    """True iff `name` was passed as a flag to the active context."""
    return name in _STATE.flags


def active_flags() -> frozenset[str]:
    """All flags of the innermost active `sharding_context` (empty
    outside any context)."""
    return _STATE.flags


def kernel_mode() -> str:
    """Kernel execution mode of the active context (one of KERNEL_MODES).

    Layers branch on this at trace time (like `flag`): ``"pallas"`` routes
    the hot-spot math through `repro.kernels.dispatch`, ``"ref"`` through
    the kernels' jnp oracles, ``"off"`` (no context / no kernel flag)
    keeps the pure-jnp layer path.  ``kernels_pallas`` wins when both
    flags are somehow present — but `kernel_mode_flags` (the CLI mapping)
    never emits both, and mklint rejects the combination (MK-L006).
    """
    if "kernels_pallas" in _STATE.flags:
        return "pallas"
    if "kernels_ref" in _STATE.flags:
        return "ref"
    return "off"


def kernel_mode_flags(mode: str) -> tuple[str, ...]:
    """`--kernels MODE` CLI value → the sharding-context flag tuple."""
    if mode not in KERNEL_MODES:
        raise ValueError(
            f"unknown kernel mode {mode!r}; pick one of {KERNEL_MODES}")
    return () if mode == "off" else (f"kernels_{mode}",)


def _axis_size(mesh: Mesh, entry: Any) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def resolve_axis(axis: str | None, mesh: Mesh) -> Any:
    """Logical axis → PartitionSpec entry for `mesh` (None if absent).

    ``"dp"`` resolves to the tuple of data axes `mesh` actually has
    (e.g. ``("pod", "data")`` on a multi-pod mesh), ``"tp"`` to
    ``"model"``, and any other name to itself when present — so the
    returned entry can be placed directly in a `PartitionSpec`.
    """
    if axis is None:
        return None
    if axis == "dp":
        present = tuple(a for a in DATA_AXES if a in mesh.shape)
        return present if present else None
    if axis == "tp":
        return MODEL_AXIS if MODEL_AXIS in mesh.shape else None
    return axis if axis in mesh.shape else None


def _bound_axis_sizes() -> dict:
    """Axis name → size of every axis bound by an enclosing manual region
    (shard_map/pmap) at trace time.  Internal-API probe with a safe
    fallback: if the probe breaks on a future jax, the mapping is empty
    (constraints stay on — the pre-manual behavior)."""
    try:
        from jax._src.core import get_axis_env
        return dict(get_axis_env().axis_sizes)
    except Exception:                      # pragma: no cover - jax drift
        return {}


def _bound_axis_names() -> frozenset:
    """Axis names bound by an enclosing manual region (shard_map/pmap)."""
    return frozenset(_bound_axis_sizes())


def manual_tp_size() -> int:
    """Tensor-parallel degree of the enclosing *manual* region: the size of
    the ``"model"`` mesh axis when it is bound by a shard_map/pmap at trace
    time, else 1.

    This is the layer code's switch for explicit tensor-parallel
    collectives.  Under GSPMD (no manual region, or the model axis left
    automatic) the compiler inserts the TP all-reduces itself and this
    returns 1; inside a pipeline island the whole mesh — ``"model"``
    included — is manual, params arrive model-sharded
    (`repro.dist.sharding.pipeline_stage_specs`), and every row-parallel
    reduction must be an explicit `psum` over ``"model"``
    (`repro.models.layers` branches on this).
    """
    return _bound_axis_sizes().get(MODEL_AXIS, 1)


def constrain(x: Any, *axes: str | None) -> Any:
    """Sharding constraint over logical axes, one entry per dim of `x`.

    No-op outside a `sharding_context`, and inside shard_map manual
    regions (GSPMD constraints don't apply there; this covers not just the
    forward trace but custom_vjp backward rules and remat re-traces, which
    run outside any context manager the caller could hold).  Otherwise
    each logical axis is resolved against the active mesh and dropped when
    the dim size does not divide the shard count (e.g. a `"tp"` entry on a
    dim the config didn't pad) — the constraint must never make a program
    unshardable.
    """
    mesh = _STATE.mesh
    if mesh is None:
        return x
    if _bound_axis_names():
        return x
    ndim = jax.numpy.ndim(x)
    if len(axes) != ndim:
        raise ValueError(
            f"constrain got {len(axes)} axes for a rank-{ndim} value")
    entries = []
    for axis, dim in zip(axes, x.shape):
        entry = resolve_axis(axis, mesh)
        if entry is not None and dim % _axis_size(mesh, entry):
            entry = None
        entries.append(entry)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def moe_groups(n: int) -> int:
    """Number of MoE dispatch groups for GShard-style grouped dispatch.

    Outside a context: `n` (the caller's default).  Inside: `n` rounded up
    to a multiple of the data-parallel shard count (and at least that
    count), so the group dim shards cleanly over `"dp"` and no data shard
    redundantly recomputes another shard's expert tokens.
    """
    mesh = _STATE.mesh
    if mesh is None:
        return n
    dp = _axis_size(mesh, resolve_axis("dp", mesh))
    if dp <= 1:
        return n
    return max(n + (-n % dp), dp)
