"""PartitionSpec construction for batches, parameters and decode caches.

`param_specs` is *name-based*: it walks the param pytree and assigns each
leaf a spec from what the layer code does with that tensor (column- vs
row-parallel projections, expert parallelism, head sharding).  Specs are
built against logical axis names and sanitized against the concrete mesh
only at application time (`with_shardings` / `shard_tree_specs`), so the
same spec tree serves any mesh — including ones where a dim doesn't divide
and the entry must quietly drop to replicated.

Megatron-style assignments (see `repro.models.layers`):

- embeddings / LM head shard the vocab dim over ``model`` (vocab is padded
  to ``tp * 128`` by `tp_align`);
- q/k/v projections shard the heads dim, the o-projection is row-parallel;
- MLP up/gate are column-parallel (d_ff), down is row-parallel;
- MoE expert stacks shard the expert dim over ``model`` (EP);
- Mamba z/x/conv/out shard the d_inner dim;
- norms, routers, biases and small SSM tensors replicate.
"""
from __future__ import annotations

import itertools
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .context import DATA_AXES, MODEL_AXIS

Tree = Any


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """The data-parallel axes present in `mesh`, outermost first — the
    subset of ``DATA_AXES`` (``("pod", "data")``) that `mesh` carries,
    ready to use as one tuple-entry of a `PartitionSpec`."""
    return tuple(a for a in DATA_AXES if a in mesh.shape)


def data_par_size(mesh: Mesh) -> int:
    """Total data-parallel shard count of `mesh` (product of data axes)."""
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def _entry_size(mesh: Mesh, entry: Any) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def batch_spec(mesh: Mesh, batch: int, ndim: int = 2) -> P:
    """Spec for a batch-leading array: dim 0 over the data axes, rest
    replicated.  Among the subsets of the data axes whose shard count
    divides `batch`, the one with the most shards wins (ties prefer the
    innermost axes, matching the old drop-outermost-first behavior), so
    odd global batches still shard over as much of the mesh as they can —
    including batches divisible only by an *outer* axis (e.g. batch 2 on
    a ("pod"=2, "data"=4) mesh shards over "pod", where dropping axes
    outermost-first fell all the way to replicated)."""
    axes = data_axes(mesh)
    idx = {a: i for i, a in enumerate(axes)}
    best: tuple[str, ...] | None = None
    best_key: tuple | None = None
    for r in range(1, len(axes) + 1):
        for combo in itertools.combinations(axes, r):
            if batch % _entry_size(mesh, combo):
                continue
            key = (_entry_size(mesh, combo),
                   tuple(idx[a] for a in combo))
            if best_key is None or key > best_key:
                best, best_key = combo, key
    return P(best, *([None] * (ndim - 1)))


def _sanitize(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Clamp `spec` to `shape`/`mesh`: pad to rank, drop axes the mesh
    doesn't have or whose shard count doesn't divide the dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for entry, dim in zip(entries, shape):
        if entry is not None:
            axes = entry if isinstance(entry, tuple) else (entry,)
            if any(a not in mesh.shape for a in axes):
                entry = None
            elif dim % _entry_size(mesh, entry):
                entry = None
        out.append(entry)
    return P(*out)


def _leaf_name(path: tuple) -> str:
    for key in reversed(path):
        if hasattr(key, "key"):        # DictKey
            return str(key.key)
        if hasattr(key, "name"):       # GetAttrKey
            return str(key.name)
    return ""


# tensors sharded over `model` at a fixed dim counted from the right:
#   -1 column-parallel (output-feature dim), -2 row-parallel (input-feature
#   dim), -3 heads/experts — leading stack dims (repeats R) shift from the
#   left, so right-indexing makes one rule cover stacked and unstacked.
_MODEL_DIM_BY_NAME = {
    # attention: (.., d, H, hd) q/k/v shard heads; (.., H, hd, d) o-proj
    "wq": -2, "wk": -2, "wv": -2, "wo": -3,
    # dense FFN: column-parallel up/gate, row-parallel down
    "w_up": -1, "w_gate": -1, "w_down": -2,
    # MoE expert stacks (.., E, d, ff) / (.., E, ff, d): expert parallelism
    "we_up": -3, "we_gate": -3, "we_down": -3,
    # Mamba: d_inner-sharded projections and conv, row-parallel out; the
    # per-head tensors (dt projection, decay/skip/bias) shard over the
    # same heads so manual-tp islands see consistent local shapes
    "w_z": -1, "w_x": -1, "conv_x": -1, "conv_bx": -1, "norm": -1,
    "w_dt": -1, "A_log": -1, "D": -1, "dt_bias": -1,
    "out_proj": -2,
    # LM head (d, vocab): vocab over model (padded by tp_align)
    "head": -1,
}


def _leaf_spec(name: str, ndim: int) -> P:
    if name == "embed":                # (vocab, d): vocab over model
        return P(MODEL_AXIS, *([None] * (ndim - 1)))
    dim = _MODEL_DIM_BY_NAME.get(name)
    if dim is None or ndim < -dim:
        return P(*([None] * ndim))
    entries = [None] * ndim
    entries[ndim + dim] = MODEL_AXIS
    return P(*entries)


def param_specs(params_abs: Tree) -> Tree:
    """PartitionSpec tree for a param tree (concrete or abstract).

    Mesh-independent: specs name the ``model`` axis; application-time
    sanitization handles meshes where a dim doesn't divide.
    """
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(_leaf_name(path), len(leaf.shape)),
        params_abs)


def stage_stack_specs(specs: Tree, axis: str = "stage") -> Tree:
    """Shard the leading repeats dim of a layer-stack spec tree over the
    pipeline `axis`.

    The canonical param layout stacks each pattern position's blocks along
    a leading `n_repeats` dim; when that dim divides the stage axis it
    shards so device s holds exactly its stage's contiguous repeats — the
    same slices the in-step ``(S, R/S, ...)`` reshape hands to
    `pipeline_apply*`.  Heterogeneous plans instead hand the executors a
    *padded* stage-stacked view whose leading dim is exactly `n_stages`
    (`repro.models.pipeline.stage_stack(sizes=...)`), which this spec
    shards unchanged; the canonical storage's non-dividing `n_repeats`
    dim then sanitizes to replicated at application time (`_sanitize`).
    Leading stack dims are never model-sharded (`_MODEL_DIM_BY_NAME`
    indexes from the right), so the entry is always free.
    """
    def s(spec: P) -> P:
        entries = list(spec)
        if not entries:
            # a rank-0 leaf has no dim to carry the stage entry; P(axis)
            # would be an invalid spec for a scalar and only fail much
            # later inside with_shardings / NamedSharding construction
            raise ValueError(
                "stage_stack_specs: rank-0 spec P() cannot take a leading "
                f"{axis!r} entry — stack block params along a leading "
                "repeats dim before sharding them over stages")
        if entries[0] is not None:
            raise ValueError(f"leading stack dim already sharded: {spec}")
        entries[0] = axis
        return P(*entries)

    return jax.tree.map(s, specs,
                        is_leaf=lambda l: isinstance(l, P))


def pipelined_param_specs(params_abs: Tree, pipelined: bool = False,
                          axis: str = "stage") -> Tree:
    """`param_specs`, with every layer stack's leading repeats dim
    stage-sharded when `pipelined`.

    The one spec tree the launch layer builds per mesh — `build` uses it
    for the initial placement and the elastic rebuild uses it to derive
    restore/reshard shardings for a *shrunk* mesh, so both paths agree
    by construction.  Mesh-independent like `param_specs`: a stage axis
    the repeats dim doesn't divide sanitizes to replicated at
    application time.
    """
    specs = param_specs(params_abs)
    if pipelined:
        specs = dict(specs)
        specs["layers"] = [stage_stack_specs(s, axis=axis)
                           for s in specs["layers"]]
    return specs


def pipeline_stage_specs(stacked_abs: Tree, mesh: Mesh,
                         axis: str = "stage") -> Tree:
    """`in_specs` for a pipeline island: `param_specs` composed with
    `stage_stack_specs`, sanitized against the concrete `mesh`.

    `stacked_abs` is one pattern position's stage-stacked block params
    (leaves ``(S, K, ...)`` — K = R/S for a uniform split, the padded
    chunk length otherwise; see `repro.models.pipeline.stage_stack`).
    Each leaf's spec carries the leading ``axis`` entry *and* its
    Megatron model-axis entry, so model-sharded leaves stay ``P("model")``
    inside the shard_map island instead of replicating over the model
    axis — the composition that lets pipeline stages run over
    tensor-sharded blocks.

    When `mesh` carries a model axis of size > 1, a leaf whose model dim
    does not divide it raises instead of quietly dropping to replicated:
    inside a *manual* island the layer code reduces row-parallel partial
    products with explicit ``psum("model")`` collectives, which would
    double-count a leaf that silently arrived replicated.  (Meshes
    without a model axis sanitize exactly as before — the entry drops.)
    """
    specs = stage_stack_specs(param_specs(stacked_abs), axis=axis)
    out = sanitize_specs(stacked_abs, specs, mesh)
    if mesh.shape.get(MODEL_AXIS, 1) > 1:
        bad = []

        def check(path, want, got):
            if MODEL_AXIS in tuple(want) and MODEL_AXIS not in tuple(got):
                bad.append(f"{jax.tree_util.keystr(path)}: {want}")
            return got

        jax.tree_util.tree_map_with_path(
            check, specs, out, is_leaf=lambda l: isinstance(l, P))
        if bad:
            raise ValueError(
                f"model axis (size {mesh.shape[MODEL_AXIS]}) does not "
                "divide the sharded dim of these stage-stacked leaves — "
                "pipeline islands need every model entry to apply (pad "
                "the config, e.g. tp_align, or lower model_par): "
                + "; ".join(bad))
    return out


def cache_specs(cache_abs: Tree, mesh: Mesh, global_batch: int) -> Tree:
    """Specs for the decode cache tree from `init_cache`.

    Stacked caches carry (repeats, batch, ...): batch shards over the data
    axes, the KV-heads / SSM-heads dim over ``model``.  The packed conv
    state's channel dim mixes d_inner and ssm_state, so only its batch dim
    shards.  `cur` (the step counter) and anything unrecognized replicate.
    """
    bspec = batch_spec(mesh, global_batch, 1)
    lead = bspec[0] if len(bspec) else None

    def spec(path, leaf):
        name = _leaf_name(path)
        ndim = len(leaf.shape)
        if name in ("k", "v") and ndim == 5:      # (R, B, S, Hkv, hd)
            return P(None, lead, None, MODEL_AXIS, None)
        if name == "ssm" and ndim == 5:           # (R, B, H, N, P)
            return P(None, lead, MODEL_AXIS, None, None)
        if name == "conv" and ndim == 4:          # (R, B, W-1, di+2N)
            return P(None, lead, None, None)
        if name == "enc_out" and ndim == 3:       # (B, F, d)
            return P(lead, None, None)
        return P(*([None] * ndim))

    return jax.tree_util.tree_map_with_path(spec, cache_abs)


def sanitize_specs(tree: Tree, specs: Tree, mesh: Mesh) -> Tree:
    """Clamp a spec tree against concrete leaf shapes and `mesh` (axes the
    mesh doesn't have, or whose shard count doesn't divide the dim, drop
    to replicated) — for building out_shardings on reduced meshes."""
    return jax.tree.map(lambda leaf, s: _sanitize(s, leaf.shape, mesh),
                        tree, specs)


def shard_tree_specs(tree: Tree, specs: Tree, mesh: Mesh) -> Tree:
    """ShapeDtypeStructs with concrete NamedShardings attached — the
    `.lower()` arguments for a dry-run (no device allocation)."""
    def to_sds(leaf, spec):
        spec = _sanitize(spec, leaf.shape, mesh)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(to_sds, tree, specs)


def with_shardings(tree: Tree, specs: Tree, mesh: Mesh) -> Tree:
    """device_put every leaf of `tree` with its (sanitized) spec:
    concrete arrays in, concrete `NamedSharding`-placed arrays out —
    the runtime sibling of `shard_tree_specs` (which builds abstract
    `.lower()` arguments instead)."""
    def put(leaf, spec):
        spec = _sanitize(spec, leaf.shape, mesh)
        return jax.device_put(leaf, NamedSharding(mesh, spec))
    return jax.tree.map(put, tree, specs)
