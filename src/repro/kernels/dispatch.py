"""Differentiable dispatch from the layer hot-spots to the Pallas kernels.

`repro.models.layers` branches on `repro.dist.context.kernel_mode()` at
trace time and calls into this module with ``mode="ref"`` or
``mode="pallas"`` — ``"ref"`` runs the kernels' pure-jnp oracles
(f32-accumulation numerics, no interpret-mode cost), ``"pallas"`` runs
the real `pallas_call` kernels (interpret mode on CPU).

Shapes are *local*: inside a shard_map pipeline island the operands
already carry tp-local head/expert/feature dims (`manual_tp_size()`
sliced them upstream), and this module never emits a collective — the
callers keep their explicit `psum` composition, so the kernels drop into
the PP×TP islands unchanged.

Gradients: `pallas_call` has no autodiff rule, so each pallas entry point
is a `jax.custom_vjp` whose forward is the kernel and whose backward is
the oracle's VJP (flash attention instead reuses the memory-linear
chunked backward from `repro.models.layers`, recomputing the forward
statistics rather than saving O(S²) probabilities).  On-hardware forward
speed, reference-exact gradients.

Block sizes: resolved per call as tuned-cache lookup → defaults, then
clamped to the largest divisor of the operand dim (`_divisor`) so shapes
that do not divide the default blocks take the shrunken-block edge path
instead of tripping the kernels' divisibility asserts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers as L

from .flash_attention.ops import flash_attention
from .fused_mlp.ops import fused_mlp
from .fused_mlp.ref import fused_mlp_ref
from .fused_rmsnorm.ops import fused_rmsnorm
from .moe_gmm.ops import moe_gmm
from .moe_gmm.ref import moe_gmm_ref
from .ssd_chunk.ops import ssd_chunked  # noqa: F401  (re-export)

MODES = ("ref", "pallas")

# defaults when the tuned cache has no entry (kernel signature defaults)
_DEFAULTS = {
    "flash_attention": {"q_blk": 256, "kv_blk": 256},
    "fused_mlp": {"bm": 128, "bff": 512},
    "fused_rmsnorm": {"bm": 256},
    "moe_gmm": {"bc": 128, "bf": 256, "bd": 256},
}


def _divisor(n: int, target: int) -> int:
    """Largest divisor of `n` that is ≤ `target` (the repo-wide clamp
    pattern — see `chunked_attention` / `pick_chunk`)."""
    d = max(min(target, n), 1)
    while n % d:
        d -= 1
    return d


def block_config(kernel: str, shape: tuple[int, ...], dtype,
                 phase: str = "fwd") -> dict:
    """Block sizes for one kernel call: tuned-cache entry if present,
    else the kernel defaults.  `shape` is the kernel-local operand shape
    (tp-local inside islands); lookup is keyed on it plus the manual tp
    degree, so a tuned pp×tp island shape never collides with the GSPMD
    one.

    ``phase="bwd"`` resolves the *backward* blocks (flash attention's
    chunked VJP): a tuned bwd entry wins, otherwise the fallback to the
    forward blocks is explicit here — not an implicit reuse inside the
    VJP — so the tuner and the planner's kernel-footprint model price
    the two phases separately."""
    from repro.dist.context import manual_tp_size

    from .tune import cached_config
    cfg = dict(_DEFAULTS.get(kernel, {}))
    tp = manual_tp_size()
    name = jnp.dtype(dtype).name
    cfg.update(cached_config(kernel, shape, name, tp=tp))
    if phase == "bwd":
        cfg.update(cached_config(kernel, shape, name, tp=tp, phase="bwd"))
    return cfg


# ------------------------------------------------------- flash attention
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash_pallas(q, k, v, causal, window, kv_offset, q_blk, kv_blk,
                  bwd_q_blk, bwd_kv_blk):
    return flash_attention(q, k, v, causal=causal, window=window,
                           kv_offset=kv_offset, q_blk=q_blk, kv_blk=kv_blk)


def _flash_pallas_fwd(q, k, v, causal, window, kv_offset, q_blk, kv_blk,
                      bwd_q_blk, bwd_kv_blk):
    out = _flash_pallas(q, k, v, causal, window, kv_offset, q_blk, kv_blk,
                        bwd_q_blk, bwd_kv_blk)
    # residuals: just q, k, v — the backward recomputes the online-softmax
    # statistics chunk-by-chunk (same memory-linear recompute strategy as
    # the XLA flash path; nothing O(S²) is saved)
    return out, (q, k, v)


def _flash_pallas_bwd(causal, window, kv_offset, q_blk, kv_blk,
                      bwd_q_blk, bwd_kv_blk, res, dout):
    # the chunked recompute runs at its *own* tuned block sizes
    # (block_config(phase="bwd") — equal to the forward's unless a bwd
    # entry was tuned)
    q, k, v = res
    out, lse = L._flash_fwd_scan(q, k, v, causal, window, bwd_q_blk,
                                 bwd_kv_blk, kv_offset)
    return L._flash_vjp_bwd(causal, window, bwd_q_blk, bwd_kv_blk,
                            kv_offset,
                            (q, k, v, out.astype(q.dtype), lse), dout)


_flash_pallas.defvjp(_flash_pallas_fwd, _flash_pallas_bwd)


def flash_mha(q, k, v, *, causal: bool, window: int = 0,
              kv_offset: int = 0, mode: str):
    """Kernel-path attention.  q: (B, Sq, Hq, D); k/v: (B, Skv, Hkv, D)
    with tp-local head counts; returns (B, Sq, Hq, D)."""
    if mode == "ref":
        return L.attention_ref(q, k, v, causal=causal, window=window,
                               kv_offset=kv_offset)
    B, Sq, Hq, D = q.shape
    Skv = k.shape[1]
    cfg = block_config("flash_attention", q.shape, q.dtype)
    bcfg = block_config("flash_attention", q.shape, q.dtype, phase="bwd")
    q_blk = _divisor(Sq, cfg["q_blk"])
    kv_blk = _divisor(Skv, cfg["kv_blk"])
    bwd_q_blk = _divisor(Sq, bcfg["q_blk"])
    bwd_kv_blk = _divisor(Skv, bcfg["kv_blk"])
    return _flash_pallas(q, k, v, causal, window, kv_offset, q_blk, kv_blk,
                         bwd_q_blk, bwd_kv_blk)


# ------------------------------------------------------------- fused MLP
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _mlp_gated_pallas(x, w_up, w_down, w_gate, act, bm, bff):
    return fused_mlp(x, w_up, w_down, w_gate, act=act, bm=bm, bff=bff)


def _mlp_gated_fwd(x, w_up, w_down, w_gate, act, bm, bff):
    out = _mlp_gated_pallas(x, w_up, w_down, w_gate, act, bm, bff)
    return out, (x, w_up, w_down, w_gate)


def _mlp_gated_bwd(act, bm, bff, res, dy):
    _, vjp = jax.vjp(
        lambda x, wu, wd, wg: fused_mlp_ref(x, wu, wd, wg, act=act), *res)
    return vjp(dy)


_mlp_gated_pallas.defvjp(_mlp_gated_fwd, _mlp_gated_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _mlp_plain_pallas(x, w_up, w_down, act, bm, bff):
    return fused_mlp(x, w_up, w_down, None, act=act, bm=bm, bff=bff)


def _mlp_plain_fwd(x, w_up, w_down, act, bm, bff):
    out = _mlp_plain_pallas(x, w_up, w_down, act, bm, bff)
    return out, (x, w_up, w_down)


def _mlp_plain_bwd(act, bm, bff, res, dy):
    _, vjp = jax.vjp(
        lambda x, wu, wd: fused_mlp_ref(x, wu, wd, None, act=act), *res)
    return vjp(dy)


_mlp_plain_pallas.defvjp(_mlp_plain_fwd, _mlp_plain_bwd)


def mlp(x, w_up, w_down, w_gate=None, *, act: str, mode: str):
    """Kernel-path FFN on a (..., d) activation; ff may be tp-local (the
    caller psums the partial output, mirroring `_row_parallel_einsum`)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if mode == "ref":
        out = fused_mlp_ref(x2, w_up, w_down, w_gate, act=act)
    else:
        T, ff = x2.shape[0], w_up.shape[1]
        cfg = block_config("fused_mlp", (T, x2.shape[1], ff), x.dtype)
        bm, bff = _divisor(T, cfg["bm"]), _divisor(ff, cfg["bff"])
        if w_gate is not None:
            out = _mlp_gated_pallas(x2, w_up, w_down, w_gate, act, bm, bff)
        else:
            out = _mlp_plain_pallas(x2, w_up, w_down, act, bm, bff)
    return out.reshape(*lead, out.shape[-1])


# -------------------------------------------------------------- RMSNorm
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm_pallas(x, scale, eps, bm):
    return fused_rmsnorm(x, scale, eps=eps, bm=bm)


def _rmsnorm_fwd(x, scale, eps, bm):
    return _rmsnorm_pallas(x, scale, eps, bm), (x, scale)


def _rmsnorm_bwd(eps, bm, res, dy):
    _, vjp = jax.vjp(lambda x, s: L.rmsnorm(x, s, eps=eps), *res)
    return vjp(dy)


_rmsnorm_pallas.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm(x, scale, *, eps: float = 1e-6, mode: str):
    """Kernel-path RMSNorm over the full last dim.  Callers must NOT use
    this for dims sharded inside a manual region (`_tp_rmsnorm` owns the
    psum'd variance there)."""
    if mode == "ref":
        return L.rmsnorm(x, scale, eps=eps)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    cfg = block_config("fused_rmsnorm", x2.shape, x.dtype)
    bm = _divisor(x2.shape[0], cfg["bm"])
    return _rmsnorm_pallas(x2, scale, eps, bm).reshape(*lead, x.shape[-1])


# -------------------------------------------------- MoE grouped matmul
@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _gmm_pallas(buf, w, bc, bf, bd):
    return moe_gmm(buf, w, bc=bc, bf=bf, bd=bd)


def _gmm_fwd(buf, w, bc, bf, bd):
    return _gmm_pallas(buf, w, bc, bf, bd), (buf, w)


def _gmm_bwd(bc, bf, bd, res, dy):
    buf, w = res
    dy32 = dy.astype(jnp.float32)
    d_buf = jnp.einsum("ecf,edf->ecd", dy32,
                       w.astype(jnp.float32)).astype(buf.dtype)
    d_w = jnp.einsum("ecd,ecf->edf", buf.astype(jnp.float32),
                     dy32).astype(w.dtype)
    return d_buf, d_w


_gmm_pallas.defvjp(_gmm_fwd, _gmm_bwd)


def gmm(buf, w, *, mode: str):
    """Expert-batched matmul.  buf: (G, E, C, d) capacity buffers with
    tp-local experts E; w: (E, d, f).  The group dim folds into capacity
    (w is indexed by expert only), so the kernel sees (E, G·C, d)."""
    G, E, C, d = buf.shape
    f = w.shape[-1]
    folded = buf.transpose(1, 0, 2, 3).reshape(E, G * C, d)
    if mode == "ref":
        out = moe_gmm_ref(folded, w)
    else:
        cfg = block_config("moe_gmm", (E, G * C, d, f), buf.dtype)
        bc = _divisor(G * C, cfg["bc"])
        bf = _divisor(f, cfg["bf"])
        bd = _divisor(d, cfg["bd"])
        out = _gmm_pallas(folded, w, bc, bf, bd)
    return out.reshape(E, G, C, f).transpose(1, 0, 2, 3)
