"""Jitted public wrapper for the grouped matmul kernel."""
import functools

import jax

from .kernel import moe_gmm_kernel


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def moe_gmm(buf, w, *, bc=128, bf=256, bd=256, interpret=True):
    return moe_gmm_kernel(buf, w, bc=bc, bf=bf, bd=bd, interpret=interpret)
