"""Pure-jnp oracle for the grouped matmul kernel."""
import jax.numpy as jnp


def moe_gmm_ref(buf, w):
    return jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(buf.dtype)
