"""MoE grouped (expert-batched) matmul Pallas kernel.

Computes y[e] = buf[e] @ w[e] over the capacity-buffer layout
(E, C, d) × (E, d, f) → (E, C, f) with one expert per grid row — the
perf-critical inner matmul of the MoE block.  Per-expert tiles stream
through VMEM; the d contraction is tiled and accumulated in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nd):
    kblk = pl.program_id(3)

    @pl.when(kblk == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[0].astype(jnp.float32), w_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kblk == nd - 1)
    def _():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def moe_gmm_kernel(buf, w, *, bc: int = 128, bf: int = 256, bd: int = 256,
                   interpret: bool = True):
    """buf: (E, C, d); w: (E, d, f) → (E, C, f)."""
    E, C, d = buf.shape
    _, _, f = w.shape
    bc = min(bc, C)
    bf = min(bf, f)
    bd = min(bd, d)
    assert C % bc == 0 and f % bf == 0 and d % bd == 0
    grid = (E, C // bc, f // bf, d // bd)

    return pl.pallas_call(
        functools.partial(_gmm_kernel, nd=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, c, fb, kb: (e, c, kb)),
            pl.BlockSpec((1, bd, bf), lambda e, c, fb, kb: (e, kb, fb)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, c, fb, kb: (e, c, fb)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), buf.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(buf, w)
