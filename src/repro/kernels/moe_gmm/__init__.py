from .ops import moe_gmm
from .ref import moe_gmm_ref

__all__ = ["moe_gmm", "moe_gmm_ref"]
