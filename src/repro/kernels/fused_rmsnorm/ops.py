"""Jitted public wrapper for the fused RMSNorm kernel."""
import functools

import jax

from .kernel import fused_rmsnorm_kernel


@functools.partial(jax.jit, static_argnames=("eps", "bm", "interpret"))
def fused_rmsnorm(x, scale, *, eps=1e-6, bm=256, interpret=True):
    return fused_rmsnorm_kernel(x, scale, eps=eps, bm=bm,
                                interpret=interpret)
