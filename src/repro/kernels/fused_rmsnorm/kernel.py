"""Fused RMSNorm Pallas kernel: variance, rsqrt, scale in one VMEM pass."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def fused_rmsnorm_kernel(x, scale, *, eps: float = 1e-6, bm: int = 256,
                         interpret: bool = True):
    """x: (T, d); scale: (d,) → (T, d)."""
    T, d = x.shape
    bm = min(bm, T)
    assert T % bm == 0
    import functools
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(T // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=interpret,
    )(x, scale)
