"""Pure-jnp oracle for the fused RMSNorm kernel."""
from repro.models.layers import rmsnorm


def fused_rmsnorm_ref(x, scale, *, eps=1e-6):
    return rmsnorm(x, scale, eps=eps)
