from .ops import fused_rmsnorm
from .ref import fused_rmsnorm_ref

__all__ = ["fused_rmsnorm", "fused_rmsnorm_ref"]
