"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; `interpret=False` on real TPUs).

flash_attention — id-queue-remapped block-skipping flash attention
fused_mlp       — up/act/down fusion through VMEM
moe_gmm         — expert-batched grouped matmul
ssd_chunk       — Mamba-2 SSD intra-chunk fusion
fused_rmsnorm   — one-pass RMSNorm
"""
from .flash_attention import flash_attention, flash_attention_ref
from .fused_mlp import fused_mlp, fused_mlp_ref
from .moe_gmm import moe_gmm, moe_gmm_ref
from .ssd_chunk import ssd_chunk, ssd_chunk_ref, ssd_chunked
from .fused_rmsnorm import fused_rmsnorm, fused_rmsnorm_ref

__all__ = [
    "flash_attention", "flash_attention_ref",
    "fused_mlp", "fused_mlp_ref",
    "moe_gmm", "moe_gmm_ref",
    "ssd_chunk", "ssd_chunk_ref", "ssd_chunked",
    "fused_rmsnorm", "fused_rmsnorm_ref",
]
