"""Block-size autotuner for the Pallas kernels.

MKPipe picks kernel attributes ahead of time from a static model of the
pipeline; this is the per-kernel analogue.  For one (kernel, operand
shape, dtype, tp degree) the tuner:

1. enumerates legal block geometries — divisors of the blocked dims
   around the power-of-two sweet spots (`enumerate_candidates`),
2. screens each candidate through the mklint MK-K geometry checks
   (`repro.analysis.kernels.check_kernel_builder`) so only configs
   whose grid/index-map/coverage arithmetic is sound are ever lowered
   ("not crashing inside pallas_call" is a *verified* property, not an
   observed one),
3. times the survivors with the benchmark harness's median-wall-clock
   `time_fn` (interpret mode on CPU — a relative ordering; on real TPUs
   the same tuner runs with ``interpret=False``),
4. persists the winner in a versioned JSON cache keyed by
   ``kernel|shape|dtype|tp|phase`` — backward block sizes
   (``phase="bwd"``, flash attention's chunked VJP) are tuned and
   stored explicitly rather than silently reusing the forward chunks.

`repro.kernels.dispatch.block_config` consults `cached_config` at trace
time: cache hit → tuned blocks; miss, stale, or corrupt → kernel
defaults (dispatch still clamps with `_divisor`, so a wrong cache can
slow a kernel down but never break it).  Stale means the stored config
no longer passes the MK-K screen for its own key — e.g. a hand-edited
cache or a kernel whose geometry rules tightened since tuning.

``ssd_chunk`` takes no block arguments (its grid is (batch·chunks,
heads)); the chunk length is a model config (`cfg.ssm_chunk`), so it is
deliberately absent here.

CLI::

  python -m repro.kernels.tune --kernel fused_mlp --shape 256,64,192 \
      --dtype float32 --cache results/kernel_tune.json
  python -m repro.kernels.tune --preset smoke     # the smoke-mesh shapes
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
from typing import Any, Callable

import numpy as np

# v2: cache keys carry the phase (``|fwd`` / ``|bwd``) so backward block
# sizes are tuned and stored explicitly instead of silently reusing the
# forward chunks; v1 caches degrade to empty (retune) by design
CACHE_VERSION = 2
DEFAULT_CACHE = os.path.join("results", "kernel_tune.json")

# candidate block sizes are divisors of the blocked dim nearest these
# targets (power-of-two ladder; `_divisor` handles non-pow2 dims)
_TARGETS = (16, 32, 64, 128, 256, 512)

# which operand dim each tunable block argument divides, per kernel,
# against the shape tuple `dispatch.block_config` passes:
#   flash_attention: q.shape = (B, S, Hq, D)       q_blk, kv_blk | S
#   fused_mlp:       (T, d, ff)                    bm | T, bff | ff
#   fused_rmsnorm:   (T, d)                        bm | T
#   moe_gmm:         (E, C, d, f)                  bc | C, bd | d, bf | f
PARAM_DIMS: dict[str, dict[str, int]] = {
    "flash_attention": {"q_blk": 1, "kv_blk": 1},
    "fused_mlp": {"bm": 0, "bff": 2},
    "fused_rmsnorm": {"bm": 0},
    "moe_gmm": {"bc": 1, "bd": 2, "bf": 3},
}

KERNELS = tuple(PARAM_DIMS)

# kernels whose *backward* consumes block sizes: flash attention's VJP
# re-runs the chunked fwd scan + a chunked bwd scan with its own
# (q_blk, kv_blk); the other kernels' backwards are blockless ref VJPs
BWD_KERNELS = ("flash_attention",)
PHASES = ("fwd", "bwd")


def _divisor(n: int, target: int) -> int:
    d = max(min(target, n), 1)
    while n % d:
        d -= 1
    return d


def cache_key(kernel: str, shape: tuple[int, ...], dtype: str,
              tp: int = 1, phase: str = "fwd") -> str:
    if phase not in PHASES:
        raise ValueError(f"unknown phase {phase!r}; want {PHASES}")
    return (f"{kernel}|{'x'.join(str(int(s)) for s in shape)}|{dtype}"
            f"|tp{tp}|{phase}")


def enumerate_candidates(kernel: str, shape: tuple[int, ...],
                         max_candidates: int = 32) -> list[dict[str, int]]:
    """Legal block configs for one call: per parameter, the divisors of
    its dim nearest the power-of-two ladder; the cartesian product,
    deterministically capped."""
    dims = PARAM_DIMS[kernel]
    per_param: list[list[tuple[str, int]]] = []
    for param, axis in dims.items():
        n = int(shape[axis])
        sizes = sorted({_divisor(n, t) for t in _TARGETS} | {n})
        per_param.append([(param, s) for s in sizes])
    configs = [dict(combo) for combo in itertools.product(*per_param)]
    # cap from the middle outward: extremes (all-tiny, all-full) are the
    # least likely winners, and the order stays deterministic
    if len(configs) > max_candidates:
        mid = len(configs) // 2
        half = max_candidates // 2
        configs = configs[mid - half:mid - half + max_candidates]
    return configs


# ------------------------------------------------------------ builders
def _builder(kernel: str, shape: tuple[int, ...],
             config: dict[str, int]) -> Callable[[], Any]:
    """A zero-input builder for `check_kernel_builder`: runs the kernel's
    construction eagerly on numpy zeros (nothing lowers under the
    recorder), with `config`'s block sizes."""
    f32 = np.float32
    if kernel == "flash_attention":
        B, S, Hq, D = shape
        q = np.zeros((B, S, Hq, D), f32)
        k = np.zeros((B, S, max(Hq // 2, 1), D), f32)

        def build():
            from .flash_attention.kernel import flash_attention_kernel
            flash_attention_kernel(q, k, k, causal=True, **config)
    elif kernel == "fused_mlp":
        T, d, ff = shape
        x = np.zeros((T, d), f32)
        wu = np.zeros((d, ff), f32)
        wd = np.zeros((ff, d), f32)

        def build():
            from .fused_mlp.kernel import fused_mlp_kernel
            fused_mlp_kernel(x, wu, wd, np.zeros((d, ff), f32), **config)
    elif kernel == "fused_rmsnorm":
        T, d = shape
        x = np.zeros((T, d), f32)

        def build():
            from .fused_rmsnorm.kernel import fused_rmsnorm_kernel
            fused_rmsnorm_kernel(x, np.zeros((d,), f32), **config)
    elif kernel == "moe_gmm":
        E, C, d, f = shape
        buf = np.zeros((E, C, d), f32)
        w = np.zeros((E, d, f), f32)

        def build():
            from .moe_gmm.kernel import moe_gmm_kernel
            moe_gmm_kernel(buf, w, **config)
    else:
        raise ValueError(f"unknown tunable kernel {kernel!r}; "
                         f"tunable: {KERNELS}")
    return build


def validate_candidate(kernel: str, shape: tuple[int, ...],
                       config: dict[str, int],
                       phase: str = "fwd") -> list:
    """MK-K screen one candidate.  No *errors* ⇒ the geometry is sound
    (blocks divide, index maps in bounds, outputs covered); degraded
    geometries (MK-K008 clamp collapse) come back as warning-severity
    diagnostics that flag but do not disqualify — filter with
    `screen_errors` to decide legality.

    ``phase="bwd"`` screens backward block configs: the chunked-VJP
    kernels (`BWD_KERNELS`) reshape operands by the chunk sizes, so the
    screen is divisibility (plus the clamp warning) — there is no
    pallas_call to record."""
    if kernel not in PARAM_DIMS:
        return [f"unknown kernel {kernel!r}"]
    if set(config) != set(PARAM_DIMS[kernel]):
        return [f"config keys {sorted(config)} != expected "
                f"{sorted(PARAM_DIMS[kernel])}"]
    if phase == "bwd":
        if kernel not in BWD_KERNELS:
            return [f"kernel {kernel!r} has a blockless ref-VJP "
                    f"backward; nothing to tune for phase='bwd'"]
        diags: list = []
        for param, axis in PARAM_DIMS[kernel].items():
            n, b = int(shape[axis]), int(config[param])
            if b < 1 or n % b:
                diags.append(f"{param}={b} does not divide dim {n} "
                             f"(shape {tuple(shape)})")
        return diags + _clamp_warnings(kernel, shape, config)
    from repro.analysis.kernels import check_kernel_builder
    return check_kernel_builder(kernel, _builder(kernel, shape, config))


def _clamp_warnings(kernel: str, shape: tuple[int, ...],
                    config: dict[str, int]) -> list:
    """MK-K008 for configs screened without a recorded pallas_call
    (the bwd phase): flag block args sitting exactly where the ladder
    clamp lands a ragged dim, under half the pow2 target."""
    from repro.analysis.kernels import check_block_clamp
    out: list = []
    for param, axis in PARAM_DIMS[kernel].items():
        n, b = int(shape[axis]), int(config.get(param, 0))
        t = max((t for t in _TARGETS if t <= n), default=0)
        if t and b == _divisor(n, t):
            out.extend(check_block_clamp(kernel, f"{param} (bwd)", n, t))
    return out


def screen_errors(diags: list) -> list:
    """Error-severity findings only: legacy strings count as errors,
    warning Diagnostics (MK-K008) do not disqualify a candidate."""
    return [d for d in diags
            if not hasattr(d, "severity") or d.is_error]


# -------------------------------------------------------------- timing
def _time_fn_fallback(fn, *args, repeats=5, warmup=2):
    import time

    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _get_time_fn():
    try:
        from benchmarks.common import time_fn    # repo-root harness
        return time_fn
    except ImportError:
        return _time_fn_fallback


def _timed_call(kernel: str, shape: tuple[int, ...], dtype: str,
                config: dict[str, int]):
    """(fn, args) running the real jitted kernel with `config`."""
    import jax.numpy as jnp

    def arr(*s):
        n = int(np.prod(s))
        return (jnp.arange(n, dtype=jnp.float32).reshape(*s) / n
                ).astype(dtype)

    if kernel == "flash_attention":
        from .flash_attention.ops import flash_attention
        B, S, Hq, D = shape
        q, k = arr(B, S, Hq, D), arr(B, S, max(Hq // 2, 1), D)
        return (lambda a, b, c: flash_attention(
            a, b, c, causal=True, **config)), (q, k, k)
    if kernel == "fused_mlp":
        from .fused_mlp.ops import fused_mlp
        T, d, ff = shape
        return (lambda x, wu, wd, wg: fused_mlp(
            x, wu, wd, wg, **config)), (
            arr(T, d), arr(d, ff), arr(ff, d), arr(d, ff))
    if kernel == "fused_rmsnorm":
        from .fused_rmsnorm.ops import fused_rmsnorm
        T, d = shape
        return (lambda x, s: fused_rmsnorm(x, s, **config)), (
            arr(T, d), arr(d))
    if kernel == "moe_gmm":
        from .moe_gmm.ops import moe_gmm
        E, C, d, f = shape
        return (lambda b, w: moe_gmm(b, w, **config)), (
            arr(E, C, d), arr(E, d, f))
    raise ValueError(f"unknown tunable kernel {kernel!r}")


def _timed_call_bwd(kernel: str, shape: tuple[int, ...], dtype: str,
                    config: dict[str, int]):
    """(fn, args) running the kernel's *backward* with `config`'s chunk
    sizes.  flash attention's VJP is the chunked recompute in
    `repro.models.layers` (`_flash_fwd_scan` + `_flash_vjp_bwd`) — a
    raw pallas_call has no autodiff rule, so the backward is timed
    directly at candidate chunk geometry rather than through jax.grad
    of the kernel."""
    import jax
    import jax.numpy as jnp

    from repro.models import layers as L

    if kernel not in BWD_KERNELS:
        raise ValueError(f"kernel {kernel!r} has a blockless ref-VJP "
                         "backward; nothing to time for phase='bwd'")

    def arr(*s):
        n = int(np.prod(s))
        return (jnp.arange(n, dtype=jnp.float32).reshape(*s) / n
                ).astype(dtype)

    B, S, Hq, D = shape
    q, k = arr(B, S, Hq, D), arr(B, S, max(Hq // 2, 1), D)
    dout = arr(B, S, Hq, D)
    q_blk, kv_blk = config["q_blk"], config["kv_blk"]

    @jax.jit
    def bwd(q, k, v, dout):
        out, lse = L._flash_fwd_scan(q, k, v, True, 0, q_blk, kv_blk, 0)
        return L._flash_vjp_bwd(True, 0, q_blk, kv_blk, 0,
                                (q, k, v, out.astype(q.dtype), lse), dout)

    return bwd, (q, k, k, dout)


# --------------------------------------------------------------- cache
def load_cache(path: str | None = None) -> dict:
    """Read the tuned-config cache; any corruption (unreadable JSON,
    wrong version, wrong top-level shape) degrades to an empty cache —
    never an exception on the training hot path."""
    path = path or DEFAULT_CACHE
    empty = {"version": CACHE_VERSION, "entries": {}}
    try:
        with open(path) as fh:
            cache = json.load(fh)
    except (OSError, ValueError):
        return empty
    if (not isinstance(cache, dict)
            or cache.get("version") != CACHE_VERSION
            or not isinstance(cache.get("entries"), dict)):
        return empty
    return cache


def save_cache(cache: dict, path: str | None = None) -> str:
    path = path or DEFAULT_CACHE
    if os.path.dirname(path):
        os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as fh:
        json.dump(cache, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return path


_MEMO: dict[tuple[str, str | None], dict[str, int]] = {}


def cached_config(kernel: str, shape: tuple[int, ...], dtype: str,
                  tp: int = 1, phase: str = "fwd",
                  path: str | None = None) -> dict[str, int]:
    """Read-only tuned-config lookup for `dispatch.block_config`.

    Keys carry the phase: ``phase="bwd"`` returns only explicitly tuned
    backward blocks ({} when the backward was never tuned — the caller
    decides the fallback, which `dispatch.block_config` makes the
    forward blocks).  Returns {} on miss, on a corrupt cache, and on a
    *stale* entry (one that no longer passes the MK-K error screen for
    its own key) — the caller falls back, and the next `tune` run
    overwrites the bad entry.  Memoized per (key, path): the screen
    runs once per process, not per trace."""
    key = cache_key(kernel, shape, dtype, tp, phase)
    memo_key = (key, path)
    if memo_key in _MEMO:
        return dict(_MEMO[memo_key])
    entry = load_cache(path)["entries"].get(key)
    config: dict[str, int] = {}
    if isinstance(entry, dict) and isinstance(entry.get("config"), dict):
        cand = {k: v for k, v in entry["config"].items()
                if isinstance(v, int) and v > 0}
        if not screen_errors(validate_candidate(kernel, tuple(shape),
                                                cand, phase=phase)):
            config = cand
    _MEMO[memo_key] = config
    return dict(config)


# ---------------------------------------------------------------- tune
def tune(kernel: str, shape: tuple[int, ...], dtype: str = "float32",
         tp: int = 1, phase: str = "fwd", path: str | None = None,
         repeats: int = 3, max_candidates: int = 16,
         verbose: bool = False) -> dict:
    """Tune one (kernel, shape, dtype, tp, phase) cell and persist the
    winner.  ``phase="bwd"`` tunes the backward's own block sizes
    (`BWD_KERNELS` only) and stores them under the phase-keyed cache
    key — `dispatch.block_config(phase="bwd")` picks them up, and falls
    back to the forward blocks explicitly when the backward was never
    tuned.

    Candidates are disqualified only by MK-K *errors*; warning-severity
    findings (MK-K008 degraded clamp geometry) stay legal and are
    reported for the winner.  Returns the cache entry:
    ``{"config", "us", "n_candidates"}``."""
    shape = tuple(int(s) for s in shape)
    candidates = enumerate_candidates(kernel, shape,
                                      max_candidates=max_candidates)
    legal = [c for c in candidates if not screen_errors(
        validate_candidate(kernel, shape, c, phase=phase))]
    if not legal:
        raise ValueError(
            f"no candidate block config for {kernel} {shape} "
            f"(phase={phase}) passed the MK-K geometry screen — the "
            "shape itself is likely invalid")
    time_fn = _get_time_fn()
    timed_call = _timed_call_bwd if phase == "bwd" else _timed_call
    best, best_t = None, float("inf")
    for config in legal:
        fn, args = timed_call(kernel, shape, dtype, config)
        t = time_fn(fn, *args, repeats=repeats, warmup=1)
        if verbose:
            print(f"  {kernel} [{phase}] {config}: {t * 1e6:.0f}us")
        if t < best_t:
            best, best_t = config, t
    for diag in validate_candidate(kernel, shape, best, phase=phase):
        print(f"  {cache_key(kernel, shape, dtype, tp, phase)}: "
              f"{diag.format() if hasattr(diag, 'format') else diag}")
    entry = {"config": best, "us": round(best_t * 1e6, 1),
             "n_candidates": len(legal)}
    cache = load_cache(path)
    cache["entries"][cache_key(kernel, shape, dtype, tp, phase)] = entry
    save_cache(cache, path)
    _MEMO.pop((cache_key(kernel, shape, dtype, tp, phase), path), None)
    return entry


# the smoke-mesh shapes the parity/e2e tests trace (tp-local halves of
# the granite/jamba smoke configs included as tp=2 cells)
_SMOKE_CELLS: list[tuple[str, tuple[int, ...], int]] = [
    ("flash_attention", (2, 64, 4, 16), 1),
    ("flash_attention", (2, 64, 2, 16), 2),
    ("fused_mlp", (128, 64, 192), 1),
    ("fused_mlp", (128, 64, 96), 2),
    ("fused_rmsnorm", (128, 64), 1),
    ("moe_gmm", (4, 64, 64, 128), 1),
    ("moe_gmm", (2, 64, 64, 128), 2),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="autotune Pallas kernel block sizes (MK-K screened)")
    ap.add_argument("--kernel", choices=list(KERNELS))
    ap.add_argument("--shape",
                    help="comma-separated operand shape, e.g. 256,64,192")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--tp", type=int, default=1,
                    help="manual tp degree the shape is local to")
    ap.add_argument("--phase", choices=list(PHASES), default="fwd",
                    help="tune forward kernel blocks or the chunked-VJP "
                         "backward blocks (flash attention)")
    ap.add_argument("--cache", default=None,
                    help=f"cache path (default {DEFAULT_CACHE})")
    ap.add_argument("--preset", choices=["smoke"],
                    help="tune the smoke-mesh shape matrix instead of "
                         "one --kernel/--shape cell")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--max-candidates", type=int, default=16)
    ap.add_argument("-v", "--verbose", action="store_true")
    args = ap.parse_args(argv)

    if args.preset == "smoke":
        cells = [(k, s, tp, args.dtype, "fwd") for k, s, tp in
                 _SMOKE_CELLS]
        # the phase-keyed cells: backward chunk sizes for the kernels
        # whose VJP consumes them
        cells += [(k, s, tp, args.dtype, "bwd") for k, s, tp in
                  _SMOKE_CELLS if k in BWD_KERNELS]
    elif args.kernel and args.shape:
        shape = tuple(int(s) for s in args.shape.split(","))
        cells = [(args.kernel, shape, args.tp, args.dtype, args.phase)]
    else:
        ap.error("pass --kernel and --shape, or --preset smoke")
    for kernel, shape, tp, dtype, phase in cells:
        entry = tune(kernel, shape, dtype, tp=tp, phase=phase,
                     path=args.cache, repeats=args.repeats,
                     max_candidates=args.max_candidates,
                     verbose=args.verbose)
        print(f"{cache_key(kernel, shape, dtype, tp, phase)}: "
              f"{entry['config']}  ({entry['us']}us over "
              f"{entry['n_candidates']} candidates)")
    print(f"cache: {args.cache or DEFAULT_CACHE}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
