"""Jitted public wrapper for the flash attention kernel."""
import functools

import jax

from .kernel import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_blk", "kv_blk", "kv_offset", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_blk=256,
                    kv_blk=256, kv_offset=0, interpret=True):
    return flash_attention_kernel(
        q, k, v, causal=causal, window=window, q_blk=q_blk, kv_blk=kv_blk,
        kv_offset=kv_offset, interpret=interpret)
