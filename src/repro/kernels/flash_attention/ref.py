"""Pure-jnp oracle for the flash attention kernel."""
from repro.models.layers import attention_ref


def flash_attention_ref(q, k, v, *, causal=True, window=0, kv_offset=0):
    return attention_ref(q, k, v, causal=causal, window=window,
                         kv_offset=kv_offset)
