"""Flash attention Pallas TPU kernel with id-queue grid remapping.

The grid's pair dimension enumerates ONLY the visible (q-block, kv-block)
pairs — the same `visible_pairs` schedule the MKPipe dependency analysis
produces (§5.4.4 workgroup-id remapping, applied as causal/SWA block
skipping).  Masked-out blocks are never scheduled, so the kernel does the
exact lower-triangle / window FLOPs, and the intermediate probabilities
never leave VMEM (the paper's "fusion removes global-memory round-trips").

Grid: (batch × kv_heads, n_pairs) via PrefetchScalarGridSpec — the pair
tables are scalar-prefetch operands consumed by the BlockSpec index maps
(the Pallas version of the paper's constant-memory id_queue).  Pairs are
row-major in q, so each output block is revisited by consecutive steps;
online-softmax state (acc, m, l) persists in VMEM scratch and resets at
each row's first pair.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.models.layers import visible_pairs

NEG_INF = -1e30


def _attn_kernel(pair_i_ref, pair_j_ref, row_start_ref, row_end_ref,
                 q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, *,
                 q_blk: int, kv_blk: int, causal: bool, window: int,
                 kv_offset: int, scale: float):
    p = pl.program_id(1)
    i = pair_i_ref[p]
    j = pair_j_ref[p]

    @pl.when(row_start_ref[p] == 1)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)            # (g*q_blk, d)
    k = k_ref[0].astype(jnp.float32)            # (kv_blk, d)
    v = v_ref[0].astype(jnp.float32)            # (kv_blk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % q_blk
    cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    qpos = i * q_blk + rows + kv_offset
    kpos = j * kv_blk + cols
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p_ = jnp.exp(s - m_new)
    p_ = jnp.where(mask, p_, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p_.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p_, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(row_end_ref[p] == 1)
    def _():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def build_pair_tables(nq, nk, *, causal, window, q_blk, kv_blk, kv_offset):
    pairs = visible_pairs(nq, nk, causal=causal, window=window,
                          q_chunk=q_blk, kv_chunk=kv_blk,
                          kv_offset=kv_offset)
    pair_i = np.asarray([p[0] for p in pairs], np.int32)
    pair_j = np.asarray([p[1] for p in pairs], np.int32)
    row_start = np.zeros(len(pairs), np.int32)
    row_end = np.zeros(len(pairs), np.int32)
    seen: set[int] = set()
    last_of: dict[int, int] = {}
    for idx, (i, _j) in enumerate(pairs):
        if i not in seen:
            row_start[idx] = 1
            seen.add(i)
        last_of[i] = idx
    for idx in last_of.values():
        row_end[idx] = 1
    return pair_i, pair_j, row_start, row_end


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           q_blk: int = 256, kv_blk: int = 256,
                           kv_offset: int = 0,
                           interpret: bool = True):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D).  Returns (B, Sq, Hq, D).

    (batch, kv_head) fold into grid dim 0; the g query heads of a KV group
    ride along in the q block (rows are g-major).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    q_blk = min(q_blk, Sq)
    kv_blk = min(kv_blk, Skv)
    assert Sq % q_blk == 0 and Skv % kv_blk == 0
    nq, nk = Sq // q_blk, Skv // kv_blk
    scale = 1.0 / math.sqrt(D)

    pair_i, pair_j, row_start, row_end = build_pair_tables(
        nq, nk, causal=causal, window=window, q_blk=q_blk, kv_blk=kv_blk,
        kv_offset=kv_offset)

    qf = (q.reshape(B, Sq, Hkv, g, D).transpose(0, 2, 1, 3, 4)
          .reshape(B * Hkv, nq, q_blk, g, D)
          .transpose(0, 1, 3, 2, 4).reshape(B * Hkv, nq * g * q_blk, D))
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)

    grid = (B * Hkv, len(pair_i))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g * q_blk, D),
                         lambda b, p, pi, pj, rs, re: (b, pi[p], 0)),
            pl.BlockSpec((1, kv_blk, D),
                         lambda b, p, pi, pj, rs, re: (b, pj[p], 0)),
            pl.BlockSpec((1, kv_blk, D),
                         lambda b, p, pi, pj, rs, re: (b, pj[p], 0)),
        ],
        out_specs=pl.BlockSpec((1, g * q_blk, D),
                               lambda b, p, pi, pj, rs, re: (b, pi[p], 0)),
        scratch_shapes=[
            pltpu.VMEM((g * q_blk, D), jnp.float32),
            pltpu.VMEM((g * q_blk, 1), jnp.float32),
            pltpu.VMEM((g * q_blk, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, q_blk=q_blk, kv_blk=kv_blk, causal=causal,
            window=window, kv_offset=kv_offset, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, nq * g * q_blk, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(pair_i), jnp.asarray(pair_j),
      jnp.asarray(row_start), jnp.asarray(row_end), qf, kf, vf)

    out = (out.reshape(B, Hkv, nq, g, q_blk, D).transpose(0, 2, 4, 1, 3, 5)
           .reshape(B, Sq, Hq, D))
    return out
