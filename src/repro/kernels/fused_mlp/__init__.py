from .ops import fused_mlp
from .ref import fused_mlp_ref

__all__ = ["fused_mlp", "fused_mlp_ref"]
