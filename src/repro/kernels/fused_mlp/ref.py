"""Pure-jnp oracle for the fused MLP kernel."""
import jax
import jax.numpy as jnp


def _act(h, kind):
    if kind == "silu":
        return jax.nn.silu(h)
    if kind == "relu2":
        r = jnp.maximum(h, 0.0)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(kind)


def fused_mlp_ref(x, w_up, w_down, w_gate=None, *, act="silu"):
    x32 = x.astype(jnp.float32)
    u = x32 @ w_up.astype(jnp.float32)
    if w_gate is not None:
        h = _act(x32 @ w_gate.astype(jnp.float32), act) * u
    else:
        h = _act(u, act)
    return (h @ w_down.astype(jnp.float32)).astype(x.dtype)
