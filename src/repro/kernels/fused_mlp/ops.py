"""Jitted public wrapper for the fused MLP kernel."""
import functools

import jax

from .kernel import fused_mlp_kernel


@functools.partial(jax.jit, static_argnames=("act", "bm", "bff", "interpret"))
def fused_mlp(x, w_up, w_down, w_gate=None, *, act="silu", bm=128, bff=512,
              interpret=True):
    return fused_mlp_kernel(x, w_up, w_down, w_gate, act=act, bm=bm,
                            bff=bff, interpret=interpret)
