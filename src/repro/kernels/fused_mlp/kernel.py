"""Fused MLP Pallas kernel: up-proj → activation (gated or plain) →
down-proj in one pass — the hidden (tokens × d_ff) activation never leaves
VMEM (MKPipe kernel-fusion plan applied to the FFN stage pair).

Grid (m_blocks, ff_blocks): each step computes one (bm × bff) hidden tile
from the resident x tile, multiplies into the down projection, and
accumulates the (bm × d) output tile in VMEM scratch across ff blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _act(h, kind):
    if kind == "silu":
        return jax.nn.silu(h)
    if kind == "relu2":
        r = jnp.maximum(h, 0.0)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(kind)


def _mlp_kernel(x_ref, wu_ref, wd_ref, o_ref, acc_ref, *, nff, act):
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    h = jax.lax.dot_general(x, wu_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = _act(h, act)
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f == nff - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _mlp_gated_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
                      nff, act):
    f = pl.program_id(1)

    @pl.when(f == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    g = jax.lax.dot_general(x, wg_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = _act(g, act) * u
    acc_ref[...] += jax.lax.dot_general(
        h, wd_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(f == nff - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def fused_mlp_kernel(x, w_up, w_down, w_gate=None, *, act="silu",
                     bm: int = 128, bff: int = 512, interpret: bool = True):
    """x: (T, d); w_up/w_gate: (d, ff); w_down: (ff, d) → (T, d)."""
    T, d = x.shape
    ff = w_up.shape[1]
    bm = min(bm, T)
    bff = min(bff, ff)
    assert T % bm == 0 and ff % bff == 0
    grid = (T // bm, ff // bff)

    if w_gate is not None:
        kernel = functools.partial(_mlp_gated_kernel, nff=grid[1], act=act)
        in_specs = [
            pl.BlockSpec((bm, d), lambda i, f: (i, 0)),
            pl.BlockSpec((d, bff), lambda i, f: (0, f)),
            pl.BlockSpec((d, bff), lambda i, f: (0, f)),
            pl.BlockSpec((bff, d), lambda i, f: (f, 0)),
        ]
        args = (x, w_gate, w_up, w_down)
    else:
        kernel = functools.partial(_mlp_kernel, nff=grid[1], act=act)
        in_specs = [
            pl.BlockSpec((bm, d), lambda i, f: (i, 0)),
            pl.BlockSpec((d, bff), lambda i, f: (0, f)),
            pl.BlockSpec((bff, d), lambda i, f: (f, 0)),
        ]
        args = (x, w_up, w_down)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, d), lambda i, f: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, d), jnp.float32)],
        interpret=interpret,
    )(*args)
