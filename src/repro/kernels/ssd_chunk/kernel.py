"""Mamba-2 SSD intra-chunk Pallas kernel.

For one (batch·chunk, head) grid cell it computes the chunk-local quadratic
term and the chunk's input-state contribution:

  y[i]  = Σ_{j≤i} (C_i·B_j) · exp(cum_i − cum_j) · dt_j · x_j
  s_in  = Σ_j  exp(cum_end − cum_j) · dt_j · B_j ⊗ x_j

(cum = within-chunk cumulative log-decay, per head).  The decay matrix and
the masked score matrix stay in VMEM — this is the fusion of the SSD
"attention-like" stage pair.  The cross-chunk recurrence (a tiny scan over
nc states) remains in XLA where it belongs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_ref):
    h = pl.program_id(1)
    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (1, Q)
    bm = b_ref[0].astype(jnp.float32)          # (Q, N)
    cm = c_ref[0].astype(jnp.float32)          # (Q, N)
    A = a_ref[h]                               # scalar (negative)

    la = dt[0] * A                             # (Q,)
    cum = jnp.cumsum(la)                       # (Q,)
    # decay L[i,j] = exp(cum_i - cum_j) for j<=i else 0
    ci = cum[:, None]
    cj = cum[None, :]
    Q = x.shape[0]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    L = jnp.where(tri, jnp.exp(jnp.clip(ci - cj, -60.0, 0.0)), 0.0)
    sc = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    att = sc * L * dt[0][None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # input state: (N, P) = (B ⊙ dt·decay_to_end)ᵀ @ x
    dte = jnp.exp(jnp.clip(cum[-1] - cum, -60.0, 0.0)) * dt[0]   # (Q,)
    bw = bm * dte[:, None]                                       # (Q, N)
    s = jax.lax.dot_general(bw, x, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (N, P)
    s_ref[0, 0] = s.astype(s_ref.dtype)


def ssd_chunk_kernel(xh, dt, A, bmat, cmat, *, interpret: bool = True):
    """Intra-chunk SSD.

    xh:   (BC, H, Q, P)  — BC = batch·num_chunks
    dt:   (BC, H, 1, Q)
    A:    (H,) negative decay rates (scalar-prefetch)
    bmat: (BC, Q, N), cmat: (BC, Q, N)
    Returns (y (BC, H, Q, P), s_in (BC, H, N, P)).
    """
    BC, H, Q, P = xh.shape
    N = bmat.shape[-1]
    grid = (BC, H)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda bc, h, a: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda bc, h, a: (bc, h, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda bc, h, a: (bc, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda bc, h, a: (bc, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda bc, h, a: (bc, h, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bc, h, a: (bc, h, 0, 0)),
        ],
    )
    return pl.pallas_call(
        _ssd_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((BC, H, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, N, P), jnp.float32),
        ],
        interpret=interpret,
    )(A, xh, dt, bmat, cmat)
