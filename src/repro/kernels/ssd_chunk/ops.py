"""Jitted public wrapper for the SSD chunk kernel, plus `ssd_chunked` —
the full chunked-SSD composition (intra-chunk term + cross-chunk XLA
recurrence) that `repro.models.layers.mamba_block` calls.

The intra-chunk quadratic term and per-chunk input states come from one
of three implementations selected by ``mode``:

- ``"off"``/``"ref"`` — `ssd_chunk_ref`, the pure-jnp oracle.  This IS
  the jnp layer path: the former duplicate ``layers._ssd_chunked`` was
  deleted and routes here (identical math, single source of truth).
- ``"pallas"`` — the Pallas kernel, wrapped in a `custom_vjp` whose
  backward is the oracle's VJP (`pallas_call` has no autodiff rule).

The cross-chunk recurrence (a tiny `lax.scan` over nc states) stays in
XLA in all modes — it is sequential and state-sized, exactly what the
kernel fusion should NOT swallow.
"""
import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_kernel
from .ref import ssd_chunk_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(xh, dt, A, bmat, cmat, *, interpret=True):
    return ssd_chunk_kernel(xh, dt, A, bmat, cmat, interpret=interpret)


@jax.custom_vjp
def _ssd_chunk_pallas(xh, dt, A, bmat, cmat):
    return ssd_chunk(xh, dt, A, bmat, cmat)


def _ssd_chunk_fwd(xh, dt, A, bmat, cmat):
    return _ssd_chunk_pallas(xh, dt, A, bmat, cmat), (xh, dt, A, bmat, cmat)


def _ssd_chunk_bwd(res, dys):
    _, vjp = jax.vjp(ssd_chunk_ref, *res)
    return vjp(dys)


_ssd_chunk_pallas.defvjp(_ssd_chunk_fwd, _ssd_chunk_bwd)


def ssd_chunked(xh, dt, A, bmat, cmat, D, chunk, init_state=None,
                mode: str = "off"):
    """Chunked SSD (Mamba-2 state-space duality).

    xh:   (B, S, H, P)    inputs per head (H is tp-local in islands)
    dt:   (B, S, H)       softplus'd step sizes
    A:    (H,)            negative decay rates
    bmat: (B, S, N), cmat: (B, S, N)   shared across heads (single group)
    Returns (y (B, S, H, P), final_state (B, H, N, P)).
    """
    B, S, H, P = xh.shape
    N = bmat.shape[-1]
    chunk = min(chunk, S)
    while S % chunk:            # largest divisor ≤ requested chunk
        chunk -= 1
    Q = chunk
    nc = S // Q

    # fold to the kernel layout: (batch·chunk, head, Q, ·)
    xc = (xh.reshape(B, nc, Q, H, P).transpose(0, 1, 3, 2, 4)
          .reshape(B * nc, H, Q, P))
    dtc = (dt.reshape(B, nc, Q, H).transpose(0, 1, 3, 2)
           .reshape(B * nc, H, 1, Q))
    bc = bmat.reshape(B * nc, Q, N)
    cc = cmat.reshape(B * nc, Q, N)
    if mode == "pallas":
        y_diag, s_in = _ssd_chunk_pallas(xc, dtc, A, bc, cc)
    else:
        y_diag, s_in = ssd_chunk_ref(xc, dtc, A, bc, cc)
    y_diag = y_diag.reshape(B, nc, H, Q, P)
    s_in = s_in.reshape(B, nc, H, N, P)

    # cross-chunk recurrence over per-chunk input states (XLA side)
    la = dt * A[None, None, :]                       # log decay ≤ 0
    cum = la.reshape(B, nc, Q, H).cumsum(axis=2)     # (B, nc, Q, H)
    seg_end = cum[:, :, -1, :]                       # (B, nc, H)
    s0 = (init_state if init_state is not None
          else jnp.zeros((B, H, N, P), s_in.dtype))

    def scan_fn(s_prev, inp):
        s_c, g_end = inp                             # (B,H,N,P), (B,H)
        s_new = s_prev * jnp.exp(jnp.clip(g_end, -60.0, 0.0)
                                 )[:, :, None, None] + s_c
        return s_new, s_prev

    final_state, s_prevs = jax.lax.scan(
        scan_fn, s0,
        (s_in.transpose(1, 0, 2, 3, 4), seg_end.transpose(1, 0, 2)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)       # (B, nc, H, N, P)

    # inter-chunk contribution
    ccg = cmat.reshape(B, nc, Q, N)
    y_off = jnp.einsum("bcqn,bchnp->bchqp", ccg, s_prevs) * jnp.exp(
        jnp.clip(cum, -60.0, 0.0)).transpose(0, 1, 3, 2)[..., None]
    y = (y_diag + y_off).transpose(0, 1, 3, 2, 4).reshape(B, S, H, P)
    y = y + xh * D[None, None, :, None]
    return y, final_state
