"""Jitted public wrapper for the SSD chunk kernel."""
import functools

import jax

from .kernel import ssd_chunk_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(xh, dt, A, bmat, cmat, *, interpret=True):
    return ssd_chunk_kernel(xh, dt, A, bmat, cmat, interpret=interpret)
