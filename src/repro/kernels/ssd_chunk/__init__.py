from .ops import ssd_chunk, ssd_chunked
from .ref import ssd_chunk_ref

__all__ = ["ssd_chunk", "ssd_chunk_ref", "ssd_chunked"]
