"""Pure-jnp oracle for the SSD intra-chunk kernel."""
import jax.numpy as jnp


def ssd_chunk_ref(xh, dt, A, bmat, cmat):
    """Same contract as ssd_chunk_kernel (see kernel.py)."""
    BC, H, Q, P = xh.shape
    x = xh.astype(jnp.float32)
    d = dt.astype(jnp.float32)[:, :, 0]              # (BC, H, Q)
    la = d * A[None, :, None]                        # (BC, H, Q)
    cum = jnp.cumsum(la, axis=-1)
    ci, cj = cum[..., :, None], cum[..., None, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None], jnp.exp(jnp.clip(ci - cj, -60.0, 0.0)),
                  0.0)                               # (BC, H, Q, Q)
    sc = jnp.einsum("bin,bjn->bij", cmat.astype(jnp.float32),
                    bmat.astype(jnp.float32))        # (BC, Q, Q)
    att = sc[:, None] * L * d[..., None, :]          # (BC, H, Q, Q)
    y = jnp.einsum("bhij,bhjp->bhip", att, x)
    dte = jnp.exp(jnp.clip(cum[..., -1:] - cum, -60.0, 0.0)) * d
    s = jnp.einsum("bhq,bqn,bhqp->bhnp", dte, bmat.astype(jnp.float32), x)
    return y, s
