"""The LM block as an MKPipe stage graph.

This closes the loop between the paper's compiler and the LM framework:
one transformer block is expressed as the 4-stage graph

    ln1 → attention → ln2 → ffn

with per-stage tile maps over the token dim, so the MKPipe pass classifies
the dependencies (all one-to-one in the token dimension), picks fusion /
channel CKE per stage pair, and the executor can lower the fused pairs to
the registered Pallas kernels (`kernels/fused_rmsnorm`, flash attention,
`kernels/fused_mlp`).  What XLA does implicitly ("fuse adjacent
elementwise into the matmul"), MKPipe does *explicitly* and reports: which
pairs fused, what HBM round-trips that removed, and what the balanced
factors are — the same report the paper produces for its OpenCL kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.graph import AffineTileMap, Stage, StageGraph
from repro.models import layers as L
from repro.models.common import LayerKind, LayerSpec, ModelConfig

Array = Any


def block_stage_graph(cfg: ModelConfig, params: dict,
                      spec: LayerSpec | None = None,
                      tile: int = 256) -> StageGraph:
    """Stage graph of one decoder block (ln1 → mixer → ln2 → ffn).

    params: one un-stacked block param tree (e.g.
    `jax.tree.map(lambda x: x[0], init_params(cfg, key)["layers"][0])`).
    """
    spec = spec or cfg.pattern[0]
    d = cfg.d_model

    def ln1(env):
        return {"h1": L.norm(env["x"], params["ln1"], cfg.norm)}

    def attn(env):
        window = cfg.window if spec.kind == LayerKind.SWA else 0
        mix = L.attention_block(params["mixer"], env["h1"], cfg,
                                causal=True, window=window)
        return {"x_mid": env["x"] + mix}

    def mamba(env):
        mix, _ = L.mamba_block(params["mixer"], env["h1"], cfg)
        return {"x_mid": env["x"] + mix}

    def ln2(env):
        return {"h2": L.norm(env["x_mid"], params["ln2"], cfg.norm)}

    def ffn(env):
        if spec.moe:
            y, _aux = L.moe_block(params["ffn"], env["h2"], cfg)
        else:
            y = L.mlp_block(params["ffn"], env["h2"], cfg)
        return {"x_out": env["x_mid"] + y}

    def fused_ln2_ffn(env):
        h2 = L.norm(env["x_mid"], params["ln2"], cfg.norm)
        if spec.moe:
            y, _aux = L.moe_block(params["ffn"], h2, cfg)
        else:
            y = L.mlp_block(params["ffn"], h2, cfg)
        return {"x_out": env["x_mid"] + y, "h2": h2}

    def fused_ln1_mixer(env):
        h1 = L.norm(env["x"], params["ln1"], cfg.norm)
        if spec.kind == LayerKind.MAMBA:
            mix, _ = L.mamba_block(params["mixer"], h1, cfg)
        else:
            window = cfg.window if spec.kind == LayerKind.SWA else 0
            mix = L.attention_block(params["mixer"], h1, cfg,
                                    causal=True, window=window)
        return {"x_mid": env["x"] + mix, "h1": h1}

    # token-dim tile maps: every stage is one-to-one over token tiles
    # (attention reads all tokens causally → its *input* h1 map is
    # broadcast-lower-triangular; conservatively modeled as broadcast,
    # which classifies attn as the pipeline's sync-free consumer since
    # ln1's output feeds it tile-for-tile plus history)
    def token_map(_grid: int) -> AffineTileMap:
        return AffineTileMap(coeff=((tile,), (0,)), const=(0, 0),
                             block=(tile, d))

    grid = None

    def build(seq_len: int) -> StageGraph:
        n_tiles = seq_len // tile
        tm = token_map(n_tiles)
        mixer_fn = mamba if spec.kind == LayerKind.MAMBA else attn
        mixer_out = "x_mid" if spec.ffn else "x_out"

        def mixer_named(env):
            return {mixer_out: mixer_fn(env)["x_mid"]}

        def fused_named(env):
            out = fused_ln1_mixer(env)
            return {mixer_out: out["x_mid"], "h1": out["h1"]}

        stages = [
            Stage("ln1", ln1, reads=("x",), writes=("h1",),
                  grid=(n_tiles,), tile_maps={"x": tm, "h1": tm}),
            Stage("mixer", mixer_named, reads=("x", "h1"),
                  writes=(mixer_out,),
                  grid=(n_tiles,),
                  tile_maps={"x": tm, "h1": tm, mixer_out: tm},
                  impls={"fuse": fused_named, "channel": fused_named}),
        ]
        if spec.ffn:
            stages += [
                Stage("ln2", ln2, reads=("x_mid",), writes=("h2",),
                      grid=(n_tiles,), tile_maps={"x_mid": tm, "h2": tm}),
                Stage("ffn", ffn, reads=("x_mid", "h2"), writes=("x_out",),
                      grid=(n_tiles,),
                      tile_maps={"x_mid": tm, "h2": tm, "x_out": tm},
                      impls={"fuse": fused_ln2_ffn,
                             "channel": fused_ln2_ffn}),
            ]
        return StageGraph(stages=stages, inputs=("x",), outputs=("x_out",))

    return build


def hbm_round_trips_eliminated(cfg: ModelConfig, batch: int, seq: int,
                               plan) -> dict[str, float]:
    """Bytes of intermediate traffic each fused pair removes (the paper's
    'fusion eliminates global-memory accesses' number for this block)."""
    d = cfg.d_model
    bytes_h = batch * seq * d * jnp.dtype(cfg.dtype).itemsize * 2  # w+r
    out = {}
    for e in plan.edges:
        if e.mechanism in ("fuse", "channel"):
            out[f"{e.producer}->{e.consumer}"] = float(bytes_h)
    return out
