"""Model substrate: unified LM covering the ten assigned architectures."""
from .common import LayerKind, LayerSpec, ModelConfig, ShapeSpec, tp_align
from .transformer import (Model, init_params, abstract_params, forward,
                          loss_fn, init_cache, decode_step)

__all__ = [
    "LayerKind", "LayerSpec", "ModelConfig", "ShapeSpec", "tp_align",
    "Model", "init_params", "abstract_params", "forward", "loss_fn",
    "init_cache", "decode_step",
]
