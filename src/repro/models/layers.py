"""Layer implementations (pure JAX / XLA path).

Attention (train/prefill) is a *triangle scan*: a `lax.scan` over the static
list of visible (q-chunk, kv-chunk) pairs with an online-softmax state.
Compared to a masked dense implementation this (a) has exact
lower-triangular / sliding-window FLOPs — the compiled HLO matches the
model FLOPs, which keeps the roofline honest — and (b) executes chunk pairs
in exactly the dependency-resolution order an MKPipe id_queue would emit
(the Pallas flash kernel applies the same order as a grid remap).

MoE is GShard-style capacity dispatch via scatter-add (dropping, capacity
factor from the config), with a dense all-experts fallback used as the
correctness oracle.  Mamba-2 is the chunked SSD algorithm with a
cross-chunk state scan.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .common import LayerKind, ModelConfig
from repro.dist.context import (MODEL_AXIS, constrain, flag, kernel_mode,
                                manual_tp_size, moe_groups)

Array = Any


def _dispatch():
    """The kernel dispatch module, imported lazily: `repro.kernels` pulls
    this module in at import time (the refs delegate here), so the reverse
    edge must resolve at call time — which is trace time, where the
    `kernel_mode()` flag decides whether it is taken at all."""
    from repro.kernels import dispatch
    return dispatch


def _row_parallel_einsum(expr: str, a: Array, w: Array, out_dtype) -> Array:
    """Row-parallel (psum-producing) projection.  Under the `ar_bf16`
    hillclimb flag the partial products are emitted in bf16, so the
    all-reduce moves half the bytes (accuracy note: the cross-shard
    reduction then accumulates in bf16).

    Under GSPMD the all-reduce over ``model`` is compiler-inserted; inside
    a manual region with the model axis bound (a pipeline island, where
    params arrive model-sharded) the partial products are reduced with an
    explicit `psum` — the block math carries its own tp collective."""
    if flag("ar_bf16"):
        part = jnp.einsum(expr, a, w, preferred_element_type=jnp.bfloat16)
    else:
        part = jnp.einsum(expr, a, w)
    if manual_tp_size() > 1:
        part = jax.lax.psum(part, MODEL_AXIS)
    return part.astype(out_dtype)


def _tp_rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    """`rmsnorm` over a dim that may be model-sharded in a manual region:
    the mean-square is reduced over ``model`` so each shard normalizes by
    the *global* variance (GSPMD does this insertion itself outside)."""
    tp = manual_tp_size()
    if tp == 1:
        return rmsnorm(x, scale, eps)
    x32 = x.astype(jnp.float32)
    var = jax.lax.psum(jnp.sum(x32 * x32, axis=-1, keepdims=True),
                       MODEL_AXIS) / (x.shape[-1] * tp)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ----------------------------------------------------------------- basics
def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def layernorm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def norm(x: Array, scale: Array, kind: str) -> Array:
    if kind != "rmsnorm":
        return layernorm(x, scale)
    mode = kernel_mode()
    if mode != "off":
        # d_model is never sharded inside the manual islands (activations
        # are replicated over "model"), so the local-variance kernel is
        # exact here; `_tp_rmsnorm` keeps the sharded-dim cases
        return _dispatch().rmsnorm(x, scale, mode=mode)
    return rmsnorm(x, scale)


def activation(x: Array, act: str) -> Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "relu2":                     # nemotron: squared ReLU
        r = jax.nn.relu(x)
        return r * r
    if act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(act)


def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ------------------------------------------------------ attention (chunked)
def visible_pairs(nq: int, nk: int, *, causal: bool, window: int,
                  q_chunk: int, kv_chunk: int, kv_offset: int = 0
                  ) -> list[tuple[int, int]]:
    """Static chunk-pair schedule — the id_queue of the attention stage."""
    pairs = []
    for i in range(nq):
        q_lo, q_hi = i * q_chunk + kv_offset, (i + 1) * q_chunk - 1 + kv_offset
        for j in range(nk):
            k_lo, k_hi = j * kv_chunk, (j + 1) * kv_chunk - 1
            if causal and k_lo > q_hi:
                continue                       # fully above the diagonal
            if window and k_hi < q_lo - window + 1:
                continue                       # fully outside the window
            pairs.append((i, j))
    return pairs


def _pair_mask(i, j, q_chunk, kv_chunk, causal, window, kv_offset):
    qpos = i * q_chunk + jnp.arange(q_chunk) + kv_offset
    kpos = j * kv_chunk + jnp.arange(kv_chunk)
    mask = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    return mask


def _flash_fwd_scan(q, k, v, causal, window, q_chunk, kv_chunk, kv_offset):
    """Online-softmax forward over visible chunk pairs.
    Returns (out f32 (B,Sq,Hq,D), lse f32 (B,Sq,Hkv,g))."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / math.sqrt(D)

    pairs = visible_pairs(nq, nk, causal=causal, window=window,
                          q_chunk=q_chunk, kv_chunk=kv_chunk,
                          kv_offset=kv_offset)
    qs = q.reshape(B, nq, q_chunk, Hkv, g, D).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, kv_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    acc = jnp.zeros((nq, B, q_chunk, Hkv, g, D), jnp.float32)
    m = jnp.full((nq, B, q_chunk, Hkv, g), -jnp.inf, jnp.float32)
    l = jnp.zeros((nq, B, q_chunk, Hkv, g), jnp.float32)
    pair_arr = jnp.asarray(pairs, jnp.int32)

    def step(state, ij):
        acc, m, l = state
        i, j = ij[0], ij[1]
        qi = jax.lax.dynamic_index_in_dim(qs, i, 0, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(ks, j, 0, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vs, j, 0, keepdims=False)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        mask = _pair_mask(i, j, q_chunk, kv_chunk, causal, window, kv_offset)
        s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
        mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(mask[:, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isfinite(mi), jnp.exp(mi - safe_m), 0.0)
        l_new = li * corr + p.sum(axis=-1)
        a_new = ai * corr[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p, vj.astype(jnp.float32))
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(step, (acc, m, l), pair_arr)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, D)
    lse = jnp.where(l > 0, jnp.where(jnp.isfinite(m), m, 0.0) + jnp.log(
        jnp.maximum(l, 1e-30)), jnp.inf)
    lse = lse.transpose(1, 0, 2, 3, 4).reshape(B, Sq, Hkv, g)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_chunk, kv_chunk, kv_offset):
    out, _ = _flash_fwd_scan(q, k, v, causal, window, q_chunk, kv_chunk,
                             kv_offset)
    return out.astype(q.dtype)


def _flash_vjp_fwd(q, k, v, causal, window, q_chunk, kv_chunk, kv_offset):
    out, lse = _flash_fwd_scan(q, k, v, causal, window, q_chunk, kv_chunk,
                               kv_offset)
    # residuals: q, k, v, out, lse — NO per-pair probabilities (the flash
    # backward recomputes them chunk-by-chunk; this is what keeps the
    # training memory footprint linear in sequence length)
    return out.astype(q.dtype), (q, k, v, out.astype(q.dtype), lse)


def _flash_vjp_bwd(causal, window, q_chunk, kv_chunk, kv_offset,
                   res, dout):
    q, k, v, out, lse = res
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / math.sqrt(D)

    pairs = visible_pairs(nq, nk, causal=causal, window=window,
                          q_chunk=q_chunk, kv_chunk=kv_chunk,
                          kv_offset=kv_offset)
    pair_arr = jnp.asarray(pairs, jnp.int32)

    qs = q.reshape(B, nq, q_chunk, Hkv, g, D).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, kv_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kv_chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    dos = dout.reshape(B, nq, q_chunk, Hkv, g, D).transpose(1, 0, 2, 3, 4, 5)
    lses = lse.reshape(B, nq, q_chunk, Hkv, g).transpose(1, 0, 2, 3, 4)
    # D_i = rowsum(dout ⊙ out)
    delta = jnp.einsum("bshgd,bshgd->bshg",
                       dout.reshape(B, Sq, Hkv, g, D).astype(jnp.float32),
                       out.reshape(B, Sq, Hkv, g, D).astype(jnp.float32))
    deltas = delta.reshape(B, nq, q_chunk, Hkv, g).transpose(1, 0, 2, 3, 4)

    dq = jnp.zeros((nq, B, q_chunk, Hkv, g, D), jnp.float32)
    dk = jnp.zeros((nk, B, kv_chunk, Hkv, D), jnp.float32)
    dv = jnp.zeros((nk, B, kv_chunk, Hkv, D), jnp.float32)

    def step(state, ij):
        dq, dk, dv = state
        i, j = ij[0], ij[1]
        qi = jax.lax.dynamic_index_in_dim(qs, i, 0, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(ks, j, 0, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vs, j, 0, keepdims=False)
        doi = jax.lax.dynamic_index_in_dim(dos, i, 0, keepdims=False
                                           ).astype(jnp.float32)
        lsei = jax.lax.dynamic_index_in_dim(lses, i, 0, keepdims=False)
        di = jax.lax.dynamic_index_in_dim(deltas, i, 0, keepdims=False)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        mask = _pair_mask(i, j, q_chunk, kv_chunk, causal, window, kv_offset)
        safe_lse = jnp.where(jnp.isfinite(lsei), lsei, 0.0)
        p = jnp.exp(s - safe_lse[..., None])
        p = jnp.where(mask[:, None, None, :] & jnp.isfinite(
            lsei)[..., None], p, 0.0)
        dvj = jnp.einsum("bqhgk,bqhgd->bkhd", p, doi)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", doi, vj.astype(jnp.float32))
        ds = p * (dp - di[..., None]) * scale
        dqi = jnp.einsum("bqhgk,bkhd->bqhgd", ds, kj.astype(jnp.float32))
        dkj = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qi.astype(jnp.float32))
        dq = dq.at[i].add(dqi)
        dk = dk.at[j].add(dkj)
        dv = dv.at[j].add(dvj)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(step, (dq, dk, dv), pair_arr)
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hq, D).astype(q.dtype)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, D).astype(k.dtype)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Skv, Hkv, D).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: int = 0, q_chunk: int = 512,
                      kv_chunk: int = 512, kv_offset: int = 0,
                      use_custom_vjp: bool = True) -> Array:
    """Flash attention over visible chunk pairs (XLA path).

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    kv_offset: absolute position of q[0] relative to k[0] (cache decoding).
    use_custom_vjp=False falls back to autodiff-through-scan (stores
    per-pair probabilities — the memory-hungry baseline; kept for A/B).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, _, _ = k.shape
    mode = kernel_mode()
    if mode != "off":
        # kernel path: head counts are already tp-local here (the qkv
        # projections were model-sharded upstream), and the wo projection
        # after this carries the tp psum — the kernel stays collective-free
        return _dispatch().flash_mha(q, k, v, causal=causal, window=window,
                                     kv_offset=kv_offset, mode=mode)
    # largest divisors ≤ requested chunk (handles Skv=1500 cross-attn etc.)
    q_chunk = min(q_chunk, Sq)
    while Sq % q_chunk:
        q_chunk -= 1
    kv_chunk = min(kv_chunk, Skv)
    while Skv % kv_chunk:
        kv_chunk -= 1
    if use_custom_vjp:
        return _flash(q, k, v, causal, window, q_chunk, kv_chunk, kv_offset)
    out, _ = _flash_fwd_scan(q, k, v, causal, window, q_chunk, kv_chunk,
                             kv_offset)
    return out.astype(q.dtype)


def attention_ref(q: Array, k: Array, v: Array, *, causal: bool,
                  window: int = 0, kv_offset: int = 0) -> Array:
    """Dense masked attention — small-shape oracle for the chunked path."""
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    g = Hq // Hkv
    qs = q.reshape(B, Sq, Hkv, g, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qs, k.astype(jnp.float32))
    s /= math.sqrt(D)
    qpos = jnp.arange(Sq) + kv_offset
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     n_valid: Array) -> Array:
    """Single-token attention against a cache.

    q: (B, 1, Hq, D); caches: (B, S, Hkv, D); n_valid: scalar count of
    valid cache slots (ring buffers pass the full size once warm).

    Under the `decode_bf16_scores` flag the cache is consumed in its
    native dtype with f32 MXU accumulation (no materialized f32 copy of
    the full KV cache — the dominant HBM traffic of large-batch decode).
    """
    B, _, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    g = Hq // Hkv
    if flag("decode_bf16_scores"):
        # preferred bf16 keeps the cache-consuming dot natively 16-bit (the
        # MXU still accumulates f32 internally); asking for f32 here makes
        # XLA maintain a hoisted f32 twin of the whole cache
        qs = q.reshape(B, Hkv, g, D).astype(k_cache.dtype)
        s = jnp.einsum("bhgd,bkhd->bhgk", qs, k_cache,
                       preferred_element_type=k_cache.dtype
                       ).astype(jnp.float32)
    else:
        qs = q.reshape(B, Hkv, g, D).astype(jnp.float32)
        s = jnp.einsum("bhgd,bkhd->bhgk", qs, k_cache.astype(jnp.float32))
    s /= math.sqrt(D)
    mask = jnp.arange(S) < n_valid
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    if flag("decode_bf16_scores"):
        out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype),
                         v_cache, preferred_element_type=v_cache.dtype)
    else:
        out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# --------------------------------------------------------------- attn block
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.q_heads, cfg.kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = 0.02
    dt = jnp.dtype(cfg.dtype)
    p = {
        "wq": (jax.random.normal(k1, (d, hq, hd)) * std).astype(dt),
        "wk": (jax.random.normal(k2, (d, hkv, hd)) * std).astype(dt),
        "wv": (jax.random.normal(k3, (d, hkv, hd)) * std).astype(dt),
        "wo": (jax.random.normal(k4, (hq, hd, d)) * std).astype(dt),
    }
    if cfg.padded_heads and cfg.padded_heads > cfg.num_heads:
        # zero o-proj rows for padded heads → they contribute nothing
        mask = (jnp.arange(cfg.q_heads) < cfg.num_heads)[:, None, None]
        p["wo"] = p["wo"] * mask.astype(dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def attention_block(p: dict, x: Array, cfg: ModelConfig, *, causal: bool,
                    window: int = 0, positions: Array | None = None,
                    kv: Array | None = None, use_rope: bool = True) -> Array:
    """Full attention block (projections + chunked attention).

    kv: source sequence for cross-attention (encoder states); defaults to x.
    """
    B, S, _ = x.shape
    src = x if kv is None else kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if use_rope:
        pos_q = positions if positions is not None else jnp.arange(S)
        q = rope(q, pos_q, cfg.rope_theta)
        k = rope(k, jnp.arange(src.shape[1]), cfg.rope_theta)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            use_custom_vjp=not flag("no_flash_vjp"))
    return _row_parallel_einsum("bshk,hkd->bsd", out, p["wo"], x.dtype)


# ----------------------------------------------------------------- dense FFN
def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    std = 0.02
    dt = jnp.dtype(cfg.dtype)
    p = {"w_up": (jax.random.normal(k1, (d, ff)) * std).astype(dt),
         "w_down": (jax.random.normal(k2, (ff, d)) * std).astype(dt)}
    if cfg.act == "silu":                     # gated (SwiGLU)
        p["w_gate"] = (jax.random.normal(k3, (d, ff)) * std).astype(dt)
    return p


def mlp_block(p: dict, x: Array, cfg: ModelConfig) -> Array:
    mode = kernel_mode()
    if mode != "off":
        # the fused kernel runs up/act/down on the tp-local d_ff slice;
        # its output is the per-shard partial sum, so the row-parallel
        # psum stays out here (mirroring `_row_parallel_einsum`)
        part = _dispatch().mlp(x, p["w_up"], p["w_down"], p.get("w_gate"),
                               act=cfg.act, mode=mode)
        if manual_tp_size() > 1:
            part = jax.lax.psum(part, MODEL_AXIS)
        return part.astype(x.dtype)
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = activation(x @ p["w_gate"], cfg.act) * h
    else:
        h = activation(h, cfg.act)
    return _row_parallel_einsum("tf,fd->td" if h.ndim == 2 else
                                "btf,fd->btd", h, p["w_down"], x.dtype)


# ---------------------------------------------------------------------- MoE
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dispatch_gather(xinfo, xg, inv, valid):
    """buf[g,e,c,:] = xg[g, inv[g,e,c], :] · valid — MoE dispatch.
    xinfo: static (Tg, dtype-name) so the backward needn't save xg."""
    G, E, C = inv.shape
    gidx3 = jnp.broadcast_to(jnp.arange(G)[:, None, None], (G, E, C))
    return xg[gidx3, inv] * valid[..., None].astype(xg.dtype)


def _dispatch_gather_fwd(xinfo, xg, inv, valid):
    return _dispatch_gather(xinfo, xg, inv, valid), (inv, valid)


def _dispatch_gather_bwd(xinfo, res, d_buf):
    Tg, xdtype = xinfo
    (inv, valid) = res
    G, E, C = inv.shape
    d = d_buf.shape[-1]
    gidx3 = jnp.broadcast_to(jnp.arange(G)[:, None, None], (G, E, C))
    upd = d_buf * valid[..., None].astype(d_buf.dtype)
    acc_dtype = jnp.dtype(xdtype) if flag("ar_bf16") else d_buf.dtype
    d_xg = jnp.zeros((G, Tg, d), acc_dtype).at[gidx3, inv].add(
        upd.astype(acc_dtype))
    # token grads sum over the k experts a token visited (possibly on
    # different model shards) → one TP all-reduce of activation size; the
    # constraint stops GSPMD from inventing a full (G,E,C,d) reduction
    d_xg = constrain(d_xg, "dp", None, None).astype(jnp.dtype(xdtype))
    return d_xg, None, None


_dispatch_gather.defvjp(_dispatch_gather_fwd, _dispatch_gather_bwd)


def _slot_gathers(yb, idg, pos_t, keep_t, wg, dtype):
    """Σ_slot w·yb[g, id_slot, pos_slot] with yb tp-replicated so every
    slot gather is shard-local (one AG of yb instead of k partial-ARs)."""
    G, E, C, d = yb.shape
    Tg, k = idg.shape[1], idg.shape[2]
    yb = constrain(yb, "dp", None, None, None)
    gidx_t = jnp.broadcast_to(jnp.arange(G)[:, None], (G, Tg))
    y = jnp.zeros((G, Tg, d), dtype)
    for slot in range(k):
        vals = yb[gidx_t, idg[:, :, slot],
                  jnp.minimum(pos_t[:, :, slot], C - 1)]
        scale = (wg[:, :, slot] * keep_t[:, :, slot]).astype(dtype)
        y = y + vals.astype(dtype) * scale[..., None]
    return y


@jax.custom_vjp
def _combine_gather(yb, inv, valid, w_buf, idg, pos_t, keep_t, wg):
    """y[g,t,:] = Σ_slot w[g,t,slot] · yb[g, id[g,t,slot], pos[g,t,slot], :]

    inv/valid/w_buf are the slot→token inverse map and per-slot weights in
    (G,E,C) layout: the backward uses them to express d_yb as a *gather*
    from dy (shard-local under dp), avoiding scatter partial-sum
    all-reduces across the model axis entirely.
    """
    return _slot_gathers(yb, idg, pos_t, keep_t, wg, yb.dtype)


def _combine_gather_fwd(yb, inv, valid, w_buf, idg, pos_t, keep_t, wg):
    y = _combine_gather(yb, inv, valid, w_buf, idg, pos_t, keep_t, wg)
    return y, (yb, inv, valid, w_buf, idg, pos_t, keep_t, wg)


def _combine_gather_bwd(res, dy):
    yb, inv, valid, w_buf, idg, pos_t, keep_t, wg = res
    G, E, C, d = yb.shape
    gidx3 = jnp.broadcast_to(jnp.arange(G)[:, None, None], (G, E, C))
    dy_rep = constrain(dy, "dp", None, None)
    # d_yb[g,e,c] = dy[g, inv[g,e,c]] · w_buf[g,e,c] — pure gather
    d_yb = (dy_rep[gidx3, inv]
            * (w_buf * valid.astype(w_buf.dtype))[..., None].astype(dy.dtype))
    d_yb = constrain(d_yb, "dp", "tp", None, None).astype(yb.dtype)
    # d_w[g,t,slot] = <dy[g,t], yb[g, id_slot, pos_slot]>
    Tg, k = idg.shape[1], idg.shape[2]
    yb_rep = constrain(yb, "dp", None, None, None)
    gidx_t = jnp.broadcast_to(jnp.arange(G)[:, None], (G, Tg))
    d_wg_slots = []
    dy32 = dy.astype(jnp.float32)
    for slot in range(k):
        vals = yb_rep[gidx_t, idg[:, :, slot],
                      jnp.minimum(pos_t[:, :, slot], C - 1)]
        d_w = jnp.einsum("gtd,gtd->gt", dy32, vals.astype(jnp.float32))
        d_wg_slots.append(d_w * keep_t[:, :, slot])
    d_wg = jnp.stack(d_wg_slots, axis=-1).astype(wg.dtype)
    return d_yb, None, None, None, None, None, None, d_wg


_combine_gather.defvjp(_combine_gather_fwd, _combine_gather_bwd)


def init_moe(key, cfg: ModelConfig) -> dict:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    std = 0.02
    dt = jnp.dtype(cfg.dtype)
    p = {
        "router": (jax.random.normal(k1, (d, e)) * std).astype(jnp.float32),
        "we_up": (jax.random.normal(k2, (e, d, ff)) * std).astype(dt),
        "we_gate": (jax.random.normal(k3, (e, d, ff)) * std).astype(dt),
        "we_down": (jax.random.normal(k4, (e, ff, d)) * std).astype(dt),
    }
    if cfg.moe_shared_expert:
        p["shared"] = init_mlp(k5, cfg, d_ff=cfg.moe_d_ff)
    return p


def _router(p: dict, xf: Array, cfg: ModelConfig):
    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.experts_per_tok)       # (T, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(0)
    ce = jnp.zeros_like(me).at[ids.reshape(-1)].add(
        jnp.ones((ids.size,), jnp.float32)) / ids.size
    aux = cfg.num_experts * jnp.sum(me * ce)
    return w, ids, aux


def moe_block(p: dict, x: Array, cfg: ModelConfig,
              impl: str = "scatter", n_groups: int = 16
              ) -> tuple[Array, Array]:
    """Returns (output, aux_loss).

    The scatter path uses GShard-style *grouped* dispatch: tokens are split
    into `n_groups` groups aligned with the data-parallel shards, so the
    dispatch scatter and the expert matmuls carry a leading batch dim that
    GSPMD shards over "data" while experts shard over "model" — without
    grouping, the capacity dim replicates and every data shard redundantly
    computes all expert tokens (a 16× compute bug the dry-run exposed).
    """
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    w, ids, aux = _router(p, xf, cfg)
    k = cfg.experts_per_tok
    E = cfg.num_experts

    if impl == "dense" and manual_tp_size() > 1:
        raise ValueError("moe_block(impl='dense') is the all-experts "
                         "oracle; inside a manual-tp island experts are "
                         "sharded — use the scatter path")
    if impl == "dense":
        # all-experts oracle: every expert computes every token
        h = jnp.einsum("td,edf->tef", xf, p["we_up"])
        g = jnp.einsum("td,edf->tef", xf, p["we_gate"])
        y_all = jnp.einsum("tef,efd->ted", activation(g, "silu") * h,
                           p["we_down"])                       # (T, E, d)
        sel = jnp.zeros((T, E), xf.dtype).at[
            jnp.arange(T)[:, None], ids].add(w.astype(xf.dtype))
        y = jnp.einsum("ted,te->td", y_all, sel)
    else:
        G = math.gcd(T, moe_groups(n_groups))
        Tg = T // G
        TK = Tg * k
        C = max(int(cfg.capacity_factor * k * Tg / E), 1)
        xg = constrain(xf.reshape(G, Tg, d), "dp", None, None)
        idg = ids.reshape(G, Tg, k)
        wg = w.reshape(G, Tg, k)
        ids_f = constrain(idg.reshape(G, TK), "dp", None)      # (G, Tg*k)
        gidx = jnp.broadcast_to(jnp.arange(G)[:, None], (G, TK))
        # position-within-expert via stable sort (the one-hot cumsum
        # alternative materializes a (G, Tg·k, E) scan — 17 GB at qwen3
        # scale; rank-minus-start is O(Tg·k) and parallel)
        order = jnp.argsort(ids_f, axis=1, stable=True)        # (G, TK)
        ranks = jnp.zeros((G, TK), jnp.int32).at[gidx, order].set(
            jnp.broadcast_to(jnp.arange(TK, dtype=jnp.int32), (G, TK)))
        counts = jnp.zeros((G, E), jnp.int32).at[gidx, ids_f].add(1)
        starts = jnp.cumsum(counts, axis=1) - counts           # (G, E) excl.
        pos = ranks - jnp.take_along_axis(starts, ids_f, axis=1)
        keep = pos < C                                         # (G, Tg*k)
        # dropped slots scatter out-of-bounds → mode="drop" discards them;
        # the dispatch itself is an int32 inverse map (slot → token), so the
        # (G,TK,d) "k copies of every token" tensor never materializes.
        pos_s = jnp.where(keep, pos, C)
        tok_of_slot = jnp.broadcast_to(
            jnp.arange(Tg, dtype=jnp.int32)[None, :, None],
            (G, Tg, k)).reshape(G, TK)
        inv = jnp.zeros((G, E, C), jnp.int32).at[
            gidx, ids_f, pos_s].set(tok_of_slot, mode="drop")
        valid = jnp.zeros((G, E, C), bool).at[
            gidx, ids_f, pos_s].set(True, mode="drop")
        w_buf = jnp.zeros((G, E, C), jnp.float32).at[
            gidx, ids_f, pos_s].set(
            wg.reshape(G, TK).astype(jnp.float32), mode="drop")
        pos_t = pos.reshape(G, Tg, k)
        keep_t = keep.reshape(G, Tg, k)
        tp = manual_tp_size()
        if tp > 1:
            # manual expert parallelism (pipeline islands): this shard owns
            # the contiguous expert block [off, off + E/tp).  Routing was
            # computed on global ids (replicated over model), so slice the
            # slot maps to the local block, restrict the combine to slots
            # whose expert lives here, and psum token outputs over `model`
            # — the collective GSPMD inserts itself in the auto-sharded
            # (EP over "tp" constraint) path below.
            El = E // tp
            off = jax.lax.axis_index(MODEL_AXIS) * El
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, off, El, axis=1)
            inv, valid, w_buf = sl(inv), sl(valid), sl(w_buf)
            keep_t = keep_t & (idg >= off) & (idg < off + El)
            idg = jnp.clip(idg - off, 0, El - 1)
        buf = _dispatch_gather((Tg, str(xg.dtype)), xg, inv, valid)
        # groups shard over data (each DP shard dispatches its own tokens),
        # experts shard over model (EP)
        buf = constrain(buf, "dp", "tp", None, None)
        mode = kernel_mode()
        if mode != "off":
            # expert-batched grouped matmuls: the buf/weight expert dims
            # are tp-local here (sliced above), the group dim folds into
            # capacity inside the dispatch
            dk = _dispatch()
            h = dk.gmm(buf, p["we_up"], mode=mode)
            g = dk.gmm(buf, p["we_gate"], mode=mode)
            yb = dk.gmm(activation(g, "silu") * h, p["we_down"], mode=mode)
        else:
            h = jnp.einsum("gecd,edf->gecf", buf, p["we_up"])
            g = jnp.einsum("gecd,edf->gecf", buf, p["we_gate"])
            yb = jnp.einsum("gecf,efd->gecd", activation(g, "silu") * h,
                            p["we_down"])
        yb = constrain(yb, "dp", "tp", None, None).astype(xf.dtype)
        # combine: one (G,Tg,d) gather per top-k slot — never (G,TK,d)
        y = _combine_gather(yb, inv, valid, w_buf, idg, pos_t, keep_t, wg)
        if tp > 1:
            # each token's experts may live on different model shards
            y = jax.lax.psum(y, MODEL_AXIS)
        y = constrain(y, "dp", None, None).reshape(T, d)

    if cfg.moe_shared_expert:
        y = y + mlp_block(p["shared"], xf, dataclasses.replace(
            cfg, act="silu")).reshape(T, d)
    return y.reshape(B, S, d), aux


# ------------------------------------------------------------------ Mamba-2
def init_mamba(key, cfg: ModelConfig) -> dict:
    """Separate projections (not one packed in_proj) so each tensor has a
    clean TP sharding: z/x/out on d_inner, small B/C/dt replicated."""
    d, di = cfg.d_model, cfg.d_inner
    H, N = cfg.ssm_heads, cfg.ssm_state
    ks = jax.random.split(key, 9)
    std = 0.02
    dt = jnp.dtype(cfg.dtype)
    w = cfg.ssm_conv_width
    return {
        "w_z": (jax.random.normal(ks[0], (d, di)) * std).astype(dt),
        "w_x": (jax.random.normal(ks[1], (d, di)) * std).astype(dt),
        "w_B": (jax.random.normal(ks[2], (d, N)) * std).astype(dt),
        "w_C": (jax.random.normal(ks[3], (d, N)) * std).astype(dt),
        "w_dt": (jax.random.normal(ks[4], (d, H)) * std).astype(dt),
        "conv_x": (jax.random.normal(ks[5], (w, di)) * std).astype(dt),
        "conv_B": (jax.random.normal(ks[6], (w, N)) * std).astype(dt),
        "conv_C": (jax.random.normal(ks[7], (w, N)) * std).astype(dt),
        "conv_bx": jnp.zeros((di,), dt),
        "conv_bB": jnp.zeros((N,), dt),
        "conv_bC": jnp.zeros((N,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, float(H), H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_proj": (jax.random.normal(ks[8], (di, d)) * std).astype(dt),
        "norm": jnp.ones((di,), dt),
    }


def _causal_conv(x: Array, w: Array, b: Array,
                 state: Array | None = None) -> Array:
    """Depthwise causal conv along seq. x: (B,S,C), w: (W,C), b: (C,)."""
    W = w.shape[0]
    pad = x if state is None else jnp.concatenate([state, x], axis=1)
    pad = jnp.pad(pad, ((0, 0), (W - 1 if state is None else 0, 0), (0, 0)))
    S = x.shape[1]
    windows = jnp.stack([pad[:, i:i + S] for i in range(W)], axis=2)
    return jnp.einsum("bswc,wc->bsc", windows, w) + b


def mamba_block(p: dict, x: Array, cfg: ModelConfig,
                init_state: Array | None = None,
                conv_state: Array | None = None):
    """Full Mamba-2 mixer. Returns (y, (ssm_state, conv_state))."""
    B, S, _d = x.shape
    P = cfg.ssm_head_dim
    w = cfg.ssm_conv_width
    z = x @ p["w_z"]
    xs_raw = x @ p["w_x"]
    b_raw = x @ p["w_B"]
    c_raw = x @ p["w_C"]
    dt_raw = x @ p["w_dt"]
    cs = (None, None, None) if conv_state is None else conv_state
    xs = jax.nn.silu(_causal_conv(xs_raw, p["conv_x"], p["conv_bx"], cs[0]))
    bmat = jax.nn.silu(_causal_conv(b_raw, p["conv_B"], p["conv_bB"], cs[1]))
    cmat = jax.nn.silu(_causal_conv(c_raw, p["conv_C"], p["conv_bC"], cs[2]))
    new_conv_state = None
    if w > 1:
        new_conv_state = (xs_raw[:, S - (w - 1):],
                          b_raw[:, S - (w - 1):], c_raw[:, S - (w - 1):])

    # head count from the local projection width, not cfg: inside a manual
    # tp region xs carries d_inner/tp channels, i.e. H/tp local heads (the
    # per-head dim P is never sharded), and w_dt/A_log/D/dt_bias are
    # sharded over the same heads so every shape below stays consistent
    xh = xs.reshape(B, S, -1, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    # the chunked SSD lives with its kernel (repro.kernels.ssd_chunk):
    # the jnp path routes through the same ssd_chunk_ref math the Pallas
    # kernel is verified against, and kernel_mode() swaps the intra-chunk
    # term for the pallas_call (H is tp-local here — see comment above)
    from repro.kernels.ssd_chunk import ssd_chunked
    y, state = ssd_chunked(xh.astype(jnp.float32), dt, A,
                           bmat.astype(jnp.float32),
                           cmat.astype(jnp.float32),
                           p["D"], cfg.ssm_chunk,
                           init_state=init_state, mode=kernel_mode())
    y = y.reshape(B, S, xs.shape[-1]).astype(x.dtype)
    # the gated norm normalizes over (possibly sharded) d_inner; out_proj
    # is row-parallel — both carry explicit tp collectives in manual mode
    gated = y * jax.nn.silu(z)
    mode = kernel_mode()
    if mode != "off" and manual_tp_size() == 1:
        y = _dispatch().rmsnorm(gated, p["norm"], mode=mode)
    else:
        y = _tp_rmsnorm(gated, p["norm"])
    return (_row_parallel_einsum("bsf,fd->bsd", y, p["out_proj"], x.dtype),
            (state, new_conv_state))


def mamba_decode_step(p: dict, x: Array, cfg: ModelConfig,
                      ssm_state: Array, conv_state: Array):
    """One-token Mamba-2 step. x: (B,1,d); conv_state: (B, W-1, di+2N)
    packed [x | B | C]. Returns (y, new states)."""
    B, _, d = x.shape
    di, H, N, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    x0 = x[:, 0]
    z = x0 @ p["w_z"]
    xbc_raw = jnp.concatenate(
        [x0 @ p["w_x"], x0 @ p["w_B"], x0 @ p["w_C"]], axis=-1)
    dt_raw = x0 @ p["w_dt"]
    conv_in = jnp.concatenate([conv_state, xbc_raw[:, None]], axis=1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=1)
    conv_b = jnp.concatenate([p["conv_bx"], p["conv_bB"], p["conv_bC"]])
    xbc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", conv_in, conv_w) + conv_b)
    new_conv = conv_in[:, 1:]
    xs, bmat, cmat = jnp.split(xbc, [di, di + N], axis=-1)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, :])
    a = jnp.exp(dt * (-jnp.exp(p["A_log"]))[None, :])          # (B,H)
    s_new = (ssm_state * a[:, :, None, None]
             + jnp.einsum("bh,bn,bhp->bhnp", dt,
                          bmat.astype(jnp.float32), xh))
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), s_new)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return (y @ p["out_proj"])[:, None], (s_new, new_conv)
