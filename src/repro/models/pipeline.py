"""Pipeline-parallel forward/loss for the unified LM.

This is the launch-layer bridge between the canonical stacked-params
model (`repro.models.transformer`) and the `repro.dist.pipeline`
executors: the layer stack is partitioned into `n_stages` contiguous
groups of repeats along the ``"stage"`` mesh axis and driven through the
microbatched GPipe schedule, while embeddings / final norm / LM head
stay in the automatically-sharded outer world.

Layer order matches the baseline `forward` exactly: the baseline applies
all `n_repeats` of pattern position 0, then all of position 1, etc.
(position-major), so each position's repeats are pipelined
*independently* — stage s holds a contiguous chunk of every position's
repeats (equal chunks ``[s·k, (s+1)·k)`` for a uniform plan, per-stage
counts from `PipelinePlan.sizes` for a heterogeneous one, padded and
masked so every stage scans the same chunk shape), and sequential
composition across stages reproduces the baseline scan order op-for-op.  Per microbatch, every op is the same op
the non-pipelined step runs on the same rows, so ``--stages > 1``
matches the baseline to numerical tolerance (bf16 reduction tiling is
the only difference), and MoE auxiliary losses are averaged over
microbatches to keep the 0.01·aux term comparable.

Inside the shard_map islands, `repro.dist.context.constrain` no-ops on
its own (it detects the bound manual axes), so the blocks run the exact
baseline layer code — including custom_vjp backward rules and remat
re-traces, which are traced outside any context manager a caller could
hold around the forward call.

Pipeline stages compose with tensor parallelism: on a
``("stage", "data", "model")`` mesh each island's in_specs come from
`pipeline_stage_specs` (`param_specs` composed with
`stage_stack_specs`), so Megatron-sharded leaves stay ``P("model")``
inside the manual region.  The schedule's ppermute/psum name only the
``"stage"`` axis; the block math carries its own tp collectives
(`manual_tp_size` branches in `repro.models.layers`: explicit
``psum("model")`` after row-parallel projections, d_inner-consistent
head counts for Mamba, local-expert dispatch + psum combine for MoE) —
the same reductions GSPMD inserts in the non-pipelined forward.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map
from repro.dist.context import active_mesh
from repro.dist.pipeline import pipeline_apply_microbatched
from repro.dist.sharding import (data_axes, data_par_size,
                                 pipeline_stage_specs)
from repro.models.common import ModelConfig
from repro.models.transformer import _apply_block, ce_from_hidden, encode
from repro.models import layers as L

Array = Any


def stage_stack(stacked: Any, n_stages: int,
                sizes: Sequence[int] | None = None) -> Any:
    """(R, ...) stacked block params → (S, K, ...) per-stage chunks
    (leading dim shardable over the ``"stage"`` axis, see
    `repro.dist.sharding.stage_stack_specs`).

    With `sizes=None` the split is uniform — K = R/S, a free reshape —
    and requires `R % n_stages == 0`.  A heterogeneous `sizes` (one
    entry per stage, summing to R, entries may be 0) pads each stage's
    contiguous repeat chunk to ``K = max(sizes)``: padded slots
    replicate the chunk's last valid repeat (a stage with no valid
    repeats gets repeat 0) so they stay finite under autodiff, and the
    stage scan masks them out (`_stage_fn` keeps an identity carry and
    zero aux for slot r >= sizes[s]).  Their cotangents are exactly
    zero, so the gather's scatter-add transpose leaves the real repeats'
    gradients untouched.
    """
    uniform = sizes is None
    if sizes is not None:
        sizes = tuple(int(k) for k in sizes)
        if len(sizes) != n_stages or any(k < 0 for k in sizes):
            raise ValueError(
                f"sizes={sizes} is not a per-stage split for "
                f"n_stages={n_stages}")
        # all-equal sizes take the free-reshape path below, but only
        # after the sum-to-R check — collapsing first would silently
        # run a *different* split than the caller asked for
        uniform = min(sizes) == max(sizes)

    def r(leaf):
        R = leaf.shape[0]
        if sizes is not None and sum(sizes) != R:
            raise ValueError(f"sizes={sizes} must sum to n_repeats={R}")
        if uniform:
            if R % n_stages:
                raise ValueError(
                    f"n_repeats={R} not divisible by n_stages={n_stages} "
                    "— pass the plan's heterogeneous per-stage `sizes` "
                    "to use padded per-stage stacks")
            return leaf.reshape(n_stages, R // n_stages, *leaf.shape[1:])
        kmax = max(sizes)
        offs = [0]
        for k in sizes:
            offs.append(offs[-1] + k)
        idx = [[offs[s] + min(r, sizes[s] - 1) if sizes[s] else 0
                for r in range(kmax)] for s in range(n_stages)]
        return jnp.take(leaf, jnp.asarray(idx, jnp.int32), axis=0)

    return jax.tree.map(r, stacked)


def _stage_fn(cfg: ModelConfig, spec, remat: bool,
              sizes: Sequence[int] | None = None, axis: str = "stage"):
    """One pipeline stage: scan the local chunk of repeats of one pattern
    position.  The rotating carry is batch-leading: ``x`` (b, S, d) and
    ``aux`` (b,); the encoder output for enc-dec archs arrives as the
    schedule's *static* side input (read locally, never ppermuted).

    A heterogeneous `sizes` (per-stage valid-repeat counts, see
    `stage_stack`) switches the scan to masked form: every stage scans
    the same padded ``max(sizes)`` chunks, but slot r only updates the
    carry when ``r < sizes[axis_index(axis)]`` — padded repeats keep the
    identity carry and contribute zero aux, so the composition across
    stages is exactly the sequential stack.
    """
    def body(enc, carry, p):
        x, aux = carry["x"], carry["aux"]
        # `constrain` self-suppresses under the shard_map manual axes, so
        # the block body is the baseline one, no context games needed
        x, a = _apply_block(p, spec, cfg, x, enc)
        return {"x": x, "aux": aux + a / x.shape[0]}, None

    if remat:
        body = jax.checkpoint(body)

    if sizes is not None and min(sizes) == max(sizes):
        sizes = None            # equal chunks: every scanned slot is valid

    if sizes is None:
        def stage(local, carry, static=None):
            enc = None if static is None else static["enc"]
            carry, _ = jax.lax.scan(
                lambda c, p: body(enc, c, p), carry, local)
            return carry

        return stage

    valid_by_stage = tuple(int(k) for k in sizes)

    def stage(local, carry, static=None):
        enc = None if static is None else static["enc"]
        valid = jnp.asarray(valid_by_stage, jnp.int32)[
            jax.lax.axis_index(axis)]
        kmax = jax.tree.leaves(local)[0].shape[0]

        def masked(c, rp):
            r, p = rp
            # lax.cond, not where: the predicate is uniform across a
            # stage's (data, model) peers — axis_index(stage) and the
            # scan counter — so every collective participant inside the
            # block body takes the same branch, and padded slots *skip*
            # the block compute instead of computing-and-discarding.
            # The per-tick stage cost then tracks the valid work the
            # plan's bottleneck `stage_time_s` prices, not the padded
            # scan length.
            return jax.lax.cond(
                r < valid,
                lambda c, p: body(enc, c, p)[0],
                lambda c, p: c,
                c, p), None

        carry, _ = jax.lax.scan(
            masked, carry, (jnp.arange(kmax, dtype=jnp.int32), local))
        return carry

    return stage


def forward_pipelined(params: dict, cfg: ModelConfig, tokens: Array,
                      n_stages: int, n_micro: int,
                      patch_embeds: Array | None = None,
                      frames: Array | None = None,
                      remat: bool = False,
                      axis: str = "stage",
                      schedule: str = "gpipe",
                      sizes: Sequence[Sequence[int]] | None = None,
                      virtual_stages: int = 1
                      ) -> tuple[Array, Array]:
    """Pipeline-parallel `forward`: → (hidden (B, S_total, d), aux_loss).

    Must trace inside a `sharding_context` whose mesh carries the `axis`
    dimension.  Embedding, encoder, final norm (and the loss, in
    `loss_fn_pipelined`) run in the auto-sharded outer world; only the
    decoder layer stack runs under shard_map.

    `schedule` ("gpipe" | "1f1b" | "interleaved") picks the backward
    ordering of each island's microbatched schedule — forward numerics
    are identical, so any value matches the baseline to the same
    tolerance; "1f1b" differentiates through an explicit stash/pop step
    program instead of the scan transpose (see `repro.dist.pipeline`).

    `sizes` is the plan's heterogeneous partition
    (`PipelinePlan.sizes`): one per-stage valid-repeat row per pattern
    position (per *group* row of ``virtual_stages * n_stages`` entries
    for an interleaved plan).  `None` (or all-equal rows) keeps the
    uniform unpadded split; ragged rows run padded per-stage stacks with
    the masked stage scan (see `stage_stack` / `_stage_fn`).

    ``schedule="interleaved"`` with `virtual_stages` v > 1 splits every
    position's repeat chain into v contiguous chunks (group q = c·S + s
    of the plan lands on device s) and runs one island per chunk in
    repeat order — the sequential composition is op-for-op the baseline
    stack, like the flat position-major island loop.  The islands
    themselves run the "1f1b" micro-schedule: interleaving is a property
    of the fused loss-in-schedule executor
    (`pipeline_train_microbatched`), which keeps all v chunks in one
    scan; the island step realizes the same partition and numerics.
    """
    mesh = active_mesh()
    if mesh is None or axis not in mesh.shape:
        raise ValueError(
            f"forward_pipelined needs an active mesh with a {axis!r} axis")
    if mesh.shape[axis] != n_stages:
        raise ValueError(
            f"mesh {axis!r} axis is {mesh.shape[axis]}, plan says "
            f"{n_stages} stages")
    if sizes is not None and len(sizes) != len(cfg.pattern):
        raise ValueError(
            f"sizes has {len(sizes)} rows for {len(cfg.pattern)} pattern "
            "positions")
    v = int(virtual_stages)
    if v < 1:
        raise ValueError(f"need virtual_stages >= 1, got {virtual_stages}")
    if v > 1 and schedule != "interleaved":
        raise ValueError(
            f"virtual_stages={v} requires schedule='interleaved', got "
            f"{schedule!r}")
    n_groups = v * n_stages
    if sizes is not None and any(len(row) != n_groups for row in sizes):
        raise ValueError(
            f"sizes rows must have virtual_stages*n_stages={n_groups} "
            f"entries, got {[len(row) for row in sizes]}")

    x = jnp.take(params["embed"], tokens, axis=0)
    if patch_embeds is not None:
        px = patch_embeds @ params["patch_proj"]
        x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
    enc_out = encode(params, cfg, frames) if frames is not None else None

    daxes = data_axes(mesh)
    bentry = tuple(daxes) if daxes else None
    carry = {"x": x, "aux": jnp.zeros((x.shape[0],), jnp.float32)}
    static = None if enc_out is None else {"enc": enc_out}

    # the islands' micro-schedule: interleaving lives in the fused
    # executor; island chunks each pipeline their S-way split as 1f1b
    island_schedule = "1f1b" if schedule == "interleaved" else schedule
    for pos, spec in enumerate(cfg.pattern):
        row = None if sizes is None else tuple(int(k) for k in sizes[pos])
        stacked = params["layers"][pos]
        R = jax.tree.leaves(stacked)[0].shape[0]
        for c in range(v):
            if row is None:
                if R % v:
                    raise ValueError(
                        f"n_repeats={R} not divisible by "
                        f"virtual_stages={v} — pass the plan's "
                        "heterogeneous per-group `sizes`")
                n_c = R // v
                off, cnt = c * n_c, n_c
                chunk_sizes = None
            else:
                off = sum(row[:c * n_stages])
                cnt = sum(row[c * n_stages:(c + 1) * n_stages])
                chunk_sizes = row[c * n_stages:(c + 1) * n_stages]
            chunk = jax.tree.map(
                lambda p, _o=off, _n=cnt: p[_o:_o + _n], stacked)
            st = stage_stack(chunk, n_stages, sizes=chunk_sizes)
            stage = _stage_fn(cfg, spec, remat, sizes=chunk_sizes,
                              axis=axis)
            bspec = lambda t: jax.tree.map(lambda _: P(bentry), t)
            # island in_specs are param_specs composed with
            # stage_stack_specs: every leaf keeps its Megatron model-axis
            # entry alongside the leading stage entry, so tensor-sharded
            # dims stay P("model") inside the manual region (the block
            # math reduces row-parallel partials with explicit
            # psum("model") — see repro.models.layers) while the
            # schedule's own collectives name only the stage axis
            st_specs = pipeline_stage_specs(st, mesh, axis=axis)

            if static is None:
                def island(st, carry, _stage=stage):
                    return pipeline_apply_microbatched(
                        _stage, st, carry, n_micro, axis=axis,
                        schedule=island_schedule)

                in_specs = (st_specs, bspec(carry))
                args = (st, carry)
            else:
                def island(st, carry, static, _stage=stage):
                    return pipeline_apply_microbatched(
                        _stage, st, carry, n_micro, axis=axis,
                        static=static, schedule=island_schedule)

                in_specs = (st_specs, bspec(carry), bspec(static))
                args = (st, carry, static)

            carry = shard_map(
                island, mesh=mesh, in_specs=in_specs,
                out_specs=bspec(carry), check_vma=False,
            )(*args)

    h = L.norm(carry["x"], params["final_norm"], cfg.norm)
    # per-example aux contributions sum back to one aux value per
    # (microbatch, data shard) pair; their mean keeps the scale of the
    # baseline's single full-batch aux
    aux = carry["aux"].sum() / (n_micro * data_par_size(mesh))
    return h, aux


def loss_fn_pipelined(params: dict, cfg: ModelConfig, batch: dict,
                      n_stages: int, n_micro: int, ce_chunk: int = 512,
                      remat: bool = False, axis: str = "stage",
                      schedule: str = "gpipe",
                      sizes: Sequence[Sequence[int]] | None = None,
                      virtual_stages: int = 1) -> Array:
    """`loss_fn` with the layer stack executed as a stage pipeline."""
    h, aux = forward_pipelined(
        params, cfg, batch["tokens"], n_stages, n_micro,
        patch_embeds=batch.get("patch_embeds"),
        frames=batch.get("frames"), remat=remat, axis=axis,
        schedule=schedule, sizes=sizes, virtual_stages=virtual_stages)
    return ce_from_hidden(params, cfg, h, batch["labels"],
                          ce_chunk=ce_chunk) + 0.01 * aux


__all__ = ["forward_pipelined", "loss_fn_pipelined", "stage_stack"]
