"""Model configuration + TP-alignment transforms.

One config class describes all ten assigned architectures.  Layers are
given as a repeating *pattern* of LayerSpecs (`pattern × n_repeats` =
num_layers) so the forward pass can `lax.scan` over repeats — keeping the
lowered HLO O(pattern) instead of O(layers), which is what makes 80-layer
dry-runs compile fast on the CPU backend.

`tp_align` applies the documented semantics-preserving padding transforms
(DESIGN.md §5): vocab padded to a multiple of tp×128, query heads padded to
a multiple of tp (zero o-proj rows → inert), KV heads duplicated to exactly
tp (bit-identical attention, shardable KV cache).
"""
from __future__ import annotations

import dataclasses
import enum
import math


class LayerKind(str, enum.Enum):
    ATTN = "attn"          # full attention
    SWA = "swa"            # sliding-window attention
    MAMBA = "mamba"        # Mamba-2 SSD mixer


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: LayerKind = LayerKind.ATTN
    moe: bool = False      # MoE FFN instead of dense
    ffn: bool = True       # False → mixer-only block (pure Mamba archs)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense|moe|vlm|audio|hybrid|ssm
    pattern: tuple[LayerSpec, ...]
    n_repeats: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    window: int = 0                    # >0 for SWA layers
    rope_theta: float = 10000.0
    qk_norm: bool = False
    # activation / norm / block style
    act: str = "silu"                  # silu|relu2|gelu
    norm: str = "rmsnorm"              # rmsnorm|layernorm
    parallel_block: bool = False       # Cohere-style parallel attn+FFN
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0
    moe_shared_expert: bool = False    # llama4: shared expert alongside MoE
    capacity_factor: float = 1.25
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # encoder-decoder (whisper): encoder layers are full-attn, non-causal
    enc_layers: int = 0
    enc_frames: int = 1500             # stub frontend sequence length
    # VLM: prefix patch embeddings from the stubbed vision tower
    num_patches: int = 0
    # padding applied by tp_align (0 = unpadded)
    padded_vocab: int = 0
    padded_heads: int = 0
    padded_kv_heads: int = 0
    dtype: str = "bfloat16"

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.n_repeats

    @property
    def d_inner(self) -> int:          # Mamba-2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def vocab(self) -> int:
        return self.padded_vocab or self.vocab_size

    @property
    def q_heads(self) -> int:
        return self.padded_heads or self.num_heads

    @property
    def kv_heads(self) -> int:
        return self.padded_kv_heads or self.num_kv_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def n_params(self, active_only: bool = False) -> float:
        """Approximate parameter count (unpadded semantics), for 6·N·D."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d                       # embeddings
        if not self.tie_embeddings:
            n += self.vocab_size * d                  # head
        for spec in self.pattern:
            per = 0
            if spec.kind in (LayerKind.ATTN, LayerKind.SWA):
                per += d * (self.num_heads * self.head_dim) * 2   # q, o
                per += d * (self.num_kv_heads * self.head_dim) * 2
            else:
                di = self.d_inner
                per += d * (2 * di + 2 * self.ssm_heads * self.ssm_state
                            + self.ssm_heads) + di * d
            if spec.ffn:
                if spec.moe:
                    e = self.num_experts if not active_only \
                        else self.experts_per_tok
                    per += 3 * d * self.moe_d_ff * e
                    if self.moe_shared_expert:
                        per += 3 * d * self.moe_d_ff
                else:
                    mult = 3 if self.act == "silu" else 2
                    per += mult * d * self.d_ff
            per += 2 * d                               # norms
            n += per * self.n_repeats
        if self.is_encdec:
            # encoder self-attn + ffn, decoder cross-attn
            enc = self.enc_layers * (4 * d * d + 2 * d * self.d_ff + 2 * d)
            cross = L * (4 * d * d)
            n += enc + cross
        return float(n)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def tp_align(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Pad vocab/heads so every model-axis-sharded dim divides `tp`.

    - vocab → multiple of tp·128 (Megatron's make-vocab-size-divisible-by);
    - q heads → multiple of tp (zero o-proj rows for padded heads);
    - kv heads → duplicated to exactly tp when kv < tp (requires tp % kv == 0;
      attention outputs are bit-identical).
    """
    padded_vocab = _round_up(cfg.vocab_size, tp * 128)
    padded_heads = _round_up(cfg.num_heads, tp)
    if cfg.num_kv_heads >= tp:
        if cfg.num_kv_heads % tp:
            raise ValueError(f"kv={cfg.num_kv_heads} not divisible by tp={tp}")
        padded_kv = cfg.num_kv_heads
    else:
        if tp % cfg.num_kv_heads:
            raise ValueError(f"tp={tp} not a multiple of kv={cfg.num_kv_heads}")
        padded_kv = tp
    # q heads must be divisible by kv heads (grouping)
    padded_heads = _round_up(padded_heads, padded_kv)
    return dataclasses.replace(
        cfg, padded_vocab=padded_vocab, padded_heads=padded_heads,
        padded_kv_heads=padded_kv)


def kv_dup_factor(cfg: ModelConfig) -> int:
    """How many times each original KV head is duplicated."""
    return cfg.kv_heads // cfg.num_kv_heads


TRAIN_4K = ShapeSpec("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
