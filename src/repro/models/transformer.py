"""Unified LM covering all ten assigned architectures.

The layer stack is `lax.scan`'d over `n_repeats` of the config's layer
*pattern*, so lowered HLO size is O(|pattern|), independent of depth —
an 80-layer dry-run compiles as fast as an 8-layer one.  Heterogeneous
stacks (jamba's mamba/attn 7:1 interleave with MoE every 2nd layer) are
expressed as an 8-entry pattern repeated 4×.

Params are plain pytrees; per-pattern-position params are stacked along a
leading repeats axis.  `abstract_params` builds the same tree as
ShapeDtypeStructs (no allocation) for dry-run lowering.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from .common import LayerKind, LayerSpec, ModelConfig
from . import layers as L
from repro.dist.context import constrain, flag

Array = Any


def pick_chunk(s: int, target: int = 512) -> int:
    """Largest divisor of s that is ≤ target (chunked attention tiling)."""
    c = min(s, target)
    while s % c:
        c -= 1
    return c


# ------------------------------------------------------------------- params
def _init_block(key, cfg: ModelConfig, spec: LayerSpec) -> dict:
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    p: dict = {"ln1": jnp.ones((cfg.d_model,), dt)}
    if spec.kind in (LayerKind.ATTN, LayerKind.SWA):
        p["mixer"] = L.init_attention(ks[0], cfg)
    else:
        p["mixer"] = L.init_mamba(ks[0], cfg)
    if cfg.is_encdec:
        p["cross"] = L.init_attention(ks[1], cfg, cross=True)
        p["ln_cross"] = jnp.ones((cfg.d_model,), dt)
    if spec.ffn:
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = L.init_moe(ks[2], cfg) if spec.moe else L.init_mlp(ks[2], cfg)
    return p


def init_params(cfg: ModelConfig, key: Array) -> dict:
    keys = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.dtype)
    params: dict = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    # per pattern position: stack over repeats with vmap'd init
    layer_params = []
    pos_keys = jax.random.split(keys[1], len(cfg.pattern))
    for pos, spec in enumerate(cfg.pattern):
        rep_keys = jax.random.split(pos_keys[pos], cfg.n_repeats)
        layer_params.append(jax.vmap(
            lambda k, _spec=spec: _init_block(k, cfg, _spec))(rep_keys))
    params["layers"] = layer_params
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(
            keys[2], (cfg.d_model, cfg.vocab)) * 0.02).astype(dt)
    if cfg.is_encdec:
        enc_keys = jax.random.split(keys[3], cfg.enc_layers)
        enc_spec = LayerSpec(kind=LayerKind.ATTN, ffn=True)
        enc_cfg = dataclasses.replace(cfg, pattern=(enc_spec,),
                                      n_repeats=cfg.enc_layers)
        params["enc"] = {
            "layers": jax.vmap(
                lambda k: {
                    "ln1": jnp.ones((cfg.d_model,), dt),
                    "mixer": L.init_attention(k, cfg),
                    "ln2": jnp.ones((cfg.d_model,), dt),
                    "ffn": L.init_mlp(k, cfg),
                })(enc_keys),
            "final_norm": jnp.ones((cfg.d_model,), dt),
        }
    if cfg.num_patches:
        params["patch_proj"] = (jax.random.normal(
            keys[4], (cfg.d_model, cfg.d_model)) * 0.02).astype(dt)
    return params


def abstract_params(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct tree — dry-run stand-in, no device allocation."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(functools.partial(init_params, cfg), key)


# ------------------------------------------------------------------ forward
def _apply_block(p: dict, spec: LayerSpec, cfg: ModelConfig, x: Array,
                 enc_out: Array | None = None):
    """One block, full-sequence. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.norm(x, p["ln1"], cfg.norm)
    if spec.kind in (LayerKind.ATTN, LayerKind.SWA):
        window = cfg.window if spec.kind == LayerKind.SWA else 0
        mix = L.attention_block(p["mixer"], h, cfg, causal=True,
                                window=window)
    else:
        mix, _state = L.mamba_block(p["mixer"], h, cfg)
    if cfg.parallel_block and spec.ffn:
        # Cohere-style: attn and FFN both read the same normed input
        y = L.mlp_block(p["ffn"], h, cfg)
        return x + mix + y, aux
    x = x + mix
    if cfg.is_encdec and enc_out is not None:
        hc = L.norm(x, p["ln_cross"], cfg.norm)
        x = x + L.attention_block(p["cross"], hc, cfg, causal=False,
                                  kv=enc_out, use_rope=False)
    if spec.ffn:
        h2 = L.norm(x, p["ln2"], cfg.norm)
        if spec.moe:
            y, a = L.moe_block(p["ffn"], h2, cfg)
            aux += a
        else:
            y = L.mlp_block(p["ffn"], h2, cfg)
        x = x + y
    return x, aux


def _sinusoidal(seq: int, d: int) -> Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe


def encode(params: dict, cfg: ModelConfig, frames: Array) -> Array:
    """Whisper-style encoder over stubbed frame embeddings (B, F, d)."""
    x = frames + _sinusoidal(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def body(x, p):
        h = L.norm(x, p["ln1"], cfg.norm)
        x = x + L.attention_block(p["mixer"], h, cfg, causal=False,
                                  use_rope=False)
        h = L.norm(x, p["ln2"], cfg.norm)
        x = x + L.mlp_block(p["ffn"], h, cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc"]["layers"])
    return L.norm(x, params["enc"]["final_norm"], cfg.norm)


def forward(params: dict, cfg: ModelConfig, tokens: Array,
            patch_embeds: Array | None = None,
            frames: Array | None = None,
            remat: bool = False) -> tuple[Array, Array]:
    """Full-sequence forward → (hidden (B, S_total, d), aux_loss).

    patch_embeds: (B, P, d) VLM prefix (stub vision tower output).
    frames: (B, F, d) audio frames (stub conv frontend) for enc-dec.
    remat: checkpoint each scanned block (activation recomputation).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    if patch_embeds is not None:
        px = patch_embeds @ params["patch_proj"]
        x = jnp.concatenate([px.astype(x.dtype), x], axis=1)
    x = constrain(x, "dp", None, None)
    enc_out = encode(params, cfg, frames) if frames is not None else None

    aux_total = jnp.zeros((), jnp.float32)
    for pos, spec in enumerate(cfg.pattern):
        stacked = params["layers"][pos]

        def body(carry, p, _spec=spec):
            x, aux = carry
            x, a = _apply_block(p, _spec, cfg, x, enc_out)
            # `seq_shard` (Megatron-SP analogue): the residual stream is
            # sequence-sharded over the model axis between blocks, turning
            # the TP all-reduces into reduce-scatter/all-gather pairs and
            # cutting resident activation memory by tp×
            seq_axis = "tp" if flag("seq_shard") else None
            return (constrain(x, "dp", seq_axis, None), aux + a), None

        if remat:
            body = jax.checkpoint(body)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
    return L.norm(x, params["final_norm"], cfg.norm), aux_total


def logits_from_hidden(params: dict, cfg: ModelConfig, h: Array) -> Array:
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = h @ w
    if cfg.padded_vocab and cfg.padded_vocab > cfg.vocab_size:
        neg = jnp.full((), -1e9, logits.dtype)
        logits = jnp.where(
            jnp.arange(cfg.vocab) < cfg.vocab_size, logits, neg)
    return logits


def ce_from_hidden(params: dict, cfg: ModelConfig, h: Array, labels: Array,
                   ce_chunk: int = 512) -> Array:
    """Mean next-token CE from final hidden states, chunked over the
    sequence so (B, chunk, V) is the peak logits footprint (a 256k vocab
    never materializes (B, S, V))."""
    if h.shape[1] != labels.shape[1]:          # VLM prefix: loss on tokens
        h = h[:, h.shape[1] - labels.shape[1]:]
    B, S, _ = h.shape
    c = pick_chunk(S, ce_chunk)
    hc = h.reshape(B, S // c, c, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, S // c, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_loss(hx, lx):
        logits = logits_from_hidden(params, cfg, hx).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lx[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    def body(tot, xs):
        hx, lx = xs
        return tot + chunk_loss(hx, lx), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (B * S)


def loss_fn(params: dict, cfg: ModelConfig, batch: dict,
            ce_chunk: int = 512, remat: bool = False) -> Array:
    """Next-token CE over the full (non-pipelined) forward."""
    h, aux = forward(params, cfg, batch["tokens"],
                     patch_embeds=batch.get("patch_embeds"),
                     frames=batch.get("frames"), remat=remat)
    return ce_from_hidden(params, cfg, h, batch["labels"],
                          ce_chunk=ce_chunk) + 0.01 * aux


# ------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               abstract: bool = False) -> dict:
    """Decode caches per pattern position, stacked over repeats."""
    mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
         (lambda s, d: jnp.zeros(s, d))
    dt = jnp.dtype(cfg.dtype)
    R = cfg.n_repeats
    cache: dict = {"cur": (jax.ShapeDtypeStruct((), jnp.int32) if abstract
                           else jnp.zeros((), jnp.int32))}
    for pos, spec in enumerate(cfg.pattern):
        if spec.kind == LayerKind.ATTN:
            s = max_seq
            cache[f"pos{pos}"] = {
                "k": mk((R, batch, s, cfg.kv_heads, cfg.head_dim), dt),
                "v": mk((R, batch, s, cfg.kv_heads, cfg.head_dim), dt),
            }
        elif spec.kind == LayerKind.SWA:
            w = min(cfg.window, max_seq)
            cache[f"pos{pos}"] = {
                "k": mk((R, batch, w, cfg.kv_heads, cfg.head_dim), dt),
                "v": mk((R, batch, w, cfg.kv_heads, cfg.head_dim), dt),
            }
        else:
            cache[f"pos{pos}"] = {
                "ssm": mk((R, batch, cfg.ssm_heads, cfg.ssm_state,
                           cfg.ssm_head_dim), jnp.float32),
                "conv": mk((R, batch, cfg.ssm_conv_width - 1,
                            cfg.d_inner + 2 * cfg.ssm_state), dt),
            }
    if cfg.is_encdec:
        cache["enc_out"] = mk((batch, cfg.enc_frames, cfg.d_model), dt)
    return cache


def _decode_attn(p: dict, cfg: ModelConfig, x: Array, cpos: dict, r: Array,
                 cur: Array, window: int = 0) -> tuple[Array, dict]:
    """One attention decode step against the *stacked* cache (R, B, S, H, D).

    The cache is a scan carry: the new K/V land via an in-place slot write
    (`at[r, :, slot]`), and the attention read is a per-layer dynamic
    slice.  Threading per-layer slices through scan ys instead copies the
    whole cache every token — a 64× HBM-traffic bug the dry-run exposed.
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = L.rmsnorm(q, p["q_norm"])
        k = L.rmsnorm(k, p["k_norm"])
    pos = cur[None]
    q = L.rope(q, jnp.broadcast_to(pos, (B, 1)), cfg.rope_theta)
    k = L.rope(k, jnp.broadcast_to(pos, (B, 1)), cfg.rope_theta)
    S = cpos["k"].shape[2]
    slot = cur % S if window else jnp.minimum(cur, S - 1)
    # dynamic_update_slice (not scatter): XLA aliases the carried buffer,
    # so the write is one slot, not a cache copy
    zero = jnp.zeros((), jnp.int32)
    upd = lambda full, new: jax.lax.dynamic_update_slice(
        full, new[:, None].astype(full.dtype)[None],
        (r, zero, slot, zero, zero))
    k_full = upd(cpos["k"], k[:, 0])
    v_full = upd(cpos["v"], v[:, 0])
    kc = jax.lax.dynamic_index_in_dim(k_full, r, 0, keepdims=False)
    vc = jax.lax.dynamic_index_in_dim(v_full, r, 0, keepdims=False)
    n_valid = jnp.minimum(cur + 1, S)
    out = L.decode_attention(q, kc, vc, n_valid)
    return (L._row_parallel_einsum("bshk,hkd->bsd", out, p["wo"], x.dtype),
            {"k": k_full, "v": v_full})


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                token: Array) -> tuple[Array, dict]:
    """One decode step. token: (B, 1) int32 → (logits (B,1,V), new cache)."""
    cur = cache["cur"]
    x = jnp.take(params["embed"], token, axis=0)
    enc_out = cache.get("enc_out")
    new_cache: dict = {"cur": cur + 1}
    if enc_out is not None:
        new_cache["enc_out"] = enc_out

    for pos, spec in enumerate(cfg.pattern):
        stacked_p = params["layers"][pos]
        cache_pos = cache[f"pos{pos}"]
        R = cfg.n_repeats

        def body(carry, pr, _spec=spec):
            x, cpos = carry
            p, r = pr
            h = L.norm(x, p["ln1"], cfg.norm)
            if _spec.kind == LayerKind.ATTN:
                mix, cpos = _decode_attn(p["mixer"], cfg, h, cpos, r, cur)
            elif _spec.kind == LayerKind.SWA:
                mix, cpos = _decode_attn(p["mixer"], cfg, h, cpos, r, cur,
                                         window=cfg.window)
            else:
                ssm_r = jax.lax.dynamic_index_in_dim(cpos["ssm"], r, 0,
                                                     keepdims=False)
                conv_r = jax.lax.dynamic_index_in_dim(cpos["conv"], r, 0,
                                                      keepdims=False)
                mix, (s_new, conv_new) = L.mamba_decode_step(
                    p["mixer"], h, cfg, ssm_r, conv_r)
                cpos = {
                    "ssm": jax.lax.dynamic_update_index_in_dim(
                        cpos["ssm"], s_new, r, 0),
                    "conv": jax.lax.dynamic_update_index_in_dim(
                        cpos["conv"], conv_new.astype(cpos["conv"].dtype),
                        r, 0),
                }
            if cfg.parallel_block and _spec.ffn:
                y = L.mlp_block(p["ffn"], h, cfg)
                return (x + mix + y, cpos), None
            x = x + mix
            if cfg.is_encdec and enc_out is not None:
                hc = L.norm(x, p["ln_cross"], cfg.norm)
                x = x + L.attention_block(p["cross"], hc, cfg, causal=False,
                                          kv=enc_out, use_rope=False)
            if _spec.ffn:
                h2 = L.norm(x, p["ln2"], cfg.norm)
                if _spec.moe:
                    y, _ = L.moe_block(p["ffn"], h2, cfg)
                else:
                    y = L.mlp_block(p["ffn"], h2, cfg)
                x = x + y
            return (x, cpos), None

        (x, cache_pos), _ = jax.lax.scan(
            body, (x, cache_pos),
            (stacked_p, jnp.arange(R, dtype=jnp.int32)))
        new_cache[f"pos{pos}"] = cache_pos

    h = L.norm(x, params["final_norm"], cfg.norm)
    return logits_from_hidden(params, cfg, h), new_cache


@dataclasses.dataclass(frozen=True)
class Model:
    """Convenience bundle for the public API."""
    cfg: ModelConfig

    def init(self, key):
        return init_params(self.cfg, key)

    def loss(self, params, batch):
        return loss_fn(params, self.cfg, batch)

    def decode(self, params, cache, token):
        return decode_step(params, self.cfg, cache, token)
