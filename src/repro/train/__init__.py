"""Training/serving substrate."""
from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm
from .step import make_serve_step, make_train_step, zero1_specs

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "make_serve_step", "make_train_step", "zero1_specs"]
