"""Train/serve step factories.

`make_train_step` builds the full step: (params, opt_state, batch) →
(params, opt_state, metrics) with
  - chunked-CE loss (+ MoE aux), per-block remat,
  - optional microbatch gradient accumulation via `lax.scan` — the MKPipe
    GLOBALMEM plan at pod scale: producer microbatch k+1's forward overlaps
    consumer microbatch k's gradient DMA,
  - grad-norm clipping + AdamW,
  - optional ZeRO-1: optimizer moments get sharding constraints that
    scatter them over the data axis, turning the gradient all-reduce into
    reduce-scatter + all-gather in the compiled collective schedule.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.context import active_mesh, flag
from repro.models.common import ModelConfig
from repro.models.transformer import decode_step, loss_fn
from .optimizer import AdamWConfig, adamw_init, adamw_update

Array = Any


def zero1_specs(param_specs_tree: Any, params_tree: Any, mesh: Mesh,
                axis: str = "data") -> Any:
    """Optimizer-moment specs: additionally shard the first still-
    replicated, divisible dim over the data axis (ZeRO-1)."""
    dp = mesh.shape[axis]

    def z(spec: P, leaf) -> P:
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (e, dim) in enumerate(zip(entries, leaf.shape)):
            if e is None and dim % dp == 0 and dim >= dp:
                entries[i] = axis
                return P(*entries)
        return P(*entries)

    return jax.tree.map(z, param_specs_tree, params_tree)


def train_state_specs(pspecs: Any, opt_state: Any) -> tuple:
    """Spec tree mirroring a ``(params, opt_state)`` train state.

    AdamW moments shard exactly like the params they track (`m`/`v`
    mirror `pspecs`); the step count and any other optimizer leaves
    (e.g. the int8 error-feedback residuals) replicate.  The result has
    the state's tree structure, so it sanitizes / converts to
    `NamedSharding`s with one tree.map — the restore and elastic-reshard
    target the driver threads through every recovery path.
    """
    opt_specs = {}
    for k, sub in opt_state.items():
        if k in ("m", "v"):
            opt_specs[k] = pspecs
        else:
            opt_specs[k] = jax.tree.map(lambda _: P(), sub)
    return pspecs, opt_specs


def _compressed_grads(loss_of, params, err, batch, mesh):
    """int8 error-feedback gradient reduction over the data axes.

    A shard_map island replaces GSPMD's implicit f32 gradient all-reduce:
    each data shard differentiates its local batch slice, quantizes
    grad+residual to int8 (`compressed_psum`), and the all-reduce runs on
    the dequantized-but-int8-rounded values — the dry-run roofline shows
    the collective-bytes A/B.  `err` leaves carry a leading shard dim
    (see `init_stacked_errors`); params must not be model-sharded (the
    island replicates them over the mapped axes).
    """
    from repro.dist.compat import shard_map
    from repro.dist.compression import compressed_psum
    from repro.dist.sharding import data_axes, data_par_size

    if mesh.shape.get("model", 1) != 1:
        raise ValueError("grad_int8 requires model parallelism = 1 "
                         "(the reduction island replicates params)")
    daxes = data_axes(mesh)
    if not daxes:
        raise ValueError("grad_int8 needs a data axis in the mesh")
    dp = data_par_size(mesh)
    for k, v in batch.items():
        if v.shape[0] % dp:
            raise ValueError(
                f"grad_int8: batch leaf {k!r} dim 0 ({v.shape[0]}) must be "
                f"a multiple of the data-parallel shard count {dp}")

    def island(params, err, batch):
        local_err = jax.tree.map(lambda l: l[0], err)
        # `constrain` self-suppresses under the manual axes, so loss_of is
        # the baseline loss on this shard's slice
        loss, g = jax.value_and_grad(loss_of)(params, batch)
        pairs = jax.tree.map(
            lambda gl, el: compressed_psum(gl, daxes, el), g, local_err)
        is_pair = lambda t: isinstance(t, tuple)
        g = jax.tree.map(lambda t: t[0], pairs, is_leaf=is_pair)
        new_err = jax.tree.map(lambda t: t[1][None], pairs, is_leaf=is_pair)
        loss = jax.lax.pmean(loss, daxes)
        return loss, g, new_err

    bspec = lambda l: P(daxes, *([None] * (jnp.ndim(l) - 1)))
    return shard_map(
        island, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), params),
                  jax.tree.map(lambda _: P(daxes), err),
                  jax.tree.map(bspec, batch)),
        out_specs=(P(), jax.tree.map(lambda _: P(), params),
                   jax.tree.map(lambda _: P(daxes), err)),
        check_vma=False,
    )(params, err, batch)


def make_train_step(cfg: ModelConfig, opt: AdamWConfig | None = None,
                    grad_accum: int = 1, remat: bool = True,
                    zero1_constraints: Any = None, pipeline: Any = None):
    """Returns train_step(params, opt_state, batch) → (p, s, metrics).

    pipeline: an optional `repro.train.pipeline.PipelinePlan`; with
    n_stages > 1 the loss runs the layer stack through the microbatched
    schedule named by the plan (GPipe, 1F1B, or interleaved virtual-stage
    backward ordering) over the ``"stage"`` mesh axis (`--stages 1`
    keeps the exact non-pipelined step, bit-for-bit).
    """
    opt = opt or AdamWConfig()
    pipelined = pipeline is not None and pipeline.n_stages > 1
    if pipelined and grad_accum > 1:
        raise ValueError("pipeline microbatching replaces grad_accum; "
                         "use --microbatch, not both")

    if pipelined:
        from repro.models.pipeline import loss_fn_pipelined

        def loss_of(params, batch):
            return loss_fn_pipelined(
                params, cfg, batch, pipeline.n_stages, pipeline.n_micro,
                remat=remat, axis=pipeline.axis,
                schedule=pipeline.schedule, sizes=pipeline.sizes,
                virtual_stages=getattr(pipeline, "virtual_stages", 1))
    else:
        def loss_of(params, batch):
            return loss_fn(params, cfg, batch, remat=remat)

    def train_step(params, opt_state, batch):
        # trace-time: the grad_int8 context flag routes the gradient
        # reduction through the int8 error-feedback island
        use_int8 = (flag("grad_int8") and isinstance(opt_state, dict)
                    and "err" in opt_state)
        if flag("grad_int8") and not use_int8:
            raise ValueError("grad_int8 flag set but opt_state has no "
                             "'err' residuals (see init_stacked_errors)")
        new_err = None
        if use_int8:
            if pipelined:
                raise ValueError("grad_int8 and pipeline stages are "
                                 "mutually exclusive")
            if grad_accum > 1:
                raise ValueError("grad_int8 with grad_accum > 1 is not "
                                 "supported")
            mesh = active_mesh()
            if mesh is None:
                raise ValueError("grad_int8 needs an active sharding "
                                 "context mesh")
            loss, grads, new_err = _compressed_grads(
                loss_of, params, opt_state["err"], batch, mesh)
        elif grad_accum > 1:
            # microbatch software pipeline (GLOBALMEM-plan analogue)
            def micro(carry, mb):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, grad_acc, g)), None

            mbs = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        moments = {k: v for k, v in opt_state.items() if k != "err"}
        new_params, new_state, metrics = adamw_update(
            opt, grads, moments, params)
        if new_err is not None:
            new_state["err"] = new_err
        elif "err" in opt_state:
            # flag off but residuals present (e.g. resuming a grad_int8
            # checkpoint without the flag): carry them through untouched
            # so the state pytree keeps its structure
            new_state["err"] = opt_state["err"]
        if zero1_constraints is not None:
            new_state = dict(new_state)
            new_state["m"] = jax.lax.with_sharding_constraint(
                new_state["m"], zero1_constraints)
            new_state["v"] = jax.lax.with_sharding_constraint(
                new_state["v"], zero1_constraints)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """Forward over the full prompt → last-token logits (inference)."""
    from repro.models.transformer import forward, logits_from_hidden

    def prefill(params, batch):
        h, _ = forward(params, cfg, batch["tokens"],
                       patch_embeds=batch.get("patch_embeds"),
                       frames=batch.get("frames"))
        return logits_from_hidden(params, cfg, h[:, -1:])

    return prefill


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, token) → (logits, cache)."""

    def serve(params, cache, token):
        return decode_step(params, cfg, cache, token)

    return serve


def init_train_state(cfg: ModelConfig, params: Any):
    return adamw_init(params)
