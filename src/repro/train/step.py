"""Train/serve step factories.

`make_train_step` builds the full step: (params, opt_state, batch) →
(params, opt_state, metrics) with
  - chunked-CE loss (+ MoE aux), per-block remat,
  - optional microbatch gradient accumulation via `lax.scan` — the MKPipe
    GLOBALMEM plan at pod scale: producer microbatch k+1's forward overlaps
    consumer microbatch k's gradient DMA,
  - grad-norm clipping + AdamW,
  - optional ZeRO-1: optimizer moments get sharding constraints that
    scatter them over the data axis, turning the gradient all-reduce into
    reduce-scatter + all-gather in the compiled collective schedule.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models.common import ModelConfig
from repro.models.transformer import decode_step, loss_fn
from .optimizer import AdamWConfig, adamw_init, adamw_update

Array = Any


def zero1_specs(param_specs_tree: Any, params_tree: Any, mesh: Mesh,
                axis: str = "data") -> Any:
    """Optimizer-moment specs: additionally shard the first still-
    replicated, divisible dim over the data axis (ZeRO-1)."""
    dp = mesh.shape[axis]

    def z(spec: P, leaf) -> P:
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (e, dim) in enumerate(zip(entries, leaf.shape)):
            if e is None and dim % dp == 0 and dim >= dp:
                entries[i] = axis
                return P(*entries)
        return P(*entries)

    return jax.tree.map(z, param_specs_tree, params_tree)


def make_train_step(cfg: ModelConfig, opt: AdamWConfig | None = None,
                    grad_accum: int = 1, remat: bool = True,
                    zero1_constraints: Any = None):
    """Returns train_step(params, opt_state, batch) → (p, s, metrics)."""
    opt = opt or AdamWConfig()

    def loss_of(params, batch):
        return loss_fn(params, cfg, batch, remat=remat)

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            # microbatch software pipeline (GLOBALMEM-plan analogue)
            def micro(carry, mb):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, grad_acc, g)), None

            mbs = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zero), mbs)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        new_params, new_state, metrics = adamw_update(
            opt, grads, opt_state, params)
        if zero1_constraints is not None:
            new_state = dict(new_state)
            new_state["m"] = jax.lax.with_sharding_constraint(
                new_state["m"], zero1_constraints)
            new_state["v"] = jax.lax.with_sharding_constraint(
                new_state["v"], zero1_constraints)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """Forward over the full prompt → last-token logits (inference)."""
    from repro.models.transformer import forward, logits_from_hidden

    def prefill(params, batch):
        h, _ = forward(params, cfg, batch["tokens"],
                       patch_embeds=batch.get("patch_embeds"),
                       frames=batch.get("frames"))
        return logits_from_hidden(params, cfg, h[:, -1:])

    return prefill


def make_serve_step(cfg: ModelConfig):
    """One decode step: (params, cache, token) → (logits, cache)."""

    def serve(params, cache, token):
        return decode_step(params, cfg, cache, token)

    return serve


def init_train_state(cfg: ModelConfig, params: Any):
    return adamw_init(params)
