"""Stage-partition planning for pipeline-parallel training.

MKPipe's Alg. 1 (throughput balancing) picks stage boundaries so the
bottleneck stage is as fast as possible.  Here the "kernels" are the
transformer blocks: `estimate_block_costs` prices one block per pattern
position through the same XLA cost-analysis path the MKPipe stage
profiler uses (`repro.core.planner._stage_cost`), converts FLOPs/bytes
into a roofline time, and `plan_pipeline` runs `balance_stages` over the
per-repeat cost vector to derive the per-stage repeat counts.

Stacked per-stage params require every stage to hold the same number of
repeats of every position; the planner verifies the balanced partition
is uniform (true exactly when `n_repeats % n_stages == 0`, since all
repeats of a position cost the same) and reports the predicted bottleneck
stage time and fill/drain bubble for the chosen (n_micro, n_stages).
"""
from __future__ import annotations

import dataclasses
import functools
import logging

import jax
import jax.numpy as jnp

from repro.dist.pipeline import (SCHEDULES, balance_stages,
                                 pipeline_bubble_fraction,
                                 pipeline_peak_activation_bytes,
                                 pipeline_peak_inflight)
from repro.models.common import LayerKind, ModelConfig

log = logging.getLogger("repro.pipeline")

# TPU v5e-like roofline constants (per chip), matching launch/dryrun.
PEAK_FLOPS = 197e12
HBM_BW = 819e9


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A validated stage partition for `make_train_step(pipeline=...)`."""
    n_stages: int
    n_micro: int
    repeats_per_stage: int
    sizes: tuple[int, ...]            # balance_stages output, repeats/stage
    block_costs_s: tuple[float, ...]  # per pattern position, one repeat,
    #                                   per model shard (already tp-divided)
    stage_time_s: float               # predicted bottleneck stage time
    bubble: float                     # analytic fill/drain bubble fraction
    axis: str = "stage"
    schedule: str = "gpipe"           # backward ordering: "gpipe" | "1f1b"
    tp: int = 1                       # model-parallel degree inside stages
    # analytic *schedule model* (see pipeline_peak_inflight): what a
    # loss-in-schedule executor stashes.  The island-based train step
    # keeps the loss outside the schedule, so it stashes M microbatches
    # per stage under either schedule — these fields predict the fused
    # executor / real-hardware bound, not today's island step's HBM.
    peak_inflight: int = 0            # stashed microbatches, worst stage
    peak_activation_bytes: float = 0.0  # peak_inflight × microbatch bytes


def _analytic_block_cost(cfg: ModelConfig, pos: int, tokens: int) -> float:
    """Fallback cost: 6·N_block·tokens FLOPs at roofline peak."""
    spec = cfg.pattern[pos]
    d = cfg.d_model
    n = 0.0
    if spec.kind in (LayerKind.ATTN, LayerKind.SWA):
        n += d * (cfg.num_heads * cfg.head_dim) * 2
        n += d * (cfg.num_kv_heads * cfg.head_dim) * 2
    else:
        di = cfg.d_inner
        n += d * (2 * di + 2 * cfg.ssm_heads * cfg.ssm_state
                  + cfg.ssm_heads) + di * d
    if spec.ffn:
        if spec.moe:
            n += 3 * d * cfg.moe_d_ff * max(cfg.experts_per_tok, 1)
        else:
            n += (3 if cfg.act == "silu" else 2) * d * cfg.d_ff
    return 6.0 * n * tokens / PEAK_FLOPS


def estimate_block_costs(cfg: ModelConfig, batch: int, seq: int,
                         tp: int = 1) -> list[float]:
    """Per-pattern-position cost (seconds) of one block's forward at
    (batch, seq): XLA cost analysis of the lowered block (the stage
    profiler's FLOP/byte estimates) folded through the roofline,
    falling back to the analytic 6·N·D estimate when compilation of the
    probe is unavailable.

    `tp` prices *per-model-shard* work: the probe lowers the full block
    and the roofline time divides by `tp`, since every sharded tensor
    (heads, d_ff, d_inner, experts) splits its FLOPs and bytes evenly
    over the model axis — so `balance_stages` partitions stages by the
    work one device actually runs, not the unsharded block.  (The
    replicated residue — norms, routers — is negligible at roofline
    granularity; a uniform divisor also leaves the *relative* costs, and
    hence the partition, of homogeneous stacks unchanged.)"""
    from repro.models.transformer import _apply_block, _init_block

    if tp < 1:
        raise ValueError(f"need tp >= 1, got {tp}")
    costs = []
    x_sds = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32)
    for pos, spec in enumerate(cfg.pattern):
        try:
            p_abs = jax.eval_shape(
                functools.partial(_init_block, cfg=cfg, spec=spec), key_sds)
            fn = lambda p, x, _s=spec: _apply_block(p, _s, cfg, x)[0]
            compiled = jax.jit(fn).lower(p_abs, x_sds).compile()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):  # jax<=0.4 returns [dict]
                ca = ca[0] if ca else {}
            flops = float(ca.get("flops", 0.0))
            bts = float(ca.get("bytes accessed", 0.0))
            cost = max(flops / PEAK_FLOPS, bts / HBM_BW)
            if cost <= 0.0:
                raise ValueError("empty cost analysis")
        except Exception as exc:               # pragma: no cover - fallback
            log.debug("block cost probe failed at pos %d (%s); "
                      "using analytic estimate", pos, exc)
            cost = _analytic_block_cost(cfg, pos, batch * seq)
        costs.append(cost / tp)
    return costs


def plan_pipeline(cfg: ModelConfig, n_stages: int, n_micro: int, *,
                  global_batch: int, seq_len: int, dp: int = 1,
                  tp: int = 1, axis: str = "stage",
                  schedule: str = "gpipe",
                  block_costs: list[float] | None = None) -> PipelinePlan:
    """Validate and price an (n_stages, n_micro) pipeline for `cfg`.

    `tp` is the model-parallel degree *inside* each stage (the mesh's
    ``"model"`` axis): block costs are priced per model shard
    (`estimate_block_costs(tp=...)`) so `balance_stages` and the
    bottleneck `stage_time_s` reflect the work one device runs on a
    stage × data × model mesh.  Microbatch activation bytes are
    unchanged by `tp` — the residual stream is replicated over the model
    axis inside the islands.

    `schedule` picks the backward ordering ("gpipe" or "1f1b"); it does
    not change the partition or the bubble, only the plan's predicted
    peak activation memory (`peak_inflight` × one microbatch's residual
    stream: (local_batch/n_micro) · seq · d_model · itemsize).  That
    prediction is the *schedule's* analytic model — realized by
    executors that run the loss inside the schedule
    (`pipeline_train_microbatched`, real hardware); the island-based
    train step differentiates the loss outside the schedule and stashes
    all n_micro microbatches per stage under either value (see
    docs/pipeline-schedules.md).

    Raises ValueError when the partition can't produce stacked per-stage
    params (n_repeats % n_stages != 0), the per-data-shard batch can't
    be microbatched (global_batch/dp % n_micro != 0), or `schedule` is
    unknown.
    """
    if n_stages < 1:
        raise ValueError(f"need n_stages >= 1, got {n_stages}")
    if n_micro < 1:
        raise ValueError(f"need n_micro >= 1, got {n_micro}")
    if tp < 1:
        raise ValueError(f"need tp >= 1, got {tp}")
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; want {SCHEDULES}")
    if cfg.n_repeats < n_stages:
        raise ValueError(
            f"{cfg.name}: n_repeats={cfg.n_repeats} < n_stages={n_stages}")
    if global_batch % dp:
        raise ValueError(
            f"global_batch={global_batch} not divisible by dp={dp}")
    local_batch = global_batch // dp
    if local_batch % n_micro:
        raise ValueError(
            f"per-shard batch {local_batch} not divisible by "
            f"n_micro={n_micro}")

    mb = max(local_batch // n_micro, 1)
    costs = (list(block_costs) if block_costs is not None
             else estimate_block_costs(cfg, mb, seq_len, tp=tp))
    if len(costs) != len(cfg.pattern):
        raise ValueError(
            f"got {len(costs)} block costs for {len(cfg.pattern)} positions")

    # One "layer" of the partition is one repeat of the full pattern: all
    # positions advance stage-by-stage together (stage s holds repeats
    # [s·k, (s+1)·k) of every position), so a repeat's cost is the sum of
    # its blocks.  Alg. 1 then splits the repeat chain.
    per_repeat = [sum(costs)] * cfg.n_repeats
    sizes = balance_stages(per_repeat, n_stages)
    if len(set(sizes)) != 1:
        raise ValueError(
            f"{cfg.name}: balanced partition {sizes} is not uniform — "
            f"stacked per-stage params need n_repeats={cfg.n_repeats} "
            f"divisible by n_stages={n_stages}")
    k = sizes[0]
    stage_time = k * sum(costs)
    mb_bytes = (mb * seq_len * cfg.d_model
                * jnp.dtype(cfg.dtype).itemsize)
    return PipelinePlan(
        n_stages=n_stages, n_micro=n_micro, repeats_per_stage=k,
        sizes=tuple(sizes), block_costs_s=tuple(costs),
        stage_time_s=stage_time,
        bubble=pipeline_bubble_fraction(n_micro, n_stages), axis=axis,
        schedule=schedule, tp=tp,
        peak_inflight=pipeline_peak_inflight(n_micro, n_stages, schedule),
        peak_activation_bytes=pipeline_peak_activation_bytes(
            n_micro, n_stages, schedule, mb_bytes))


__all__ = ["PipelinePlan", "estimate_block_costs", "plan_pipeline"]
