"""Stage-partition planning for pipeline-parallel training.

MKPipe's Alg. 1 (throughput balancing) picks stage boundaries so the
bottleneck stage is as fast as possible.  Here the "kernels" are the
transformer blocks: `estimate_block_costs` prices one block per pattern
position through the same XLA cost-analysis path the MKPipe stage
profiler uses (`repro.core.planner._stage_cost`), converts FLOPs/bytes
into a roofline time, and `choose_partition` runs `balance_stages` over
the resulting cost vectors to derive per-stage repeat counts.

Partitions may be *heterogeneous*: stages need not hold equal repeat
counts, and different pattern positions may split their repeats across
the stages differently.  Two cost models matter, because the executor
runs one pipeline island per pattern position (position-major order):

- **realized island time** `padded_stage_time_s = Σ_p K_p·c_p` (K_p the
  position's longest per-stage chunk): each island ticks at its own
  bottleneck stage, so the per-microbatch critical path sums the
  per-position maxima — this is what today's executor pays;
- **fused bottleneck** `stage_time_s = max_s Σ_p sizes[p][s]·c_p`: the
  load-balance bound a schedule that fuses all positions into one tick
  per stage would pay — MKPipe Alg. 1's objective.

`choose_partition` compares three candidates and keeps the best by
``(realized island time, fused bottleneck)`` — never trading away
realized time for a better-looking bound (ties keep the
earlier-listed, less-padded candidate):

- **uniform** : every position splits `balance_stages([Σcosts]·R, S)`
  the same way — the old unpadded behavior when `n_repeats % n_stages
  == 0` (then provably optimal on both metrics, always kept), a
  front-loaded ceil/floor split otherwise;
- **staggered** (`n_repeats % n_stages != 0` only): every row keeps
  chunks in {floor(R/S), ceil(R/S)} — so the realized island time
  *equals* the uniform split's — but each position places its extra
  repeats on the stages least loaded so far, heaviest positions first;
  heterogeneous per-position costs (jamba-style mamba/attn/MoE mixes)
  make the staggering strictly lower the fused bottleneck — the MKPipe
  move of balancing unequal kernels in one CKE pipeline;
- **block** (`n_repeats % n_stages != 0` only): the chain of all
  `R·P` blocks in position-major execution order cut by
  `balance_stages` on the flattened per-block cost vector.  The
  aligned cut minimizes the fused bottleneck but concentrates whole
  positions on single stages, so its realized island time is provably
  ≥ the uniform split's — it wins only in degenerate cost vectors
  (e.g. zero-cost positions) and otherwise documents the gap.

The executors realize any of these with padded per-stage stacks
(`repro.models.pipeline.stage_stack`): each stage's chunk is padded to
the position's longest chunk and the stage scan skips the padding, and
the plan accounts the overhead (`padded_stage_time_s`,
`padding_overhead`).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import logging
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.analysis.costmodel import (analytic_block_cost,
                                      estimate_block_costs)
from repro.dist.pipeline import (SCHEDULES, balance_stages,
                                 pipeline_bubble_fraction,
                                 pipeline_peak_activation_bytes,
                                 pipeline_peak_inflight)
from repro.models.common import ModelConfig

log = logging.getLogger("repro.pipeline")

# Block pricing moved behind the unified cost-model API
# (`repro.analysis.costmodel`); the old private name stays importable
# for existing call sites (analysis.verify, tests).
_analytic_block_cost = analytic_block_cost


@dataclasses.dataclass(frozen=True)
class StagePartition:
    """A per-position stage assignment of the layer stack's repeats.

    ``sizes[pos][s]`` is how many repeats of pattern position `pos`
    stage `s` holds (contiguous in repeat order, possibly 0).  The
    executors pad each position's chunks to ``padded_repeats[pos] =
    max_s sizes[pos][s]`` and mask the padding, so every stage scans the
    same chunk shape while only its valid repeats contribute.
    """
    kind: str                           # "uniform" | "staggered" | "block"
    sizes: tuple[tuple[int, ...], ...]  # [pattern position][stage]
    stage_times_s: tuple[float, ...]    # per-stage valid-work time
    padded_repeats: tuple[int, ...]     # per-position padded scan length

    @property
    def bottleneck_s(self) -> float:
        """Predicted bottleneck stage time (valid work only)."""
        return max(self.stage_times_s)

    def padded_stage_time_s(self, costs: Sequence[float]) -> float:
        """Realized per-microbatch island time, `Σ_pos K_pos·c_pos`:
        the executor runs one pipeline island per position, each island
        ticks at its own bottleneck stage (the one holding the longest
        chunk `K_pos = padded_repeats[pos]`), and the islands are
        sequential — so this, not `bottleneck_s`, is what today's
        per-position schedule pays per microbatch.  (It also upper
        bounds a backend that lowers the padding mask to
        compute-both-branches select.)"""
        return sum(k * c for k, c in zip(self.padded_repeats, costs))


def choose_partition(costs: Sequence[float], n_repeats: int,
                     n_stages: int) -> StagePartition:
    """Pick the stage partition for per-position block costs `costs`.

    Compares the "uniform", "staggered" and "block" candidates (see the
    module docstring) by ``(realized island time, fused bottleneck)`` and
    keeps the best — ties keep the earlier-listed candidate, so a
    divisible `n_repeats % n_stages == 0` (where uniform sits at the
    lower bound of both metrics) always keeps the old unpadded
    partition, and a candidate is never chosen on its bottleneck bound
    at the price of realized time.
    """
    P, R, S = len(costs), int(n_repeats), int(n_stages)
    if not 1 <= S <= R:
        raise ValueError(f"need 1 <= n_stages={S} <= n_repeats={R}")
    total = sum(costs)

    def build(kind: str, sizes: list[list[int]]) -> StagePartition:
        stage_times = tuple(
            sum(sizes[p][s] * costs[p] for p in range(P)) for s in range(S))
        return StagePartition(
            kind=kind,
            sizes=tuple(tuple(row) for row in sizes),
            stage_times_s=stage_times,
            padded_repeats=tuple(max(row) for row in sizes))

    def key(part: StagePartition):
        return (part.padded_stage_time_s(costs), part.bottleneck_s)

    rsizes = balance_stages([total if total > 0 else 1.0] * R, S)
    best = build("uniform", [list(rsizes) for _ in range(P)])
    if R % S:
        # staggered: rows stay within {k, k+1} (same realized island
        # time as uniform), but each position drops its extra repeats on
        # the least-loaded stages, heaviest positions first — on
        # heterogeneous costs this strictly lowers the fused bottleneck
        k, e = divmod(R, S)
        load = [0.0] * S
        rows: list[list[int]] = [[] for _ in range(P)]
        for p in sorted(range(P), key=lambda p: -costs[p]):
            extra = set(sorted(range(S), key=lambda s: (load[s], s))[:e])
            rows[p] = [k + (1 if s in extra else 0) for s in range(S)]
            for s in range(S):
                load[s] += rows[p][s] * costs[p]
        for cand in (build("staggered", rows), _block_cut(costs, R, S,
                                                          build)):
            if key(cand) < key(best):
                best = cand
    return best


def _block_cut(costs: Sequence[float], R: int, S: int,
               build) -> StagePartition:
    """The aligned block-granularity candidate: `balance_stages` over
    the position-major flattened per-block cost chain."""
    flat = [c for c in costs for _ in range(R)]
    cuts = [0, *itertools.accumulate(balance_stages(flat, S))]
    sizes = [[max(0, min(cuts[s + 1], (p + 1) * R) - max(cuts[s], p * R))
              for s in range(S)] for p in range(len(costs))]
    return build("block", sizes)


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A validated stage partition for `make_train_step(pipeline=...)`."""
    n_stages: int
    n_micro: int
    repeats_per_stage: int            # longest padded per-stage chunk
    #                                   (== n_repeats/n_stages when uniform)
    sizes: tuple[tuple[int, ...], ...]  # [pattern position][stage] valid
    #                                   repeats (choose_partition output)
    block_costs_s: tuple[float, ...]  # per pattern position, one repeat,
    #                                   per model shard (already tp-divided)
    stage_time_s: float               # predicted bottleneck stage time
    #                                   (valid work of the slowest stage)
    bubble: float                     # analytic fill/drain bubble fraction
    #                                   (bottleneck-based when stages are
    #                                   unequal)
    axis: str = "stage"
    schedule: str = "gpipe"           # "gpipe" | "1f1b" | "interleaved"
    tp: int = 1                       # model-parallel degree inside stages
    virtual_stages: int = 1           # chunks per device (interleaved only):
    #                                   the partition splits the repeat chain
    #                                   into v·n_stages groups, group q on
    #                                   device q mod n_stages
    # analytic *schedule model* (see pipeline_peak_inflight): what a
    # loss-in-schedule executor stashes.  The island-based train step
    # keeps the loss outside the schedule, so it stashes M microbatches
    # per stage under either schedule — these fields predict the fused
    # executor / real-hardware bound, not today's island step's HBM.
    peak_inflight: int = 0            # stashed microbatches, worst stage
    peak_activation_bytes: float = 0.0  # peak_inflight × microbatch bytes
    # heterogeneous-partition accounting (all zero-overhead when the
    # partition is uniform and unpadded):
    partition: str = "uniform"        # "uniform" | "staggered" | "block"
    stage_times_s: tuple[float, ...] = ()   # per-stage valid-work time
    padded_repeats: tuple[int, ...] = ()    # per-position padded scan len
    padded_stage_time_s: float = 0.0  # lockstep scan time incl. padding
    padding_overhead: float = 0.0     # padded_stage_time_s/stage_time_s - 1


def plan_pipeline(cfg: ModelConfig, n_stages: int, n_micro: int, *,
                  global_batch: int, seq_len: int, dp: int = 1,
                  tp: int = 1, axis: str = "stage",
                  schedule: str = "gpipe", virtual_stages: int = 1,
                  block_costs: list[float] | None = None) -> PipelinePlan:
    """Validate and price an (n_stages, n_micro) pipeline for `cfg`.

    `tp` is the model-parallel degree *inside* each stage (the mesh's
    ``"model"`` axis): block costs are priced per model shard
    (`estimate_block_costs(tp=...)`) so `balance_stages` and the
    bottleneck `stage_time_s` reflect the work one device runs on a
    stage × data × model mesh.  Microbatch activation bytes are
    unchanged by `tp` — the residual stream is replicated over the model
    axis inside the islands.

    `schedule` picks the backward ordering ("gpipe" or "1f1b"); it does
    not change the partition or the bubble, only the plan's predicted
    peak activation memory (`peak_inflight` × one microbatch's residual
    stream: (local_batch/n_micro) · seq · d_model · itemsize).  That
    prediction is the *schedule's* analytic model — realized by
    executors that run the loss inside the schedule
    (`pipeline_train_microbatched`, real hardware); the island-based
    train step differentiates the loss outside the schedule and stashes
    all n_micro microbatches per stage under either value (see
    docs/pipeline-schedules.md).

    ``schedule="interleaved"`` with `virtual_stages` v > 1 partitions
    the repeat chain into v·n_stages *groups* instead of n_stages
    stages — `choose_partition` balances the same three candidates at
    group granularity, group q = c·n_stages + s lands on device
    q mod n_stages, and the plan prices the *device*: its bottleneck
    time sums its v groups, the bubble uses the interleaved form
    (S-1)/(vM+S-1) generalized to unequal groups, and the peak
    activation stash uses the interleaved bound min(vM, vS+S-1+v).

    Any `n_stages <= n_repeats` is accepted: non-uniform partitions
    (including `n_repeats % n_stages != 0`) run as padded per-stage
    stacks — `choose_partition` picks among the uniform split, the
    cost-staggered extra-repeat placement (the usual winner on
    heterogeneous costs), and the aligned block-granularity comparator,
    and the plan reports the padding overhead the padded scan pays.

    Raises ValueError when `n_stages > n_repeats` (a stage would hold no
    repeats of any position — even the padded stacks need at least one
    repeat per stage to split), the per-data-shard batch can't be
    microbatched (global_batch/dp % n_micro != 0), or `schedule` is
    unknown.
    """
    if n_stages < 1:
        raise ValueError(f"need n_stages >= 1, got {n_stages}")
    if n_micro < 1:
        raise ValueError(f"need n_micro >= 1, got {n_micro}")
    if tp < 1:
        raise ValueError(f"need tp >= 1, got {tp}")
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; want {SCHEDULES}")
    v = int(virtual_stages)
    if v < 1:
        raise ValueError(f"need virtual_stages >= 1, got {virtual_stages}")
    if v > 1 and schedule != "interleaved":
        raise ValueError(
            f"virtual_stages={v} requires schedule='interleaved', got "
            f"{schedule!r}")
    n_groups = v * n_stages
    if cfg.n_repeats < n_groups:
        raise ValueError(
            f"{cfg.name}: n_repeats={cfg.n_repeats} < "
            f"virtual_stages*n_stages={n_groups} — padded per-stage "
            "stacks relax divisibility (any virtual_stages*n_stages <= "
            "n_repeats works), but every virtual stage still needs at "
            "least one repeat to hold")
    if global_batch % dp:
        raise ValueError(
            f"global_batch={global_batch} not divisible by dp={dp}")
    local_batch = global_batch // dp
    if local_batch % n_micro:
        raise ValueError(
            f"per-shard batch {local_batch} not divisible by "
            f"n_micro={n_micro}")

    mb = max(local_batch // n_micro, 1)
    costs = (list(block_costs) if block_costs is not None
             else estimate_block_costs(cfg, mb, seq_len, tp=tp))
    if len(costs) != len(cfg.pattern):
        raise ValueError(
            f"got {len(costs)} block costs for {len(cfg.pattern)} positions")

    # Alg. 1 splits the repeat chains: `choose_partition` compares the
    # uniform split (each repeat of the full pattern priced at
    # sum(costs)) against, when n_repeats % n_stages != 0, the
    # staggered and block-granularity candidates built from the
    # per-position costs — hybrid patterns get their extra-repeat
    # placement from the measured costs.
    part = choose_partition(costs, cfg.n_repeats, n_groups)
    # part.stage_times_s is per *group* q = c·S + s; a device's valid
    # work per microbatch sums its v resident groups
    dev_times = tuple(
        sum(part.stage_times_s[c * n_stages + s] for c in range(v))
        for s in range(n_stages))
    stage_time = max(dev_times)
    # the interleaved executor ticks v times per microbatch per device,
    # each tick padded to the position's longest *group* chunk
    padded_time = v * part.padded_stage_time_s(costs)
    bubble = (pipeline_bubble_fraction(n_micro, n_stages,
                                       stage_times=part.stage_times_s,
                                       virtual_stages=v)
              if stage_time > 0.0
              else pipeline_bubble_fraction(n_micro, n_stages,
                                            virtual_stages=v))
    mb_bytes = (mb * seq_len * cfg.d_model
                * jnp.dtype(cfg.dtype).itemsize)
    return PipelinePlan(
        n_stages=n_stages, n_micro=n_micro,
        repeats_per_stage=max(part.padded_repeats),
        sizes=part.sizes, block_costs_s=tuple(costs),
        stage_time_s=stage_time,
        bubble=bubble, axis=axis,
        schedule=schedule, tp=tp, virtual_stages=v,
        peak_inflight=pipeline_peak_inflight(n_micro, n_stages, schedule,
                                             virtual_stages=v),
        peak_activation_bytes=pipeline_peak_activation_bytes(
            n_micro, n_stages, schedule, mb_bytes, virtual_stages=v),
        partition=part.kind, stage_times_s=part.stage_times_s,
        padded_repeats=part.padded_repeats,
        padded_stage_time_s=padded_time,
        padding_overhead=(padded_time / stage_time - 1.0
                          if stage_time > 0.0 else 0.0))


__all__ = ["PipelinePlan", "StagePartition", "choose_partition",
           "estimate_block_costs", "plan_pipeline"]
