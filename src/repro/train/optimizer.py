"""AdamW with f32 moments over (possibly bf16) params, plus grad-norm
clipping and a linear-warmup cosine schedule.  Hand-rolled (no optax in the
container) but shaped like a production optimizer: pure pytree functions,
state shardable leaf-by-leaf (ZeRO-1 applies sharding constraints on top).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads: Any, state: dict,
                 params: Any) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, count)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decay matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}, {
        "grad_norm": gnorm, "lr": lr}
