# Two test modes, one command each (see tests/README.md).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-dist test-kernels test-ft bench bench-smoke \
	lint-programs quickstart docs-check

# tier-1: the fast single-device suite (multi-device cases run in
# subprocesses that set their own XLA_FLAGS, so this works on 1 CPU)
test:
	$(PY) -m pytest -x -q

# multi-device mode: 8 fake host devices for the in-process tests too,
# plus a PP×TP (stage=2, model=2) smoke train run through the real CLI
# and a heterogeneous-partition smoke: --stages 3 on the jamba hybrid
# (n_repeats=4 not divisible by 3 → padded per-stage stacks), both
# schedules
test-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m pytest -q tests/test_dist.py tests/test_multidevice.py \
	    tests/test_pipeline.py
	rm -rf checkpoints/pptp-smoke
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m repro.launch.train --arch granite-3-8b --smoke --steps 2 \
	    --global-batch 8 --seq-len 64 --stages 2 --microbatch 2 \
	    --mesh-shape 2,2,2 --axes stage,data,model \
	    --ckpt-dir checkpoints/pptp-smoke
	rm -rf checkpoints/het-smoke checkpoints/het-smoke-1f1b
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m repro.launch.train --arch jamba-v0.1-52b --smoke --steps 2 \
	    --global-batch 4 --seq-len 32 --stages 3 --microbatch 2 \
	    --schedule gpipe --ckpt-dir checkpoints/het-smoke
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m repro.launch.train --arch jamba-v0.1-52b --smoke --steps 2 \
	    --global-batch 4 --seq-len 32 --stages 3 --microbatch 2 \
	    --schedule 1f1b --ckpt-dir checkpoints/het-smoke-1f1b

# kernel gate: the parity suite (five Pallas kernels, forward + grad,
# kernel vs ref vs jnp layer path), the block-size autotuner tests, and
# a --kernels pallas smoke train through the real CLI (docs/kernels.md)
test-kernels:
	$(PY) -m pytest -q tests/test_kernels.py tests/test_tune.py
	rm -rf checkpoints/kernels-smoke
	$(PY) -m repro.launch.train --arch granite-3-8b --smoke --steps 2 \
	    --global-batch 2 --seq-len 64 --kernels pallas \
	    --ckpt-dir checkpoints/kernels-smoke

# fault-tolerance gate (docs/fault-tolerance.md): the sharded-checkpoint
# contract + the elastic suite (incl. the kill-one-stage e2e, which
# spawns its own 8-fake-device subprocesses), then an elastic CLI smoke
# through the real train entrypoint: --stages 3, stage 1 killed at step
# 4, run finishes on the surviving 2-stage mesh
test-ft:
	$(PY) -m pytest -q tests/test_ckpt.py tests/test_elastic.py
	rm -rf checkpoints/elastic-smoke
	XLA_FLAGS=--xla_force_host_platform_device_count=3 \
	$(PY) -m repro.launch.train --arch jamba-v0.1-52b --smoke --steps 6 \
	    --global-batch 4 --seq-len 16 --stages 3 --microbatch 2 \
	    --mesh-shape 3,1,1 --axes stage,data,model --schedule 1f1b \
	    --elastic --inject-fail-step 4 --inject-fail-stage 1 \
	    --ckpt-dir checkpoints/elastic-smoke --ckpt-every 2

bench:
	$(PY) -m benchmarks.run

# CI smoke: exercise every benchmark section, tolerate section failures
# (perf numbers on shared runners are informational, not gating).  The
# pp×tp dryrun row lowers the pipelined train step over a
# (stage, data, model) mesh at CI scale: plan + per-axis collective bytes
bench-smoke:
	$(PY) -m repro.launch.dryrun --arch granite-3-8b --shape train_4k \
	    --smoke --stages 2 --model-par 2 --data-par 4 --microbatch 2 \
	    --out results/dryrun-smoke
	$(PY) -m repro.launch.dryrun --arch jamba-v0.1-52b --shape train_4k \
	    --smoke --stages 3 --data-par 2 --microbatch 2 \
	    --out results/dryrun-smoke
	$(PY) -m repro.launch.dryrun --arch jamba-v0.1-52b --shape train_4k \
	    --smoke --stages 2 --data-par 2 --microbatch 2 \
	    --schedule interleaved --virtual-stages 2 \
	    --out results/dryrun-smoke
	$(PY) -m benchmarks.planner_bench
	$(PY) -m benchmarks.ckpt_bench
	$(PY) -m benchmarks.run --tolerate-failures

# mklint: statically verify every bench-smoke launch config (every
# schedule incl. interleaved --virtual-stages, the heterogeneous
# --stages 3 cell, the pp×tp mesh) without compiling anything — exits 1
# on any error-severity diagnostic.  Rule catalog: docs/static-analysis.md
lint-programs:
	$(PY) tools/mklint.py --preset bench-smoke

quickstart:
	$(PY) examples/quickstart.py

# verify every relative link in *.md resolves (stdlib only, no install)
docs-check:
	$(PY) tools/check_links.py
