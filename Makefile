# Two test modes, one command each (see tests/README.md).
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-dist bench bench-smoke quickstart docs-check

# tier-1: the fast single-device suite (multi-device cases run in
# subprocesses that set their own XLA_FLAGS, so this works on 1 CPU)
test:
	$(PY) -m pytest -x -q

# multi-device mode: 8 fake host devices for the in-process tests too
test-dist:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m pytest -q tests/test_dist.py tests/test_multidevice.py \
	    tests/test_pipeline.py

bench:
	$(PY) -m benchmarks.run

# CI smoke: exercise every benchmark section, tolerate section failures
# (perf numbers on shared runners are informational, not gating)
bench-smoke:
	$(PY) -m benchmarks.run --tolerate-failures

quickstart:
	$(PY) examples/quickstart.py

# verify every relative link in *.md resolves (stdlib only, no install)
docs-check:
	$(PY) tools/check_links.py
